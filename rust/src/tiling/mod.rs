//! Tiling configuration space — the DSE's design space.
//!
//! The paper adopts CHARM's four-level decomposition (§III-A, Fig. 2):
//!
//! * level 0 — each AIE computes a fixed `32x32x32` micro-kernel;
//! * level 1 — `P_M x P_N x P_K` AIEs compute a
//!   `(32·P_M) x (32·P_N) x (32·P_K)` array tile in parallel (`P_K` is
//!   the cascade / partial-sum dimension);
//! * level 2 — PL reuse buffers enlarge the array tile by factors
//!   `B_M, B_N, B_K`; tiles `T_A`/`T_B` are buffered in BRAM/URAM and
//!   reused across the inner loops;
//! * level 3 — the remaining `ceil(d / 32·P_d·B_d)` iterations stream
//!   from DDR.
//!
//! A candidate is valid for workload `G` iff every level evenly
//! partitions the 32-padded dimensions ("candidate tiling parameters
//! that evenly partition the dimensions", §IV-A.1).

use crate::config::BoardConfig;
use crate::workloads::Gemm;

/// One tiling configuration: AIE parallelization `P_d` and PL reuse
/// buffer factors `B_d` for `d ∈ {M, N, K}`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Tiling {
    pub p_m: usize,
    pub p_n: usize,
    pub p_k: usize,
    pub b_m: usize,
    pub b_n: usize,
    pub b_k: usize,
}

impl Tiling {
    pub fn new(p: (usize, usize, usize), b: (usize, usize, usize)) -> Tiling {
        Tiling {
            p_m: p.0,
            p_n: p.1,
            p_k: p.2,
            b_m: b.0,
            b_n: b.1,
            b_k: b.2,
        }
    }

    /// Number of allocated AIEs: `N_AIE = P_M · P_N · P_K`.
    pub fn n_aie(&self) -> usize {
        self.p_m * self.p_n * self.p_k
    }

    /// Level-2 (PL buffer) tile edge lengths in elements.
    pub fn l2_tile(&self, micro: usize) -> (usize, usize, usize) {
        (
            micro * self.p_m * self.b_m,
            micro * self.p_n * self.b_n,
            micro * self.p_k * self.b_k,
        )
    }

    /// DDR-level iteration counts `(t_m, t_n, t_k)` for a workload.
    /// Returns `None` if this tiling does not evenly partition `g`.
    pub fn l3_iters(&self, g: &Gemm, micro: usize) -> Option<(usize, usize, usize)> {
        let (tm, tn, tk) = g.tiles(micro);
        let div = |tiles: usize, p: usize, b: usize| {
            let step = p * b;
            (tiles % step == 0).then_some(tiles / step)
        };
        Some((
            div(tm, self.p_m, self.b_m)?,
            div(tn, self.p_n, self.b_n)?,
            div(tk, self.p_k, self.b_k)?,
        ))
    }

    /// PL buffer footprint in bytes (double-buffered A, B and C tiles,
    /// FP32) — what the resource model packs into BRAM/URAM.
    pub fn buffer_bytes(&self, micro: usize) -> BufferBytes {
        let (lm, ln, lk) = self.l2_tile(micro);
        BufferBytes {
            a: 2 * 4 * lm * lk,
            b: 2 * 4 * lk * ln,
            c: 2 * 4 * lm * ln,
        }
    }

    pub fn label(&self) -> String {
        format!(
            "P[{},{},{}] B[{},{},{}]",
            self.p_m, self.p_n, self.p_k, self.b_m, self.b_n, self.b_k
        )
    }

    /// Stable byte encoding for hashing (deterministic measurement noise).
    pub fn to_bytes(&self, g: &Gemm) -> [u8; 72] {
        let mut out = [0u8; 72];
        let fields = [
            g.m, g.n, g.k, self.p_m, self.p_n, self.p_k, self.b_m, self.b_n, self.b_k,
        ];
        for (i, f) in fields.iter().enumerate() {
            out[i * 8..(i + 1) * 8].copy_from_slice(&(*f as u64).to_le_bytes());
        }
        out
    }
}

/// Double-buffered A/B/C tile footprints in bytes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BufferBytes {
    pub a: usize,
    pub b: usize,
    pub c: usize,
}

impl BufferBytes {
    pub fn total(&self) -> usize {
        self.a + self.b + self.c
    }
}

/// All divisors of `n`, ascending.
pub fn divisors(n: usize) -> Vec<usize> {
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Placement constraints on the AIE parallelization, from the physical
/// array geometry and the cascade chain length.
#[derive(Debug, Clone, Copy)]
pub struct TilingLimits {
    pub max_aie: usize,
    /// Cascade chains run along rows: `P_K` bounded by chain length.
    pub max_p_k: usize,
    /// `P_M`/`P_N` bounded by array columns/rows feasibility.
    pub max_p_m: usize,
    pub max_p_n: usize,
    /// Cap on the PL buffer footprint (bytes) during *enumeration*; the
    /// resource model applies the exact check later.
    pub max_buffer_bytes: usize,
}

impl TilingLimits {
    pub fn from_board(board: &BoardConfig) -> TilingLimits {
        let pl_bytes = board.bram_total * board.bram_bytes + board.uram_total * board.uram_bytes;
        TilingLimits {
            max_aie: board.aie_total,
            max_p_k: board.max_cascade,
            max_p_m: board.aie_cols,
            max_p_n: board.aie_cols,
            // Allow slight over-enumeration; exact packing filters later.
            max_buffer_bytes: (pl_bytes as f64 * 1.25) as usize,
        }
    }
}

/// Lazy enumeration of the candidate set `C(G)`: every `(P_d, B_d)`
/// that evenly partitions the padded workload and respects the placement
/// limits, in the same nested order the eager enumeration used
/// (`p_m` outer, `p_n`, `p_k`, then `b_m`/`b_n`/`b_k`).
///
/// This is the streaming front of the DSE hot path: nothing is
/// materialized up front — the engine pulls fixed-size chunks,
/// featurizes and batch-predicts them, and folds survivors into an
/// incremental Pareto front. Two hot-path economies over the old
/// triple-`flat_map` closure tower:
///
/// * the B-level divisor lists are **memoized per P value** at
///   construction (`divisors(tm/p_m)` depends only on `p_m`, yet the
///   old shape recomputed it — plus the `p_ns`/`p_ks` list clones — for
///   every `(p_n, p_k)` pair);
/// * one **reused block buffer** holds the current P-combination's
///   B-grid instead of allocating a fresh `Vec` per combination.
#[derive(Debug)]
pub struct CandidateIter {
    micro: usize,
    max_aie: usize,
    max_buffer_bytes: usize,
    /// P-level divisor lists, pre-filtered by the placement limits.
    p_ms: Vec<usize>,
    p_ns: Vec<usize>,
    p_ks: Vec<usize>,
    /// Memoized B-level divisor lists, index-aligned with the P lists:
    /// `b_ms[i] == divisors(tm / p_ms[i])`, etc.
    b_ms: Vec<Vec<usize>>,
    b_ns: Vec<Vec<usize>>,
    b_ks: Vec<Vec<usize>>,
    /// Cursor over P-combinations, advanced in nested order.
    i_m: usize,
    i_n: usize,
    i_k: usize,
    /// Reused block buffer: the current P-combination's B-grid.
    block: Vec<Tiling>,
    cursor: usize,
}

impl CandidateIter {
    fn new(g: &Gemm, micro: usize, limits: &TilingLimits) -> CandidateIter {
        let (tm, tn, tk) = g.tiles(micro);
        let p_ms: Vec<usize> = divisors(tm).into_iter().filter(|&p| p <= limits.max_p_m).collect();
        let p_ns: Vec<usize> = divisors(tn).into_iter().filter(|&p| p <= limits.max_p_n).collect();
        let p_ks: Vec<usize> = divisors(tk).into_iter().filter(|&p| p <= limits.max_p_k).collect();
        let b_ms = p_ms.iter().map(|&p| divisors(tm / p)).collect();
        let b_ns = p_ns.iter().map(|&p| divisors(tn / p)).collect();
        let b_ks = p_ks.iter().map(|&p| divisors(tk / p)).collect();
        CandidateIter {
            micro,
            max_aie: limits.max_aie,
            max_buffer_bytes: limits.max_buffer_bytes,
            p_ms,
            p_ns,
            p_ks,
            b_ms,
            b_ns,
            b_ks,
            i_m: 0,
            i_n: 0,
            i_k: 0,
            block: Vec::new(),
            cursor: 0,
        }
    }

    /// Advance to the next P-combination with a non-empty B-block,
    /// rebuilding `block` in place. `false` = enumeration exhausted.
    fn refill(&mut self) -> bool {
        self.block.clear();
        self.cursor = 0;
        while self.i_m < self.p_ms.len() {
            if self.i_n >= self.p_ns.len() {
                self.i_m += 1;
                self.i_n = 0;
                self.i_k = 0;
                continue;
            }
            if self.i_k >= self.p_ks.len() {
                self.i_n += 1;
                self.i_k = 0;
                continue;
            }
            let (i_m, i_n, i_k) = (self.i_m, self.i_n, self.i_k);
            self.i_k += 1;
            let (p_m, p_n, p_k) = (self.p_ms[i_m], self.p_ns[i_n], self.p_ks[i_k]);
            if p_m * p_n * p_k > self.max_aie {
                continue;
            }
            for &b_m in &self.b_ms[i_m] {
                for &b_n in &self.b_ns[i_n] {
                    for &b_k in &self.b_ks[i_k] {
                        let t = Tiling::new((p_m, p_n, p_k), (b_m, b_n, b_k));
                        if t.buffer_bytes(self.micro).total() <= self.max_buffer_bytes {
                            self.block.push(t);
                        }
                    }
                }
            }
            if !self.block.is_empty() {
                return true;
            }
        }
        false
    }
}

impl Iterator for CandidateIter {
    type Item = Tiling;

    fn next(&mut self) -> Option<Tiling> {
        loop {
            if self.cursor < self.block.len() {
                let t = self.block[self.cursor];
                self.cursor += 1;
                return Some(t);
            }
            if !self.refill() {
                return None;
            }
        }
    }
}

/// Construct the lazy enumeration of `C(G)` (see [`CandidateIter`]).
pub fn candidate_iter(g: &Gemm, micro: usize, limits: &TilingLimits) -> CandidateIter {
    CandidateIter::new(g, micro, limits)
}

/// Enumerate the candidate set `C(G)` eagerly (collected form of
/// [`candidate_iter`], kept for the exhaustive explorer and tests).
pub fn enumerate_candidates(g: &Gemm, micro: usize, limits: &TilingLimits) -> Vec<Tiling> {
    candidate_iter(g, micro, limits).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;
    use crate::workloads::eval_workloads;

    fn limits() -> TilingLimits {
        TilingLimits::from_board(&BoardConfig::default())
    }

    #[test]
    fn divisors_basic() {
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(28), vec![1, 2, 4, 7, 14, 28]);
        assert_eq!(divisors(97), vec![1, 97]); // prime
    }

    #[test]
    fn n_aie_and_l2_tile() {
        let t = Tiling::new((8, 8, 4), (4, 8, 1));
        assert_eq!(t.n_aie(), 256);
        assert_eq!(t.l2_tile(32), (32 * 8 * 4, 32 * 8 * 8, 32 * 4));
    }

    #[test]
    fn l3_iters_divisibility() {
        let g = Gemm::new(1024, 1024, 512); // tiles: 32, 32, 16
        let t = Tiling::new((8, 4, 2), (2, 4, 4));
        assert_eq!(t.l3_iters(&g, 32), Some((2, 2, 2)));
        let bad = Tiling::new((5, 4, 2), (2, 4, 4));
        assert_eq!(bad.l3_iters(&g, 32), None); // 32 % (5*2) != 0
    }

    #[test]
    fn buffer_bytes_double_buffered() {
        let t = Tiling::new((1, 1, 1), (1, 1, 1));
        let bb = t.buffer_bytes(32);
        assert_eq!(bb.a, 2 * 4 * 32 * 32);
        assert_eq!(bb.total(), 3 * 2 * 4 * 32 * 32);
    }

    #[test]
    fn paper_example_33x_pl_memory() {
        // Paper §III-B.1: 256 AIEs (P=[8,8,4]) with B=[1,1,1] vs B=[4,8,1]
        // gives a much larger PL footprint (the paper quotes 33x for its
        // buffer accounting; our A+B+C accounting still shows a large
        // multiple and identical AIE counts).
        let small = Tiling::new((8, 8, 4), (1, 1, 1));
        let big = Tiling::new((8, 8, 4), (4, 8, 1));
        assert_eq!(small.n_aie(), big.n_aie());
        let ratio = big.buffer_bytes(32).total() as f64 / small.buffer_bytes(32).total() as f64;
        assert!(ratio > 8.0, "ratio {ratio}");
    }

    #[test]
    fn enumeration_covers_and_respects_limits() {
        let g = Gemm::new(512, 512, 512); // tiles 16,16,16
        let cands = enumerate_candidates(&g, 32, &limits());
        assert!(!cands.is_empty());
        for t in &cands {
            assert!(t.n_aie() <= 400);
            assert!(t.p_k <= 8);
            assert!(t.l3_iters(&g, 32).is_some(), "{} invalid", t.label());
        }
        // Contains the trivial mapping and a large one.
        assert!(cands.contains(&Tiling::new((1, 1, 1), (1, 1, 1))));
        assert!(cands.iter().any(|t| t.n_aie() >= 256));
    }

    #[test]
    fn enumeration_size_is_thousands_for_typical_workloads() {
        // Paper §I: ">6000 for typical GEMM operations".
        let g = Gemm::new(1024, 4864, 896);
        let n = enumerate_candidates(&g, 32, &limits()).len();
        assert!(n > 3000, "only {n} candidates");
    }

    #[test]
    fn every_eval_workload_has_candidates() {
        for w in eval_workloads() {
            let n = enumerate_candidates(&w.gemm, 32, &limits()).len();
            assert!(n > 10, "{} has only {n} candidates", w.id);
        }
    }

    #[test]
    fn property_candidates_always_partition_evenly() {
        forall(
            0xA11CE,
            40,
            |r| {
                Gemm::new(
                    32 * r.range_usize(1, 64),
                    32 * r.range_usize(1, 64),
                    32 * r.range_usize(1, 64),
                )
            },
            |g| {
                let cands = enumerate_candidates(g, 32, &limits());
                for t in cands.iter().take(200) {
                    let (i, j, k) = t.l3_iters(g, 32).expect("must partition");
                    let (tm, tn, tk) = g.tiles(32);
                    assert_eq!(i * t.p_m * t.b_m, tm);
                    assert_eq!(j * t.p_n * t.b_n, tn);
                    assert_eq!(k * t.p_k * t.b_k, tk);
                }
            },
        );
    }

    #[test]
    fn candidate_iter_matches_naive_reference() {
        // The memoized/streaming iterator must reproduce the naive
        // nested-loop enumeration exactly — order included (the DSE's
        // determinism tie-breaks assume a stable enumeration order).
        let lim = limits();
        for g in [
            Gemm::new(512, 512, 512),
            Gemm::new(224, 3072, 768),
            Gemm::new(1024, 4864, 896),
            Gemm::new(32, 32, 32),
        ] {
            let (tm, tn, tk) = g.tiles(32);
            let mut want = Vec::new();
            for p_m in divisors(tm).into_iter().filter(|&p| p <= lim.max_p_m) {
                for p_n in divisors(tn).into_iter().filter(|&p| p <= lim.max_p_n) {
                    for p_k in divisors(tk).into_iter().filter(|&p| p <= lim.max_p_k) {
                        if p_m * p_n * p_k > lim.max_aie {
                            continue;
                        }
                        for b_m in divisors(tm / p_m) {
                            for b_n in divisors(tn / p_n) {
                                for b_k in divisors(tk / p_k) {
                                    let t = Tiling::new((p_m, p_n, p_k), (b_m, b_n, b_k));
                                    if t.buffer_bytes(32).total() <= lim.max_buffer_bytes {
                                        want.push(t);
                                    }
                                }
                            }
                        }
                    }
                }
            }
            let got: Vec<Tiling> = candidate_iter(&g, 32, &lim).collect();
            assert_eq!(got, want, "enumeration drift for {}", g.label());
        }
    }

    #[test]
    fn candidate_iter_matches_eager_enumeration() {
        for g in [
            Gemm::new(512, 512, 512),
            Gemm::new(224, 3072, 768),
            Gemm::new(32, 896, 896),
        ] {
            let lazy: Vec<Tiling> = candidate_iter(&g, 32, &limits()).collect();
            let eager = enumerate_candidates(&g, 32, &limits());
            assert_eq!(lazy, eager, "order/content drift for {}", g.label());
        }
    }

    #[test]
    fn to_bytes_is_injective_enough() {
        let g = Gemm::new(64, 64, 64);
        let a = Tiling::new((1, 2, 1), (1, 1, 2)).to_bytes(&g);
        let b = Tiling::new((1, 2, 1), (1, 2, 1)).to_bytes(&g);
        assert_ne!(a, b);
    }
}
