//! Embedded-GPU comparators (paper §V-A.2, Fig. 9, Table II).
//!
//! The paper measures PyTorch/CUDA GEMMs on three NVIDIA Jetson boards
//! with Tegrastats power sampling. Those boards are not available here;
//! each is modeled as a roofline with empirically-shaped efficiency
//! terms (DESIGN.md §1):
//!
//! * compute roof `peak · eff_c(shape)` — cuBLAS-like efficiency with
//!   tensor-tile quantization (dims off the 64/128 tile grid waste
//!   lanes), a small-M occupancy penalty, and a skinny-M/huge-N
//!   streaming penalty (weights stream from DRAM with almost no reuse
//!   per SM tile — the paper's G12 case where the VCK190 overtakes
//!   AGX Orin);
//! * memory roof `AI · BW · eff_m` — the term that makes Jetsons win
//!   big on the small, memory-bound `G1..G8` (their DDR bandwidth is
//!   2.33–8x the VCK190's, Table II).

use crate::workloads::Gemm;

/// One embedded GPU device model.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuDevice {
    pub name: String,
    /// Peak FP32-path throughput (GFLOP/s, Table II).
    pub peak_gflops: f64,
    /// Memory bandwidth (GB/s, Table II).
    pub mem_bw_gbps: f64,
    pub idle_w: f64,
    pub max_w: f64,
    /// cuBLAS baseline compute efficiency on well-shaped GEMMs.
    pub base_eff: f64,
    /// Achievable fraction of peak DRAM bandwidth.
    pub mem_eff: f64,
    /// Tensor/warp tile the kernel quantizes M and N to.
    pub tile: usize,
    /// Fixed kernel-launch + framework overhead per GEMM (s).
    pub launch_s: f64,
}

/// The three Jetson boards of Table II.
pub fn jetson_devices() -> Vec<GpuDevice> {
    vec![
        GpuDevice {
            name: "AGX Xavier".into(),
            peak_gflops: 1410.0,
            mem_bw_gbps: 136.5,
            idle_w: 9.0,
            max_w: 30.0,
            base_eff: 0.62,
            mem_eff: 0.75,
            tile: 64,
            launch_s: 12e-6,
        },
        GpuDevice {
            name: "Xavier NX".into(),
            peak_gflops: 844.8,
            mem_bw_gbps: 59.71,
            idle_w: 5.0,
            max_w: 15.0,
            base_eff: 0.60,
            mem_eff: 0.72,
            tile: 64,
            launch_s: 12e-6,
        },
        GpuDevice {
            name: "AGX Orin".into(),
            peak_gflops: 5325.0,
            mem_bw_gbps: 204.8,
            idle_w: 12.0,
            max_w: 50.0,
            base_eff: 0.64,
            mem_eff: 0.78,
            tile: 128,
            launch_s: 10e-6,
        },
    ]
}

impl GpuDevice {
    /// Shape-dependent compute efficiency multiplier.
    pub fn shape_efficiency(&self, g: &Gemm) -> f64 {
        let quant = |d: usize| {
            let padded = d.div_ceil(self.tile) * self.tile;
            d as f64 / padded as f64
        };
        // Tile quantization on the output dims.
        let mut eff = quant(g.m) * quant(g.n);
        // Small-M occupancy: too few thread-block rows to fill the SMs.
        if g.m < 256 {
            eff *= (g.m as f64 / 256.0).powf(0.3);
        }
        // Skinny-M / huge-N weight streaming: each weight tile is used by
        // very few output rows, so the kernel degenerates to DRAM-bound
        // streaming with poor L2 reuse (bigger tile => bigger waste).
        if g.n >= 16 * g.m && (g.n * g.k) as f64 * 4.0 > 64e6 {
            eff *= 0.30;
        }
        eff.clamp(0.02, 1.0)
    }

    /// Attained throughput (GFLOP/s) on the roofline.
    pub fn throughput(&self, g: &Gemm) -> f64 {
        let compute_roof = self.peak_gflops * self.base_eff * self.shape_efficiency(g);
        let ai = g.arithmetic_intensity();
        let mem_roof = ai * self.mem_bw_gbps * self.mem_eff;
        let roof = compute_roof.min(mem_roof);
        // Launch overhead matters for the tiny decode GEMMs.
        let t = g.flops() / (roof * 1e9) + self.launch_s;
        g.flops() / t / 1e9
    }

    pub fn latency_s(&self, g: &Gemm) -> f64 {
        g.flops() / (self.throughput(g) * 1e9)
    }

    /// Board power while running `g`: idle + utilization-scaled dynamic
    /// (memory-bound kernels hold the GPU at high clocks too, hence the
    /// floor on the duty term).
    pub fn power_w(&self, g: &Gemm) -> f64 {
        let util = self.throughput(g) / (self.peak_gflops * self.base_eff);
        let duty = 0.35 + 0.65 * util.clamp(0.0, 1.0);
        self.idle_w + duty * (self.max_w - self.idle_w)
    }

    pub fn energy_eff(&self, g: &Gemm) -> f64 {
        self.throughput(g) / self.power_w(g)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::eval_workloads;

    fn devices() -> Vec<GpuDevice> {
        jetson_devices()
    }

    #[test]
    fn table2_specs() {
        let d = devices();
        assert_eq!(d.len(), 3);
        assert_eq!(d[0].name, "AGX Xavier");
        assert!((d[1].peak_gflops - 844.8).abs() < 1e-9);
        assert!((d[2].mem_bw_gbps - 204.8).abs() < 1e-9);
    }

    #[test]
    fn throughput_below_effective_peak() {
        for dev in devices() {
            for w in eval_workloads() {
                let t = dev.throughput(&w.gemm);
                assert!(t > 0.0);
                assert!(t <= dev.peak_gflops * dev.base_eff + 1e-9);
            }
        }
    }

    #[test]
    fn orin_fastest_on_large_square() {
        let d = devices();
        let g = Gemm::new(2048, 2048, 2048);
        let thr: Vec<f64> = d.iter().map(|x| x.throughput(&g)).collect();
        assert!(thr[2] > thr[0] && thr[0] > thr[1]);
    }

    #[test]
    fn quantization_hurts_odd_shapes() {
        let d = &devices()[2];
        let aligned = Gemm::new(2048, 2048, 2048);
        let odd = Gemm::new(2048 + 1, 2048 + 1, 2048);
        assert!(d.shape_efficiency(&odd) < d.shape_efficiency(&aligned));
    }

    #[test]
    fn skinny_huge_n_penalized() {
        let d = &devices()[2];
        let lm_head = Gemm::new(256, 128256, 2048);
        let square = Gemm::new(2048, 2048, 2048);
        assert!(d.shape_efficiency(&lm_head) < 0.35 * d.shape_efficiency(&square));
    }

    #[test]
    fn power_within_board_envelope() {
        for dev in devices() {
            for w in eval_workloads() {
                let p = dev.power_w(&w.gemm);
                assert!(p >= dev.idle_w && p <= dev.max_w + 1e-9, "{p} on {}", dev.name);
            }
        }
    }

    #[test]
    fn memory_bound_small_workloads_run_below_compute_roof() {
        let d = &devices()[1]; // Xavier NX, weakest memory
        let g = Gemm::new(32, 896, 896);
        let compute_roof = d.peak_gflops * d.base_eff * d.shape_efficiency(&g);
        assert!(d.throughput(&g) < compute_roof);
    }
}
