//! Rendering for lint results: the human table `cargo run -- lint`
//! prints, and the JSON document CI uploads as an artifact.

use crate::util::json::{arr, num, obj, s, Json};
use crate::util::table::Table;

use super::{Finding, LintReport};

/// Schema version of the JSON report.
pub const REPORT_VERSION: u64 = 1;

fn status(f: &Finding) -> &'static str {
    if f.waived {
        "waived"
    } else if f.baselined {
        "baselined"
    } else {
        "FAIL"
    }
}

/// Human-readable table: one row per finding plus a summary line.
pub fn render_table(report: &LintReport) -> String {
    let mut out = String::new();
    if report.findings.is_empty() {
        out.push_str(&format!(
            "lint: clean — {} files scanned, {} rules, no findings\n",
            report.files_scanned,
            report.rules.len()
        ));
        return out;
    }
    let mut t = Table::new("lint findings", &["location", "rule", "status", "message"]);
    for f in &report.findings {
        t.row(vec![
            format!("{}:{}", f.file, f.line),
            f.rule.to_string(),
            status(f).to_string(),
            f.message.clone(),
        ]);
    }
    out.push_str(&t.render());
    out.push_str(&format!(
        "\n{} failing, {} waived, {} baselined ({} files scanned, {} rules)\n",
        report.count_unwaived(),
        report.count_waived(),
        report.count_baselined(),
        report.files_scanned,
        report.rules.len()
    ));
    out
}

/// Machine-readable report (CI artifact). Findings keep their waived /
/// baselined flags so the artifact shows the full picture, not just
/// what failed.
pub fn render_json(report: &LintReport) -> String {
    let findings: Vec<Json> = report
        .findings
        .iter()
        .map(|f| {
            obj(vec![
                ("file", s(&f.file)),
                ("line", num(f.line as f64)),
                ("rule", s(f.rule)),
                ("status", s(status(f))),
                ("message", s(&f.message)),
            ])
        })
        .collect();
    let rules: Vec<Json> = report
        .rules
        .iter()
        .map(|(id, desc)| obj(vec![("id", s(id)), ("describes", s(desc))]))
        .collect();
    obj(vec![
        ("version", num(REPORT_VERSION as f64)),
        ("files_scanned", num(report.files_scanned as f64)),
        ("failing", num(report.count_unwaived() as f64)),
        ("waived", num(report.count_waived() as f64)),
        ("baselined", num(report.count_baselined() as f64)),
        ("rules", arr(rules)),
        ("findings", arr(findings)),
    ])
    .to_string_pretty()
}

#[cfg(test)]
mod tests {
    use super::super::{run, Baseline, Repo};
    use super::*;
    use crate::util::json::Json;

    fn sample() -> LintReport {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let repo = Repo::from_sources(&[("rust/src/server/fx.rs", src)]);
        run(&repo, &Baseline::empty())
    }

    #[test]
    fn table_lists_findings_with_anchors() {
        let text = render_table(&sample());
        assert!(text.contains("rust/src/server/fx.rs:1"), "{text}");
        assert!(text.contains("panic-freedom"), "{text}");
        assert!(text.contains("1 failing"), "{text}");
    }

    #[test]
    fn clean_repo_renders_clean_line() {
        let repo = Repo::from_sources(&[("rust/src/x.rs", "pub fn f() {}\n")]);
        let text = render_table(&run(&repo, &Baseline::empty()));
        assert!(text.contains("clean"), "{text}");
    }

    #[test]
    fn json_report_roundtrips_and_counts() {
        let text = render_json(&sample());
        let v = Json::parse(&text).expect("report is valid json");
        assert_eq!(v.get("failing").and_then(Json::as_u64), Some(1));
        let findings = v.get("findings").and_then(Json::as_arr).expect("findings");
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").and_then(Json::as_str),
            Some("panic-freedom")
        );
        assert_eq!(findings[0].get("line").and_then(Json::as_u64), Some(1));
    }
}
