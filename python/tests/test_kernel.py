"""Kernel-vs-oracle correctness: the CORE build-time signal.

``hypothesis`` sweeps the Pallas kernel's shape/block/dtype space and
asserts allclose against the pure-jnp oracle in ``ref.py``; nothing is
AOT-lowered unless these pass.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import gemm_ref, tiled_gemm_ref
from compile.kernels.tiled_gemm import (
    MICRO_K,
    MICRO_M,
    MICRO_N,
    arithmetic_intensity,
    grid_shape,
    micro_gemm,
    mxu_utilization,
    tiled_gemm,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------------------
# Micro-kernel (the paper's fixed 32x32x32 AIE workload)
# ---------------------------------------------------------------------------


def test_micro_gemm_matches_ref():
    a = _rand((MICRO_M, MICRO_K), seed=1)
    b = _rand((MICRO_K, MICRO_N), seed=2)
    np.testing.assert_allclose(micro_gemm(a, b), gemm_ref(a, b), rtol=1e-5, atol=1e-5)


def test_micro_gemm_identity():
    a = jnp.eye(32, dtype=jnp.float32)
    b = _rand((32, 32), seed=3)
    np.testing.assert_allclose(micro_gemm(a, b), b, rtol=1e-6, atol=1e-6)


def test_micro_gemm_zeros():
    a = jnp.zeros((32, 32), jnp.float32)
    b = _rand((32, 32), seed=4)
    assert jnp.all(micro_gemm(a, b) == 0.0)


def test_micro_gemm_rejects_bad_shape():
    with pytest.raises(ValueError):
        micro_gemm(_rand((16, 32)), _rand((32, 32)))


# ---------------------------------------------------------------------------
# Tiled GEMM: fixed-case grid coverage
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n,k",
    [
        (32, 32, 32),
        (64, 32, 32),
        (32, 64, 32),
        (32, 32, 64),
        (64, 64, 64),
        (96, 64, 128),
        (128, 128, 128),
        (32, 256, 96),
    ],
)
def test_tiled_gemm_matches_ref(m, n, k):
    a = _rand((m, k), seed=m + n)
    b = _rand((k, n), seed=k + n)
    got = tiled_gemm(a, b)
    np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 64, 32), (32, 128, 64), (128, 128, 128)])
def test_tiled_gemm_block_shapes(bm, bn, bk):
    m, n, k = 128, 128, 128
    a = _rand((m, k), seed=7)
    b = _rand((k, n), seed=8)
    got = tiled_gemm(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4, atol=1e-4)


def test_tiled_gemm_k_accumulation_order():
    # Matches the blocked-accumulation oracle bit-for-bit-ish (same order).
    m, n, k = 64, 64, 128
    a = _rand((m, k), seed=9)
    b = _rand((k, n), seed=10)
    got = tiled_gemm(a, b)
    want = tiled_gemm_ref(a, b, block_k=MICRO_K)
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_tiled_gemm_rejects_indivisible():
    with pytest.raises(ValueError):
        tiled_gemm(_rand((48, 32)), _rand((32, 32)))
    with pytest.raises(ValueError):
        tiled_gemm(_rand((32, 40)), _rand((40, 32)))


def test_tiled_gemm_rejects_contraction_mismatch():
    with pytest.raises(ValueError):
        tiled_gemm(_rand((32, 64)), _rand((32, 32)))


# ---------------------------------------------------------------------------
# Hypothesis sweep over shapes / blocks / dtypes
# ---------------------------------------------------------------------------

dims = st.integers(min_value=1, max_value=4).map(lambda x: 32 * x)


@settings(max_examples=25, deadline=None)
@given(m=dims, n=dims, k=dims, seed=st.integers(0, 2**16))
def test_hypothesis_shapes_f32(m, n, k, seed):
    a = _rand((m, k), seed=seed)
    b = _rand((k, n), seed=seed + 1)
    np.testing.assert_allclose(
        tiled_gemm(a, b), gemm_ref(a, b), rtol=1e-4, atol=1e-4
    )


@settings(max_examples=10, deadline=None)
@given(
    mult=st.tuples(st.integers(1, 2), st.integers(1, 2), st.integers(1, 2)),
    blocks=st.sampled_from([(32, 32, 32), (64, 32, 32), (32, 64, 64)]),
    seed=st.integers(0, 2**16),
)
def test_hypothesis_block_shapes(mult, blocks, seed):
    bm, bn, bk = blocks
    m, n, k = bm * mult[0], bn * mult[1], bk * mult[2]
    a = _rand((m, k), seed=seed)
    b = _rand((k, n), seed=seed + 1)
    got = tiled_gemm(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, gemm_ref(a, b), rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]), seed=st.integers(0, 2**16))
def test_hypothesis_dtypes(dtype, seed):
    # Paper is FP32-only (VCK190 constraint); bfloat16 covers the
    # "newer formats" the paper cites as future targets.
    a = _rand((64, 64), dtype=dtype, seed=seed)
    b = _rand((64, 64), dtype=dtype, seed=seed + 1)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(tiled_gemm(a, b), dtype=np.float32),
        np.asarray(gemm_ref(a, b), dtype=np.float32),
        rtol=tol,
        atol=tol,
    )


# ---------------------------------------------------------------------------
# Static estimator sanity (used by the perf pass)
# ---------------------------------------------------------------------------


def test_vmem_footprint():
    # 32^3 f32: 3 * 32*32*4 bytes = 12 KiB — fits the AIE's 32 KB analogue.
    assert vmem_footprint_bytes(32, 32, 32) == 3 * 32 * 32 * 4
    assert vmem_footprint_bytes(128, 128, 128) == 3 * 128 * 128 * 4


def test_mxu_utilization_monotone():
    assert mxu_utilization(128, 128, 128) == 1.0
    assert mxu_utilization(32, 32, 32) == pytest.approx((32 / 128) ** 2)
    assert mxu_utilization(32, 32, 32) < mxu_utilization(64, 64, 64)


def test_arithmetic_intensity_grows_with_block():
    assert arithmetic_intensity(64, 64, 64) > arithmetic_intensity(32, 32, 32)


def test_grid_shape():
    assert grid_shape(128, 64, 96, 32, 32, 32) == (4, 2, 3)
    with pytest.raises(ValueError):
        grid_shape(100, 64, 96, 32, 32, 32)
