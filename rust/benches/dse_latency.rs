//! Bench: online-phase DSE wall-clock per workload (paper §V-A: the
//! ML-driven DSE completes in < 2 s per workload). Exercises the
//! streaming path: lazy candidate iterator -> PREDICT_CHUNK-sized
//! batched GBDT predictions -> incremental Pareto front.
//!
//! Section 1 isolates the model layer: `CompiledForest::predict_rows`
//! (one SoA arena, row-blocked traversal) vs the legacy per-tree walk
//! on the same trained bundle and the same feature rows, asserting the
//! >= 2x predictions-per-second acceptance floor plus bit-identical
//! outputs.
//!
//! `--smoke` runs a cheap release-mode pass for CI: a reduced in-memory
//! dataset/model, fewer iterations, the first two workloads, and
//! report-only timing (shared runners are too noisy to hard-gate a
//! measured ratio; the bit-identical output assert is the smoke gate).
use versal_gemm::config::Config;
use versal_gemm::dataset::Dataset;
use versal_gemm::features::{featurize, FeatureSet};
use versal_gemm::models::Predictors;
use versal_gemm::report::Lab;
use versal_gemm::tiling::enumerate_candidates;
use versal_gemm::util::bench::{bench, report, report_throughput};
use versal_gemm::workloads::{eval_workloads, training_workloads, Gemm};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let lab = if smoke {
        // Fast in-memory lab: no disk cache, reduced offline budget.
        let mut cfg = Config::default();
        cfg.dataset.top_k = 12;
        cfg.dataset.bottom_k = 8;
        cfg.dataset.random_k = 60;
        cfg.train.n_trees = 120;
        cfg.train.learning_rate = 0.15;
        let ds = Dataset::generate(&cfg, &training_workloads());
        let predictors = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
        Lab::in_memory(cfg, ds, predictors)
    } else {
        Lab::prepare(Config::default(), "data".into())?
    };
    let engine = lab.engine();

    // ---- 1. forest engine vs legacy per-tree traversal -----------------
    let predictors = &engine.predictors;
    let n_feat = predictors.feature_set.len();
    let g = Gemm::new(512, 1024, 768);
    let cands = enumerate_candidates(&g, engine.micro, &engine.limits);
    let mut rows: Vec<f64> = Vec::with_capacity(cands.len() * n_feat);
    for t in &cands {
        let full = featurize(&g, t, engine.micro);
        rows.extend_from_slice(&full[..n_feat]);
    }
    let fm = predictors.forest_metrics();
    println!(
        "== bench: forest inference engine ({} outputs, {} trees, {} nodes; \
         compile {:.2} ms) ==",
        fm.n_outputs, fm.n_trees, fm.n_nodes, fm.compile_ms
    );
    let iters = if smoke { 3 } else { 9 };
    let mut legacy_preds = Vec::new();
    let legacy = bench(1, iters, || {
        predictors.predict_rows_legacy(&rows, n_feat, &mut legacy_preds);
        std::hint::black_box(legacy_preds.len());
    });
    let mut forest_preds = Vec::new();
    let forest = bench(1, iters, || {
        predictors.predict_rows(&rows, n_feat, &mut forest_preds);
        std::hint::black_box(forest_preds.len());
    });
    assert_eq!(
        forest_preds, legacy_preds,
        "forest predictions diverged from the legacy path"
    );
    report(&format!("legacy per-tree ({} rows)", cands.len()), &legacy);
    report_throughput("  legacy rate", &legacy, cands.len() as f64, "rows");
    report(&format!("compiled forest ({} rows)", cands.len()), &forest);
    report_throughput("  forest rate", &forest, cands.len() as f64, "rows");
    let speedup = legacy.median.as_secs_f64() / forest.median.as_secs_f64();
    if smoke {
        // Report-only on CI runners: shared vCPUs make measured ratios
        // too noisy to hard-gate. The bit-identical output assert above
        // is the smoke gate; the 2x floor is enforced by the full bench.
        println!("forest speedup: {speedup:.2}x (smoke mode: informational)");
    } else {
        println!("forest speedup: {speedup:.2}x (acceptance floor: 2x)");
        assert!(
            speedup >= 2.0,
            "forest path only {speedup:.2}x over legacy (floor 2x)"
        );
    }

    // ---- 2. end-to-end streaming DSE latency per workload ---------------
    println!(
        "\n== bench: streaming DSE latency per eval workload (paper: < 2 s; chunk = {}) ==",
        versal_gemm::dse::PREDICT_CHUNK
    );
    let workloads = eval_workloads();
    let workloads = if smoke { &workloads[..2] } else { &workloads[..] };
    let mut worst = 0.0f64;
    for w in workloads {
        let stats = bench(1, if smoke { 2 } else { 5 }, || {
            let r = engine.explore(&w.gemm).unwrap();
            std::hint::black_box(r.n_feasible);
        });
        let r = engine.explore(&w.gemm)?;
        report(&format!("{} {} ({} cands)", w.id, w.gemm.label(), r.n_candidates), &stats);
        report_throughput("  prediction rate", &stats, r.n_candidates as f64, "candidates");
        worst = worst.max(stats.median.as_secs_f64());
        if !smoke {
            assert!(stats.median.as_secs_f64() < 2.0, "{} DSE exceeded 2 s", w.id);
        }
    }
    println!("worst-case median DSE: {worst:.3} s — within the paper's 2 s budget");
    Ok(())
}
