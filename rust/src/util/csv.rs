//! Minimal CSV reader/writer for dataset persistence.
//!
//! The dataset schema is numeric-heavy and never contains embedded commas
//! or newlines, but quoting is still handled for robustness.

#[derive(Debug, Clone, Default, PartialEq)]
pub struct Csv {
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Csv {
    pub fn new(header: &[&str]) -> Csv {
        Csv {
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.header.len(), "csv row width mismatch");
        self.rows.push(row);
    }

    pub fn col_index(&self, name: &str) -> Option<usize> {
        self.header.iter().position(|h| h == name)
    }

    /// Typed column accessor.
    pub fn f64_col(&self, name: &str) -> anyhow::Result<Vec<f64>> {
        let idx = self
            .col_index(name)
            .ok_or_else(|| anyhow::anyhow!("no csv column `{name}`"))?;
        self.rows
            .iter()
            .map(|r| {
                r[idx]
                    .parse::<f64>()
                    .map_err(|_| anyhow::anyhow!("bad f64 `{}` in column `{name}`", r[idx]))
            })
            .collect()
    }

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        out.push_str(&encode_row(&self.header));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&encode_row(row));
            out.push('\n');
        }
        out
    }

    pub fn parse(text: &str) -> anyhow::Result<Csv> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = match lines.next() {
            Some(h) => decode_row(h)?,
            None => anyhow::bail!("empty csv"),
        };
        let mut rows = Vec::new();
        for line in lines {
            let row = decode_row(line)?;
            if row.len() != header.len() {
                anyhow::bail!(
                    "csv row has {} fields, header has {}",
                    row.len(),
                    header.len()
                );
            }
            rows.push(row);
        }
        Ok(Csv { header, rows })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_string())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Csv> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Csv::parse(&text)
    }
}

fn encode_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

fn decode_row(line: &str) -> anyhow::Result<Vec<String>> {
    let mut cells = Vec::new();
    let mut cur = String::new();
    let mut chars = line.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes => {
                if chars.peek() == Some(&'"') {
                    chars.next();
                    cur.push('"');
                } else {
                    in_quotes = false;
                }
            }
            '"' if cur.is_empty() => in_quotes = true,
            ',' if !in_quotes => {
                cells.push(std::mem::take(&mut cur));
            }
            c => cur.push(c),
        }
    }
    if in_quotes {
        anyhow::bail!("unterminated quote in csv row");
    }
    cells.push(cur);
    Ok(cells)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let mut csv = Csv::new(&["a", "b"]);
        csv.push(vec!["1".into(), "2.5".into()]);
        csv.push(vec!["x".into(), "y".into()]);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        assert_eq!(parsed, csv);
    }

    #[test]
    fn roundtrip_quoted() {
        let mut csv = Csv::new(&["name"]);
        csv.push(vec!["has,comma".into()]);
        csv.push(vec!["has\"quote".into()]);
        let parsed = Csv::parse(&csv.to_string()).unwrap();
        assert_eq!(parsed, csv);
    }

    #[test]
    fn typed_column() {
        let csv = Csv::parse("x,y\n1,2\n3,4.5\n").unwrap();
        assert_eq!(csv.f64_col("y").unwrap(), vec![2.0, 4.5]);
        assert!(csv.f64_col("z").is_err());
    }

    #[test]
    fn width_mismatch_rejected() {
        assert!(Csv::parse("a,b\n1\n").is_err());
    }

    #[test]
    fn save_load() {
        let dir = std::env::temp_dir().join("versal_gemm_csv_test");
        let path = dir.join("d.csv");
        let mut csv = Csv::new(&["k"]);
        csv.push(vec!["v".into()]);
        csv.save(&path).unwrap();
        assert_eq!(Csv::load(&path).unwrap(), csv);
        let _ = std::fs::remove_dir_all(dir);
    }
}
