//! AIE array timing model.
//!
//! Each AI Engine runs the fixed 32x32x32 FP32 micro-kernel at ~90% of
//! its 8-MAC/cycle peak (paper §III-A). Two effects degrade the array
//! beyond what analytical models capture:
//!
//! * **cascade sync** — partial sums flow along `P_K`-deep cascade
//!   chains; each extra stage adds pipeline stalls at tile boundaries;
//! * **placement congestion** — beyond ~256 AIEs the mapper struggles to
//!   place/route the PL-side stream infrastructure, degrading achieved
//!   throughput (observed on-board as the non-uniform scaling of Fig. 3).

use crate::config::{BoardConfig, SimConfig};
use crate::tiling::Tiling;

/// Ideal cycles for one 32x32x32 micro-kernel at 100% MAC efficiency.
pub fn micro_kernel_ideal_cycles(board: &BoardConfig) -> f64 {
    let t = board.micro_tile as f64;
    t * t * t / board.macs_per_cycle
}

/// Achieved cycles for one micro-kernel including kernel inefficiency.
pub fn micro_kernel_cycles(board: &BoardConfig, sim: &SimConfig) -> f64 {
    micro_kernel_ideal_cycles(board) / sim.kernel_efficiency
}

/// Cascade efficiency for a `P_K`-deep partial-sum chain.
pub fn cascade_efficiency(t: &Tiling, sim: &SimConfig) -> f64 {
    (1.0 - sim.cascade_penalty * (t.p_k as f64 - 1.0)).max(0.5)
}

/// Placement/routing congestion derate: 1.0 up to the knee, growing
/// linearly to `1 + congestion_slope` at the full array.
pub fn congestion_factor(n_aie: usize, board: &BoardConfig, sim: &SimConfig) -> f64 {
    if n_aie <= sim.congestion_knee {
        1.0
    } else {
        let span = (board.aie_total - sim.congestion_knee).max(1) as f64;
        1.0 + sim.congestion_slope * (n_aie - sim.congestion_knee) as f64 / span
    }
}

/// Seconds of pure AIE compute for ONE level-2 (PL-buffer) iteration:
/// each of the `P_M·P_N·P_K` AIEs executes `B_M·B_N·B_K` micro-kernels.
pub fn compute_time_per_l2_iter(t: &Tiling, board: &BoardConfig, sim: &SimConfig) -> f64 {
    let micros_per_aie = (t.b_m * t.b_n * t.b_k) as f64;
    let cycles = micros_per_aie * micro_kernel_cycles(board, sim)
        / cascade_efficiency(t, sim)
        * congestion_factor(t.n_aie(), board, sim);
    cycles / board.aie_clock_hz
}

/// Peak-relative efficiency of the array for this tiling, ignoring
/// memory (used by tests and the report's roofline annotations).
pub fn array_compute_efficiency(t: &Tiling, board: &BoardConfig, sim: &SimConfig) -> f64 {
    sim.kernel_efficiency * cascade_efficiency(t, sim)
        / congestion_factor(t.n_aie(), board, sim)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> (BoardConfig, SimConfig) {
        (BoardConfig::default(), SimConfig::default())
    }

    #[test]
    fn micro_kernel_is_4096_ideal_cycles() {
        let (b, s) = defaults();
        assert_eq!(micro_kernel_ideal_cycles(&b), 4096.0);
        // ~90% efficiency => ~4551 cycles.
        assert!((micro_kernel_cycles(&b, &s) - 4096.0 / 0.9).abs() < 1e-9);
    }

    #[test]
    fn single_aie_hits_90_percent_of_peak() {
        // Paper §III-A: each AIE achieves ~90% of peak on the micro-kernel.
        let (b, s) = defaults();
        let t = Tiling::new((1, 1, 1), (1, 1, 1));
        let secs = compute_time_per_l2_iter(&t, &b, &s);
        let flops = 2.0 * 32.0f64.powi(3);
        let gflops = flops / secs / 1e9;
        let peak_per_aie = b.peak_gflops() / b.aie_total as f64;
        let eff = gflops / peak_per_aie;
        assert!((eff - 0.9).abs() < 1e-6, "eff {eff}");
    }

    #[test]
    fn cascade_costs_throughput() {
        let (b, s) = defaults();
        let shallow = Tiling::new((8, 8, 1), (1, 1, 1));
        let deep = Tiling::new((8, 8, 8), (1, 1, 1));
        assert!(cascade_efficiency(&deep, &s) < cascade_efficiency(&shallow, &s));
        assert!(array_compute_efficiency(&deep, &b, &s) < 0.9);
    }

    #[test]
    fn congestion_kicks_in_past_knee() {
        let (b, s) = defaults();
        assert_eq!(congestion_factor(1, &b, &s), 1.0);
        assert_eq!(congestion_factor(256, &b, &s), 1.0);
        let at_400 = congestion_factor(400, &b, &s);
        assert!((at_400 - (1.0 + s.congestion_slope)).abs() < 1e-12);
        assert!(congestion_factor(300, &b, &s) < at_400);
    }

    #[test]
    fn more_aies_do_not_slow_one_iteration() {
        // Per-iteration time depends on B (work per AIE), not on P —
        // parallel AIEs each still run B_M*B_N*B_K micro-kernels.
        let (b, s) = defaults();
        let small = Tiling::new((1, 1, 1), (2, 2, 2));
        let big = Tiling::new((8, 8, 4), (2, 2, 2));
        let ts = compute_time_per_l2_iter(&small, &b, &s);
        let tb = compute_time_per_l2_iter(&big, &b, &s);
        // big has cascade + congestion penalties but same per-AIE work.
        assert!(tb >= ts);
        assert!(tb < ts * 1.25);
    }
}
