//! PJRT runtime: load the AOT-compiled Pallas GEMM artifacts and execute
//! tiled GEMMs from the Rust hot path.
//!
//! The artifacts (`artifacts/*.hlo.txt` + `manifest.json`) are produced
//! ONCE by `make artifacts` (python/compile/aot.py); at run time this
//! module compiles them on the PJRT CPU client and composes them into
//! arbitrary-size GEMMs: the executor streams 32-aligned operand tiles,
//! invokes the micro/macro-kernel executable per tile, and accumulates
//! partial `T_C` tiles — exactly the role the PL plays for the AIE array
//! on the real board (DESIGN.md §1). Python never runs here.
//!
//! The PJRT engine is one of several execution paths: [`backend`]
//! abstracts it behind the [`backend::ExecBackend`] trait next to an
//! always-available packed-panel CPU GEMM (built on [`microkernel`],
//! the GotoBLAS2-style blocking + autovectorized register-tile kernel)
//! and a simulator-stamped variant, so the coordinator executes data
//! jobs even when no artifacts exist.

pub mod arena;
pub mod backend;
pub mod faults;
pub mod microkernel;
pub mod resilient;

use anyhow::{anyhow, bail, Context, Result};
use std::path::{Path, PathBuf};

use crate::util::json::Json;

/// Metadata of one AOT artifact (an entry of `manifest.json`).
#[derive(Debug, Clone, PartialEq)]
pub struct VariantMeta {
    pub name: String,
    pub file: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub block_m: usize,
    pub block_n: usize,
    pub block_k: usize,
}

impl VariantMeta {
    pub fn flops(&self) -> f64 {
        2.0 * (self.m * self.n * self.k) as f64
    }

    /// Dimension sanity. [`pick_variant`] divides by the block dims and
    /// assumes they partition the tile, so a malformed manifest entry
    /// must fail here at parse time with a clear error, not panic the
    /// planner mid-serve.
    pub fn validate(&self) -> Result<()> {
        for (what, dim, block) in [
            ("m", self.m, self.block_m),
            ("n", self.n, self.block_n),
            ("k", self.k, self.block_k),
        ] {
            if dim == 0 || block == 0 {
                bail!(
                    "variant `{}`: {what}={dim}, block_{what}={block} — tile and block dims must be nonzero",
                    self.name
                );
            }
            if dim % block != 0 {
                bail!(
                    "variant `{}`: block_{what}={block} does not divide {what}={dim}",
                    self.name
                );
            }
        }
        Ok(())
    }
}

/// Parsed artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub variants: Vec<VariantMeta>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let json = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let variants = json
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing `variants`"))?
            .iter()
            .map(|v| {
                let meta = VariantMeta {
                    name: v.req_str("name")?.to_string(),
                    file: v.req_str("file")?.to_string(),
                    m: v.req_usize("m")?,
                    n: v.req_usize("n")?,
                    k: v.req_usize("k")?,
                    block_m: v.req_usize("block_m")?,
                    block_n: v.req_usize("block_n")?,
                    block_k: v.req_usize("block_k")?,
                };
                meta.validate()?;
                Ok(meta)
            })
            .collect::<Result<Vec<_>>>()?;
        if variants.is_empty() {
            bail!("manifest has no variants");
        }
        Ok(Manifest {
            variants,
            dir: dir.to_path_buf(),
        })
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text, dir)
    }
}

/// Pick the variant minimizing padded work for an `MxNxK` GEMM.
///
/// Cost model (fit to the SPerf measurements): padded MACs, plus a
/// per-invocation charge, plus a per-*grid-step* charge — interpret-mode
/// Pallas pays ~10us of loop overhead per 32^3 grid step, which is why
/// the fused MXU-edge variants win whenever they fit.
///
/// Degenerate metas (a zero dim or block dim) are skipped rather than
/// divided by. Callers must supply at least one valid variant —
/// `Manifest::parse` rejects degenerate entries, so every
/// engine-loaded manifest satisfies this; with an all-degenerate
/// hand-built slice the fallback index 0 is returned and downstream
/// tiling loops must not assume its dims are usable.
pub fn pick_variant(variants: &[VariantMeta], m: usize, n: usize, k: usize) -> usize {
    let mut best = 0usize;
    let mut best_cost = f64::INFINITY;
    for (i, v) in variants.iter().enumerate() {
        // Manifest::parse enforces nonzero dividing blocks; guard
        // hand-constructed metas so the planner can't divide by zero.
        if v.m == 0 || v.n == 0 || v.k == 0 || v.block_m == 0 || v.block_n == 0 || v.block_k == 0 {
            continue;
        }
        let padded = (m.div_ceil(v.m) * v.m) as f64
            * (n.div_ceil(v.n) * v.n) as f64
            * (k.div_ceil(v.k) * v.k) as f64;
        let calls = (m.div_ceil(v.m) * n.div_ceil(v.n) * k.div_ceil(v.k)) as f64;
        let steps_per_call =
            ((v.m / v.block_m) * (v.n / v.block_n) * (v.k / v.block_k)) as f64;
        let cost = padded + calls * 40_000.0 + calls * (steps_per_call - 1.0) * 13_000.0;
        if cost < best_cost {
            best_cost = cost;
            best = i;
        }
    }
    best
}

/// Plain-Rust row-major reference GEMM (f32 accumulate, like the kernel).
pub fn matmul_ref(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    assert_eq!(a.len(), m * k);
    assert_eq!(b.len(), k * n);
    let mut c = vec![0f32; m * n];
    for i in 0..m {
        for kk in 0..k {
            let av = a[i * k + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * n..(kk + 1) * n];
            let crow = &mut c[i * n..(i + 1) * n];
            for j in 0..n {
                crow[j] += av * brow[j];
            }
        }
    }
    c
}

pub fn max_abs_diff(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    x.iter()
        .zip(y)
        .map(|(a, b)| (a - b).abs())
        .fold(0f32, f32::max)
}

/// Copy a zero-padded tile out of a row-major matrix.
pub fn extract_tile(
    src: &[f32],
    rows: usize,
    cols: usize,
    r0: usize,
    c0: usize,
    tr: usize,
    tc: usize,
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), tr * tc);
    out.fill(0.0);
    let r_end = (r0 + tr).min(rows);
    let c_end = (c0 + tc).min(cols);
    for r in r0..r_end {
        let src_row = &src[r * cols + c0..r * cols + c_end];
        let dst_row = &mut out[(r - r0) * tc..(r - r0) * tc + (c_end - c0)];
        dst_row.copy_from_slice(src_row);
    }
}

/// Accumulate a (cropped) result tile into the output matrix.
pub fn accumulate_tile(
    dst: &mut [f32],
    rows: usize,
    cols: usize,
    r0: usize,
    c0: usize,
    tr: usize,
    tc: usize,
    tile: &[f32],
) {
    debug_assert_eq!(tile.len(), tr * tc);
    let r_end = (r0 + tr).min(rows);
    let c_end = (c0 + tc).min(cols);
    for r in r0..r_end {
        let dst_row = &mut dst[r * cols + c0..r * cols + c_end];
        let src_row = &tile[(r - r0) * tc..(r - r0) * tc + (c_end - c0)];
        for (d, s) in dst_row.iter_mut().zip(src_row) {
            *d += *s;
        }
    }
}

/// The PJRT-backed GEMM engine. One compiled executable per artifact.
pub struct GemmEngine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: Vec<xla::PjRtLoadedExecutable>,
    /// Executed tile-kernel invocations (for stats/benches).
    pub invocations: std::cell::Cell<u64>,
}

impl GemmEngine {
    /// Compile every artifact on the PJRT CPU client.
    pub fn load(dir: &Path) -> Result<GemmEngine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut exes = Vec::with_capacity(manifest.variants.len());
        for v in &manifest.variants {
            let path = dir.join(&v.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e:?}", v.name))?;
            exes.push(exe);
        }
        Ok(GemmEngine {
            manifest,
            client,
            exes,
            invocations: std::cell::Cell::new(0),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn variant_index(&self, name: &str) -> Option<usize> {
        self.manifest.variants.iter().position(|v| v.name == name)
    }

    /// Execute one artifact on exact-shape operands.
    pub fn execute_variant(&self, idx: usize, a: &[f32], b: &[f32]) -> Result<Vec<f32>> {
        let v = &self.manifest.variants[idx];
        if a.len() != v.m * v.k || b.len() != v.k * v.n {
            bail!(
                "variant {} expects {}x{} @ {}x{}",
                v.name,
                v.m,
                v.k,
                v.k,
                v.n
            );
        }
        let la = self.tile_buffer(a, v.m, v.k)?;
        let lb = self.tile_buffer(b, v.k, v.n)?;
        self.execute_buffers(idx, &la, &lb)
    }

    /// Transfer a host tile to a device buffer (done ONCE per tile; the
    /// tiled executor replays the buffer across every tile pair it
    /// participates in — the PL double-buffering analogue, and the
    /// executor's SPerf optimization: no per-invocation host->device
    /// literal construction).
    pub fn tile_buffer(&self, data: &[f32], rows: usize, cols: usize) -> Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer::<f32>(data, &[rows, cols], None)
            .map_err(|e| anyhow!("tile transfer: {e:?}"))
    }

    /// Execute on pre-transferred device buffers — the reuse fast path.
    pub fn execute_buffers(
        &self,
        idx: usize,
        la: &xla::PjRtBuffer,
        lb: &xla::PjRtBuffer,
    ) -> Result<Vec<f32>> {
        let v = &self.manifest.variants[idx];
        let result = self.exes[idx]
            .execute_b::<&xla::PjRtBuffer>(&[la, lb])
            .map_err(|e| anyhow!("execute {}: {e:?}", v.name))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e:?}"))?;
        // Lowered with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1().map_err(|e| anyhow!("untuple: {e:?}"))?;
        self.invocations.set(self.invocations.get() + 1);
        out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))
    }

    /// Full tiled GEMM via the best-fitting artifact (auto-selected).
    pub fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        let idx = pick_variant(&self.manifest.variants, m, n, k);
        self.gemm_with(idx, a, b, m, n, k)
    }

    /// Full tiled GEMM through a specific artifact: pad, stream tiles,
    /// invoke, accumulate partial C tiles (the PL's job on the board).
    pub fn gemm_with(
        &self,
        idx: usize,
        a: &[f32],
        b: &[f32],
        m: usize,
        n: usize,
        k: usize,
    ) -> Result<Vec<f32>> {
        if a.len() != m * k || b.len() != k * n {
            bail!("operand shapes do not match {m}x{n}x{k}");
        }
        let v = self.manifest.variants[idx].clone();
        let (vm, vn, vk) = (v.m, v.n, v.k);
        let mut c = vec![0f32; m * n];
        let mut atile = vec![0f32; vm * vk];
        let mut btile = vec![0f32; vk * vn];
        // Transfer each B column-panel tile to the device once per K step
        // and reuse it across every A row panel (B tiles are revisited
        // m/vm times; A tiles n/vn times).
        for kk in (0..k).step_by(vk) {
            let mut b_buffers = Vec::with_capacity(n.div_ceil(vn));
            for j in (0..n).step_by(vn) {
                extract_tile(b, k, n, kk, j, vk, vn, &mut btile);
                b_buffers.push(self.tile_buffer(&btile, vk, vn)?);
            }
            for i in (0..m).step_by(vm) {
                extract_tile(a, m, k, i, kk, vm, vk, &mut atile);
                let la = self.tile_buffer(&atile, vm, vk)?;
                for (jj, lb) in b_buffers.iter().enumerate() {
                    let out = self.execute_buffers(idx, &la, lb)?;
                    accumulate_tile(&mut c, m, n, i, jj * vn, vm, vn, &out);
                }
            }
        }
        Ok(c)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn metas() -> Vec<VariantMeta> {
        let mk = |name: &str, m: usize, n: usize, k: usize| VariantMeta {
            name: name.into(),
            file: format!("{name}.hlo.txt"),
            m,
            n,
            k,
            block_m: 32,
            block_n: 32,
            block_k: 32,
        };
        vec![
            mk("micro_32", 32, 32, 32),
            mk("tile_64", 64, 64, 64),
            mk("tile_128", 128, 128, 128),
            mk("tile_32x128x128", 32, 128, 128),
        ]
    }

    #[test]
    fn manifest_parses() {
        let text = r#"{"version": 1, "variants": [
            {"name": "micro_32", "file": "micro_32.hlo.txt", "m": 32, "n": 32,
             "k": 32, "block_m": 32, "block_n": 32, "block_k": 32}
        ]}"#;
        let m = Manifest::parse(text, Path::new("/tmp")).unwrap();
        assert_eq!(m.variants.len(), 1);
        assert_eq!(m.variants[0].name, "micro_32");
        assert_eq!(m.variants[0].flops(), 2.0 * 32768.0);
        assert!(Manifest::parse(r#"{"variants": []}"#, Path::new("/tmp")).is_err());
    }

    #[test]
    fn manifest_rejects_malformed_block_dims() {
        // Regression: a zero or non-dividing block dim used to sail
        // through parsing and panic `pick_variant` in the planner.
        let text = |m: usize, block_m: usize| {
            format!(
                r#"{{"variants": [{{"name": "bad", "file": "bad.hlo.txt",
                    "m": {m}, "n": 32, "k": 32,
                    "block_m": {block_m}, "block_n": 32, "block_k": 32}}]}}"#
            )
        };
        for (m, block_m, want) in [
            (32, 0, "nonzero"),
            (0, 32, "nonzero"),
            (0, 0, "nonzero"),
            (48, 32, "does not divide"),
        ] {
            let err = Manifest::parse(&text(m, block_m), Path::new("/tmp"))
                .unwrap_err()
                .to_string();
            assert!(err.contains(want), "m={m} block_m={block_m}: {err}");
            assert!(err.contains("bad"), "error names the variant: {err}");
        }
        // A well-formed entry still parses.
        assert!(Manifest::parse(&text(64, 32), Path::new("/tmp")).is_ok());
    }

    #[test]
    fn pick_variant_skips_degenerate_metas() {
        // Hand-constructed zero-block metas are skipped, not divided by
        // — even when the degenerate variant would otherwise have won.
        let mut v = metas();
        assert_eq!(v[pick_variant(&v, 128, 128, 128)].name, "tile_128");
        v[2].block_m = 0; // tile_128
        let idx = pick_variant(&v, 128, 128, 128);
        assert_ne!(v[idx].name, "tile_128");
    }

    #[test]
    fn pick_variant_prefers_fit() {
        let v = metas();
        // Exact 128-cube: the 128 tile wins.
        assert_eq!(v[pick_variant(&v, 128, 128, 128)].name, "tile_128");
        // Decode shape (32 x 896 x 896): skinny variant avoids 4x M-padding.
        assert_eq!(v[pick_variant(&v, 32, 896, 896)].name, "tile_32x128x128");
        // Tiny GEMM: micro tile.
        assert_eq!(v[pick_variant(&v, 32, 32, 32)].name, "micro_32");
    }

    #[test]
    fn matmul_ref_known_values() {
        // [[1,2],[3,4]] @ [[5,6],[7,8]] = [[19,22],[43,50]]
        let c = matmul_ref(&[1., 2., 3., 4.], &[5., 6., 7., 8.], 2, 2, 2);
        assert_eq!(c, vec![19., 22., 43., 50.]);
    }

    #[test]
    fn extract_and_accumulate_roundtrip() {
        // 3x3 matrix, 2x2 tiles with padding.
        let src: Vec<f32> = (1..=9).map(|x| x as f32).collect();
        let mut tile = vec![0f32; 4];
        extract_tile(&src, 3, 3, 2, 2, 2, 2, &mut tile);
        assert_eq!(tile, vec![9.0, 0.0, 0.0, 0.0]); // bottom-right corner padded

        let mut dst = vec![0f32; 9];
        accumulate_tile(&mut dst, 3, 3, 2, 2, 2, 2, &tile);
        assert_eq!(dst[8], 9.0);
        assert_eq!(dst.iter().filter(|&&x| x != 0.0).count(), 1);
    }

    #[test]
    fn tiled_composition_matches_ref_in_pure_rust() {
        // Emulate the executor's tiling loop with matmul_ref as the
        // "kernel" to validate the padding/accumulation logic without
        // PJRT (the PJRT path is covered by integration tests).
        let (m, n, k) = (70, 50, 90);
        let a: Vec<f32> = (0..m * k).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
        let b: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
        let want = matmul_ref(&a, &b, m, n, k);

        let (vm, vn, vk) = (32, 32, 32);
        let mut c = vec![0f32; m * n];
        let mut atile = vec![0f32; vm * vk];
        let mut btile = vec![0f32; vk * vn];
        for i in (0..m).step_by(vm) {
            for kk in (0..k).step_by(vk) {
                extract_tile(&a, m, k, i, kk, vm, vk, &mut atile);
                for j in (0..n).step_by(vn) {
                    extract_tile(&b, k, n, kk, j, vk, vn, &mut btile);
                    let out = matmul_ref(&atile, &btile, vm, vn, vk);
                    accumulate_tile(&mut c, m, n, i, j, vm, vn, &out);
                }
            }
        }
        assert!(max_abs_diff(&c, &want) < 1e-3);
    }
}
