//! Structural model zoo: derive GEMM working sets from real DL
//! architectures instead of hard-coding shapes.
//!
//! The paper extracts its training workloads from NCF/MLP/ViT/BERT and
//! its evaluation workloads from Swin-Tiny/DeiT-Base/Qwen2.5-0.5B/
//! LLaMA-3-1B inference. This module describes those architectures
//! structurally (hidden sizes, FFN widths, attention layout) and emits
//! the per-layer GEMMs for arbitrary sequence lengths / batch sizes —
//! the job streams `examples/serve_llm.rs` and the `sweep` subcommand
//! feed to the coordinator.

use crate::workloads::Gemm;

/// A transformer-family architecture (decoder or encoder).
#[derive(Debug, Clone, PartialEq)]
pub struct TransformerSpec {
    pub name: String,
    pub hidden: usize,
    pub ffn: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub n_layers: usize,
    pub vocab: usize,
    /// Gated FFN (SwiGLU-style: gate+up projections) vs plain MLP.
    pub gated_ffn: bool,
}

impl TransformerSpec {
    pub fn head_dim(&self) -> usize {
        self.hidden / self.n_heads
    }

    /// Fused QKV output width (GQA shrinks the KV share).
    pub fn qkv_width(&self) -> usize {
        self.hidden + 2 * self.n_kv_heads * self.head_dim()
    }

    /// The GEMMs of ONE block for `m` token rows (named, in layer order).
    pub fn block_gemms(&self, m: usize) -> Vec<(String, Gemm)> {
        let mut out = vec![
            ("qkv_proj".to_string(), Gemm::new(m, self.qkv_width(), self.hidden)),
            ("attn_out".to_string(), Gemm::new(m, self.hidden, self.hidden)),
        ];
        if self.gated_ffn {
            out.push(("ffn_gate_up".to_string(), Gemm::new(m, 2 * self.ffn, self.hidden)));
        } else {
            out.push(("ffn_up".to_string(), Gemm::new(m, self.ffn, self.hidden)));
        }
        out.push(("ffn_down".to_string(), Gemm::new(m, self.hidden, self.ffn)));
        out
    }

    /// LM-head projection (decoder models).
    pub fn lm_head(&self, m: usize) -> Gemm {
        Gemm::new(m, self.vocab, self.hidden)
    }

    /// Whole-model inference working set: unique GEMMs of a forward pass
    /// over `m` token rows (blocks are identical, so one block + head).
    pub fn working_set(&self, m: usize, include_head: bool) -> Vec<(String, Gemm)> {
        let mut out = self.block_gemms(m);
        if include_head && self.vocab > 0 {
            out.push(("lm_head".to_string(), self.lm_head(m)));
        }
        out
    }

    /// Total GEMM FLOPs for a forward pass over `m` rows.
    pub fn forward_flops(&self, m: usize, include_head: bool) -> f64 {
        let per_block: f64 = self.block_gemms(m).iter().map(|(_, g)| g.flops()).sum();
        let head = if include_head && self.vocab > 0 {
            self.lm_head(m).flops()
        } else {
            0.0
        };
        per_block * self.n_layers as f64 + head
    }
}

/// Qwen2.5-0.5B (hidden 896, FFN 4864, 14 heads / 2 KV heads, 24 layers).
pub fn qwen25_05b() -> TransformerSpec {
    TransformerSpec {
        name: "Qwen2.5-0.5B".into(),
        hidden: 896,
        ffn: 4864,
        n_heads: 14,
        n_kv_heads: 2,
        n_layers: 24,
        vocab: 151_936,
        gated_ffn: true,
    }
}

/// LLaMA-3.2-1B (hidden 2048, FFN 8192, 32 heads / 8 KV heads, 16 layers).
pub fn llama3_1b() -> TransformerSpec {
    TransformerSpec {
        name: "LLaMA-3-1B".into(),
        hidden: 2048,
        ffn: 8192,
        n_heads: 32,
        n_kv_heads: 8,
        n_layers: 16,
        vocab: 128_256,
        gated_ffn: true,
    }
}

/// DeiT-Base encoder (hidden 768, MLP 3072, 12 heads, 12 layers; 197
/// tokens per image at 224x224/patch-16).
pub fn deit_base() -> TransformerSpec {
    TransformerSpec {
        name: "DeiT-Base".into(),
        hidden: 768,
        ffn: 3072,
        n_heads: 12,
        n_kv_heads: 12,
        n_layers: 12,
        vocab: 0,
        gated_ffn: false,
    }
}

/// BERT-Base encoder.
pub fn bert_base() -> TransformerSpec {
    TransformerSpec {
        name: "BERT-Base".into(),
        hidden: 768,
        ffn: 3072,
        n_heads: 12,
        n_kv_heads: 12,
        n_layers: 12,
        vocab: 0,
        gated_ffn: false,
    }
}

/// A Swin-style hierarchical ViT stage (windowed attention — the GEMM
/// shapes depend on the stage's token count and channel width).
#[derive(Debug, Clone, Copy)]
pub struct SwinStage {
    pub tokens: usize,
    pub channels: usize,
}

/// Swin-Tiny's four stages at 224x224 input.
pub fn swin_tiny_stages() -> Vec<SwinStage> {
    vec![
        SwinStage { tokens: 3136, channels: 96 },
        SwinStage { tokens: 784, channels: 192 },
        SwinStage { tokens: 196, channels: 384 },
        SwinStage { tokens: 49, channels: 768 },
    ]
}

impl SwinStage {
    /// The attention-projection and MLP GEMMs of one block in the stage.
    pub fn block_gemms(&self) -> Vec<(String, Gemm)> {
        let c = self.channels;
        vec![
            ("qkv".to_string(), Gemm::new(self.tokens, 3 * c, c)),
            ("proj".to_string(), Gemm::new(self.tokens, c, c)),
            ("mlp_fc1".to_string(), Gemm::new(self.tokens, 4 * c, c)),
            ("mlp_fc2".to_string(), Gemm::new(self.tokens, c, 4 * c)),
        ]
    }
}

/// NCF MLP tower (user/item embedding concat -> funnel MLP).
pub fn ncf_gemms(batch: usize) -> Vec<(String, Gemm)> {
    vec![
        ("mlp_l1".to_string(), Gemm::new(batch, 256, 512)),
        ("mlp_l2".to_string(), Gemm::new(batch, 128, 256)),
        ("mlp_l3".to_string(), Gemm::new(batch, 64, 128)),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn qwen_shapes_match_eval_catalog() {
        let q = qwen25_05b();
        assert_eq!(q.head_dim(), 64);
        // GQA: 2 KV heads of 64 -> qkv width 896 + 256.
        assert_eq!(q.qkv_width(), 1152);
        let block = q.block_gemms(32);
        // attn_out is the paper-catalog G (32, 896, 896).
        assert!(block.iter().any(|(n, g)| n == "attn_out" && *g == Gemm::new(32, 896, 896)));
        // ffn_down contraction is the FFN width.
        assert!(block.iter().any(|(n, g)| n == "ffn_down" && g.k == 4864));
    }

    #[test]
    fn llama_lm_head_matches_g13_shape() {
        let l = llama3_1b();
        assert_eq!(l.lm_head(256), Gemm::new(256, 128_256, 2048));
        assert_eq!(l.qkv_width(), 2048 + 2 * 8 * 64);
    }

    #[test]
    fn deit_block_shapes() {
        let d = deit_base();
        let block = d.block_gemms(197);
        assert!(block.iter().any(|(n, g)| n == "ffn_up" && *g == Gemm::new(197, 3072, 768)));
        assert!(!d.gated_ffn);
        assert_eq!(block.len(), 4);
    }

    #[test]
    fn swin_stages_shrink_tokens_grow_channels() {
        let stages = swin_tiny_stages();
        assert_eq!(stages.len(), 4);
        for w in stages.windows(2) {
            assert_eq!(w[0].tokens, 4 * w[1].tokens);
            assert_eq!(2 * w[0].channels, w[1].channels);
        }
        let g = &stages[0].block_gemms()[0].1;
        assert_eq!(*g, Gemm::new(3136, 288, 96));
    }

    #[test]
    fn forward_flops_scale_with_layers_and_rows() {
        let q = qwen25_05b();
        let f1 = q.forward_flops(64, false);
        let f2 = q.forward_flops(128, false);
        assert!((f2 / f1 - 2.0).abs() < 1e-9);
        assert!(q.forward_flops(64, true) > f1);
    }

    #[test]
    fn ncf_funnel() {
        let g = ncf_gemms(256);
        assert_eq!(g.len(), 3);
        for w in g.windows(2) {
            assert!(w[0].1.n > w[1].1.n);
        }
    }
}
