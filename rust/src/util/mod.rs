//! Foundation utilities: deterministic PRNG, JSON/TOML/CSV codecs, CLI
//! parsing, ASCII table rendering, and a tiny property-testing helper.
//!
//! All hand-rolled: the offline crate set has no serde facade, clap,
//! rand, or proptest (see DESIGN.md §7 on vendored dependencies).

pub mod backoff;
pub mod bench;
pub mod cli;
pub mod csv;
pub mod json;
pub mod rng;
pub mod table;
pub mod toml;

/// Poison-proof mutex lock for the serve path: a panicking holder must
/// not cascade `PoisonError` panics through planner threads, so recover
/// the guard instead of unwrapping (the protected state is plain data
/// whose worst case after a panic is a stale counter).
pub fn lock_unpoisoned<T: ?Sized>(m: &std::sync::Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Property-testing helper: run `check` against `cases` randomly
/// generated inputs, reporting the failing seed on panic. A lightweight
/// stand-in for proptest in the offline environment — used by the L3
/// invariant tests (routing, batching, tiling, Pareto).
pub fn forall<G, T, C>(seed: u64, cases: usize, mut generate: G, mut check: C)
where
    G: FnMut(&mut rng::Rng) -> T,
    T: std::fmt::Debug,
    C: FnMut(&T),
{
    let mut root = rng::Rng::new(seed);
    for case in 0..cases {
        let mut case_rng = root.fork(case as u64);
        let input = generate(&mut case_rng);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| check(&input)));
        if let Err(panic) = result {
            eprintln!(
                "property failed on case {case} (seed {seed}): input = {input:?}"
            );
            std::panic::resume_unwind(panic);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut count = 0;
        forall(
            1,
            25,
            |r| r.below(10),
            |x| {
                assert!(*x < 10);
                count += 1;
            },
        );
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall(2, 50, |r| r.below(100), |x| assert!(*x < 50));
    }
}
