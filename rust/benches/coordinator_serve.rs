//! Bench: coordinator serving throughput (plan-only path: DSE + cache +
//! channels), the L3 router hot path.
use versal_gemm::config::Config;
use versal_gemm::coordinator::{Coordinator, GemmJob};
use versal_gemm::dse::Objective;
use versal_gemm::report::Lab;
use versal_gemm::util::bench::once;
use versal_gemm::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;
    println!("== bench: coordinator plan-only serving ==");
    let mut coord = Coordinator::start(&cfg, lab.engine(), None, 4);
    let shapes = [
        Gemm::new(512, 1024, 512),
        Gemm::new(224, 3072, 768),
        Gemm::new(32, 4864, 896),
        Gemm::new(2048, 2048, 2048),
    ];
    // Cold: 8 distinct (shape, objective) plans; warm: 192 cached jobs.
    let jobs: Vec<GemmJob> = (0..200u64)
        .map(|i| {
            GemmJob::plan_only(
                i,
                shapes[(i % 4) as usize],
                if i % 2 == 0 { Objective::Throughput } else { Objective::EnergyEfficiency },
            )
        })
        .collect();
    let results = once("serve 200 plan jobs (8 unique plans)", || coord.run_batch(jobs));
    assert_eq!(results.len(), 200);
    let stats = coord.stats();
    println!(
        "cache: {} hits / {} misses; failed {}",
        stats.cache_hits, stats.cache_misses, stats.jobs_failed
    );
    let warm: Vec<f64> = results.iter().filter(|r| r.cache_hit).map(|r| r.plan_time.as_secs_f64()).collect();
    println!(
        "warm plan latency: median {:.1} us over {} jobs",
        versal_gemm::metrics::median(&warm) * 1e6,
        warm.len()
    );
    Ok(())
}
