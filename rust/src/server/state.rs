//! Daemon process state: PID/state file, liveness probing, and
//! async-signal-safe SIGINT/SIGTERM capture.
//!
//! The state file (`daemon.json` in the daemon's state directory)
//! records which process owns the socket, so `serve start` can refuse a
//! second daemon, `serve stop`/`status` can find the running one, and a
//! crashed daemon's leftovers are recognised as stale (PID no longer
//! alive) and reclaimed instead of blocking restarts.
//!
//! Signals are the one place the std-only crate set needs libc symbols;
//! the three declarations below (`kill`, `signal`, `setsid`) are the
//! complete FFI surface. The handler just bumps an atomic counter —
//! everything observable happens on the daemon's tick loop, which polls
//! [`signals_received`] and routes the first signal through the drain
//! path (ISSUE 6 satellite: an interrupted daemon must still persist
//! its plan cache and write honest final stats).

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use anyhow::Context;

use crate::util::json::{self, Json};

#[cfg(unix)]
pub mod sys {
    extern "C" {
        pub fn kill(pid: i32, sig: i32) -> i32;
        pub fn signal(signum: i32, handler: usize) -> usize;
        pub fn setsid() -> i32;
    }

    pub const SIGINT: i32 = 2;
    pub const SIGPIPE: i32 = 13;
    pub const SIGTERM: i32 = 15;
    pub const SIG_IGN: usize = 1;
}

/// Contents of the daemon state file.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StateFile {
    pub pid: u32,
    /// Endpoint label: a Unix socket path or `tcp://host:port`.
    pub socket: String,
    pub started_unix: u64,
    pub version: String,
}

impl StateFile {
    pub fn current(socket: String) -> StateFile {
        StateFile {
            pid: std::process::id(),
            socket,
            started_unix: SystemTime::now()
                .duration_since(UNIX_EPOCH)
                .map(|d| d.as_secs())
                .unwrap_or(0),
            version: env!("CARGO_PKG_VERSION").to_string(),
        }
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let doc = json::obj(vec![
            ("pid", json::num(self.pid as f64)),
            ("socket", json::s(&self.socket)),
            ("started_unix", json::num(self.started_unix as f64)),
            ("version", json::s(&self.version)),
        ]);
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating state dir {}", dir.display()))?;
        }
        std::fs::write(path, doc.to_string_pretty())
            .with_context(|| format!("writing state file {}", path.display()))
    }

    /// Load the state file; `Ok(None)` when it does not exist.
    pub fn load(path: &Path) -> anyhow::Result<Option<StateFile>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(e).with_context(|| format!("reading state file {}", path.display()))
            }
        };
        let doc = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("state file {} is not JSON: {e:?}", path.display()))?;
        Ok(Some(StateFile {
            pid: doc.req_usize("pid")? as u32,
            socket: doc.req_str("socket")?.to_string(),
            started_unix: doc.req_usize("started_unix")? as u64,
            version: doc.req_str("version")?.to_string(),
        }))
    }

    /// Remove the state file (best-effort; missing is fine).
    pub fn remove(path: &Path) {
        let _ = std::fs::remove_file(path);
    }
}

/// Is a process with this PID alive? On Linux `/proc/<pid>` existence is
/// authoritative and needs no permissions; elsewhere fall back to
/// `kill(pid, 0)`.
pub fn pid_alive(pid: u32) -> bool {
    if pid == 0 {
        return false;
    }
    #[cfg(target_os = "linux")]
    {
        Path::new(&format!("/proc/{pid}")).exists()
    }
    #[cfg(all(unix, not(target_os = "linux")))]
    {
        unsafe { sys::kill(pid as i32, 0) == 0 }
    }
    #[cfg(not(unix))]
    {
        false
    }
}

/// Send SIGTERM to a process (the polite half of `--force` takeover and
/// of `serve stop` when the socket is unresponsive).
#[cfg(unix)]
pub fn terminate(pid: u32) -> bool {
    unsafe { sys::kill(pid as i32, sys::SIGTERM) == 0 }
}

#[cfg(not(unix))]
pub fn terminate(_pid: u32) -> bool {
    false
}

/// Count of SIGINT/SIGTERM deliveries (plus test-injected requests).
static SIGNALS: AtomicU64 = AtomicU64::new(0);

#[cfg(unix)]
extern "C" fn on_signal(_signum: i32) {
    // Async-signal-safe: a single atomic increment, nothing else.
    SIGNALS.fetch_add(1, Ordering::SeqCst);
}

/// Install handlers: SIGINT/SIGTERM bump the counter, SIGPIPE is
/// ignored so a write to a disconnected client surfaces as `EPIPE`
/// instead of killing the daemon.
#[cfg(unix)]
pub fn install_signal_handlers() {
    unsafe {
        sys::signal(sys::SIGINT, on_signal as usize);
        sys::signal(sys::SIGTERM, on_signal as usize);
        sys::signal(sys::SIGPIPE, sys::SIG_IGN);
    }
}

#[cfg(not(unix))]
pub fn install_signal_handlers() {}

/// How many shutdown signals have arrived so far.
pub fn signals_received() -> u64 {
    SIGNALS.load(Ordering::SeqCst)
}

/// Programmatic equivalent of delivering SIGTERM (used by tests and by
/// embedders driving the daemon in-process).
pub fn request_shutdown() {
    SIGNALS.fetch_add(1, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("versal-gemm-state-{}-{name}", std::process::id()))
    }

    #[test]
    fn state_file_roundtrip() {
        let path = tmp("roundtrip.json");
        let sf = StateFile {
            pid: 4242,
            socket: "/tmp/d.sock".to_string(),
            started_unix: 1_754_000_000,
            version: "0.1.0".to_string(),
        };
        sf.save(&path).unwrap();
        assert_eq!(StateFile::load(&path).unwrap(), Some(sf));
        StateFile::remove(&path);
        assert_eq!(StateFile::load(&path).unwrap(), None);
    }

    #[test]
    fn corrupt_state_file_is_an_error_not_a_panic() {
        let path = tmp("corrupt.json");
        std::fs::write(&path, "{ not json").unwrap();
        assert!(StateFile::load(&path).is_err());
        StateFile::remove(&path);
    }

    #[test]
    fn liveness_probes() {
        // Our own PID is alive.
        assert!(pid_alive(std::process::id()));
        // PID 0 is never "a running daemon".
        assert!(!pid_alive(0));
        // Beyond Linux's pid_max (2^22), so guaranteed dead.
        assert!(!pid_alive(0x3FF_FFFF));
    }

    #[test]
    fn shutdown_requests_accumulate() {
        let before = signals_received();
        request_shutdown();
        request_shutdown();
        assert!(signals_received() >= before + 2);
    }
}
