//! Feature engineering Φ for the ML models (paper §IV-A.3).
//!
//! 17 features in two sets:
//! * **Set-I** — fundamental parameters read directly off the workload
//!   and candidate: GEMM dims `d ∈ {M,N,K}`, AIE parallelization `P_d`
//!   and PL buffer factors `B_d` (9 features).
//! * **Set-II** — custom-crafted interaction features: allocated AIEs
//!   `N_AIE = P_M·P_N·P_K`, per-AIE computational load `ρ = FLOP/N_AIE`
//!   (Pearson r ≈ 0.81 with latency on the dataset), and the
//!   workload-to-tiling ratios `R_{P_d}` and `R_{B_d}` that let the model
//!   generalize across unseen dimension scales (8 features).

use crate::tiling::Tiling;
use crate::workloads::Gemm;

pub const N_FEATURES: usize = 17;
pub const N_FEATURES_SET1: usize = 9;

/// Which feature subset a model consumes (Fig. 6/7 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FeatureSet {
    SetI,
    SetIAndII,
}

impl FeatureSet {
    pub fn len(&self) -> usize {
        match self {
            FeatureSet::SetI => N_FEATURES_SET1,
            FeatureSet::SetIAndII => N_FEATURES,
        }
    }

    pub fn is_empty(&self) -> bool {
        false
    }

    pub fn label(&self) -> &'static str {
        match self {
            FeatureSet::SetI => "Set-I",
            FeatureSet::SetIAndII => "Set-I&II",
        }
    }
}

/// Feature names, index-aligned with [`featurize`] output.
pub const FEATURE_NAMES: [&str; N_FEATURES] = [
    "M", "N", "K", "P_M", "P_N", "P_K", "B_M", "B_N", "B_K", // Set-I
    "N_AIE", "rho", "R_P_M", "R_P_N", "R_P_K", "R_B_M", "R_B_N", "R_B_K", // Set-II
];

/// Compute the full 17-feature vector for `(g, t)`.
pub fn featurize(g: &Gemm, t: &Tiling, micro: usize) -> [f64; N_FEATURES] {
    let n_aie = t.n_aie() as f64;
    let rho = g.flops() / n_aie;
    let ratio_p = |d: usize, p: usize| d as f64 / (micro * p) as f64;
    let ratio_b = |d: usize, p: usize, b: usize| d as f64 / (micro * p * b) as f64;
    [
        g.m as f64,
        g.n as f64,
        g.k as f64,
        t.p_m as f64,
        t.p_n as f64,
        t.p_k as f64,
        t.b_m as f64,
        t.b_n as f64,
        t.b_k as f64,
        n_aie,
        rho,
        ratio_p(g.m, t.p_m),
        ratio_p(g.n, t.p_n),
        ratio_p(g.k, t.p_k),
        ratio_b(g.m, t.p_m, t.b_m),
        ratio_b(g.n, t.p_n, t.b_n),
        ratio_b(g.k, t.p_k, t.b_k),
    ]
}

/// Project a full feature vector down to the chosen subset.
pub fn project(full: &[f64; N_FEATURES], set: FeatureSet) -> Vec<f64> {
    match set {
        FeatureSet::SetI => full[..N_FEATURES_SET1].to_vec(),
        FeatureSet::SetIAndII => full.to_vec(),
    }
}

/// Featurize directly into the chosen subset.
pub fn featurize_set(g: &Gemm, t: &Tiling, micro: usize, set: FeatureSet) -> Vec<f64> {
    project(&featurize(g, t, micro), set)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_align_with_vector() {
        assert_eq!(FEATURE_NAMES.len(), N_FEATURES);
        assert_eq!(FEATURE_NAMES[9], "N_AIE");
        assert_eq!(FEATURE_NAMES[10], "rho");
    }

    #[test]
    fn set2_values() {
        let g = Gemm::new(512, 1024, 2048);
        let t = Tiling::new((4, 2, 2), (2, 4, 8));
        let f = featurize(&g, &t, 32);
        assert_eq!(f[9], 16.0); // N_AIE
        assert_eq!(f[10], g.flops() / 16.0); // rho
        assert_eq!(f[11], 512.0 / (32.0 * 4.0)); // R_P_M
        assert_eq!(f[14], 512.0 / (32.0 * 4.0 * 2.0)); // R_B_M
        assert_eq!(f[16], 2048.0 / (32.0 * 2.0 * 8.0)); // R_B_K
    }

    #[test]
    fn projection_lengths() {
        let g = Gemm::new(64, 64, 64);
        let t = Tiling::new((1, 1, 1), (1, 1, 1));
        let full = featurize(&g, &t, 32);
        assert_eq!(project(&full, FeatureSet::SetI).len(), 9);
        assert_eq!(project(&full, FeatureSet::SetIAndII).len(), 17);
        assert_eq!(FeatureSet::SetI.len(), 9);
        assert_eq!(FeatureSet::SetIAndII.len(), 17);
    }

    #[test]
    fn set1_prefix_matches() {
        let g = Gemm::new(96, 128, 160);
        let t = Tiling::new((3, 2, 1), (1, 2, 5));
        let full = featurize(&g, &t, 32);
        let s1 = project(&full, FeatureSet::SetI);
        assert_eq!(s1, full[..9].to_vec());
        assert_eq!(s1, vec![96.0, 128.0, 160.0, 3.0, 2.0, 1.0, 1.0, 2.0, 5.0]);
    }
}
