//! Sanctioned sleep and backoff primitives for the serving stack.
//!
//! The `bounded-sleep` lint rule (DESIGN.md §5) bans literal `sleep`
//! calls in `server/`, `coordinator/`, and `runtime/` non-test code:
//! an ad-hoc sleep on a serve-critical path is how drains wedge,
//! deadlines silently stretch, and retry storms synchronize. Every
//! wait in those trees routes through this module instead — `util/` is
//! outside the rule's scope by design, so the policy (slicing,
//! cancellation, jitter) lives in exactly one place:
//!
//! * [`pause`] — a plain bounded sleep, for tick loops and injected
//!   fault latency;
//! * [`cancellable_sleep`] — a sliced sleep that returns early when
//!   the cancellation flag flips, so a retry backoff never outlives a
//!   shutdown request;
//! * [`decorrelated_jitter`] — the backoff schedule used by the
//!   resilient executor and the daemon client's connect loop.

use std::sync::atomic::{AtomicBool, Ordering};
use std::time::Duration;

use crate::util::rng::Rng;

/// Slice width for [`cancellable_sleep`]: long waits are chopped into
/// slices this wide, so cancellation is observed within ~one slice.
const SLICE: Duration = Duration::from_millis(10);

/// A plain bounded sleep. The single sanctioned wrapper around
/// `std::thread::sleep` for serve-path code.
pub fn pause(d: Duration) {
    if !d.is_zero() {
        std::thread::sleep(d);
    }
}

/// Sleep for `d`, waking early if `cancel` flips true. Returns `true`
/// when the full duration elapsed, `false` when cancelled.
pub fn cancellable_sleep(d: Duration, cancel: &AtomicBool) -> bool {
    let mut left = d;
    while !left.is_zero() {
        if cancel.load(Ordering::SeqCst) {
            return false;
        }
        let step = left.min(SLICE);
        std::thread::sleep(step);
        left = left.saturating_sub(step);
    }
    !cancel.load(Ordering::SeqCst)
}

/// Decorrelated-jitter exponential backoff:
/// `next = min(cap, uniform(base, prev * 3))`. Successive delays
/// random-walk upward toward `cap` while staying desynchronized across
/// callers — under correlated failures (a tier flapping, a daemon
/// restarting) retriers do not stampede in lockstep the way plain
/// doubling does.
pub fn decorrelated_jitter(rng: &mut Rng, prev: Duration, base: Duration, cap: Duration) -> Duration {
    let lo = base.as_secs_f64();
    let hi = (prev.max(base).as_secs_f64() * 3.0).max(lo);
    let next = rng.range_f64(lo, hi).min(cap.as_secs_f64());
    Duration::from_secs_f64(next)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;
    use std::time::Instant;

    #[test]
    fn jitter_stays_within_base_and_cap() {
        let mut rng = Rng::new(7);
        let base = Duration::from_millis(10);
        let cap = Duration::from_millis(250);
        let mut prev = base;
        for _ in 0..200 {
            prev = decorrelated_jitter(&mut rng, prev, base, cap);
            assert!(prev >= base, "delay {prev:?} under base");
            assert!(prev <= cap, "delay {prev:?} over cap");
        }
    }

    #[test]
    fn jitter_is_deterministic_per_seed() {
        let run = |seed| {
            let mut rng = Rng::new(seed);
            let mut prev = Duration::from_millis(10);
            (0..50)
                .map(|_| {
                    prev = decorrelated_jitter(
                        &mut rng,
                        prev,
                        Duration::from_millis(10),
                        Duration::from_millis(250),
                    );
                    prev
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(3), run(3));
        assert_ne!(run(3), run(4));
    }

    #[test]
    fn cancellable_sleep_completes_when_uncancelled() {
        let cancel = AtomicBool::new(false);
        assert!(cancellable_sleep(Duration::from_millis(25), &cancel));
    }

    #[test]
    fn cancellable_sleep_aborts_quickly_on_cancel() {
        let cancel = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&cancel);
        let t = std::thread::spawn(move || {
            pause(Duration::from_millis(30));
            flag.store(true, Ordering::SeqCst);
        });
        let started = Instant::now();
        let completed = cancellable_sleep(Duration::from_secs(30), &cancel);
        t.join().unwrap();
        assert!(!completed);
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "cancellation took {:?}",
            started.elapsed()
        );
    }
}
