//! Single-flight plan coalescing and bounded admission — the
//! coordinator's front door.
//!
//! Real GEMM streams are heavily repetitive (a model's layer working set
//! is a handful of shapes), so the worst serving pathology is a burst of
//! identical cold jobs: without coordination every planner that misses
//! the cache for the same `(Gemm, Objective)` key redundantly runs the
//! full streaming DSE — up to `min(K, n_planners)` explorations for a
//! K-way burst that needs exactly one.
//!
//! [`FlightTable`] kills that herd with a per-key waiter queue claimed
//! at *submit* time:
//!
//! * **claim** — the first job for an un-cached, un-claimed key claims
//!   the flight and is handed to the planner pool; it will run the one
//!   exploration (the "leader").
//! * **park**  — every later job for a claimed key parks on the flight's
//!   waiter queue instead of entering the planner channel. Parked jobs
//!   consume no planner thread.
//! * **publish / fail** — when the leader resolves (cache hit, cold plan,
//!   or error), it removes the flight and completes every parked job
//!   from that one resolution. Errors propagate to all waiters.
//! * **release** — resolution always removes the flight, so a failed
//!   exploration never poisons the key: the next submit claims afresh
//!   and retries.
//!
//! Because the claim happens on the submitting thread before the job
//! reaches any planner, a burst submitted back-to-back coalesces
//! deterministically — the leader cannot publish before the remaining
//! submits have parked unless the entire DSE outran a few channel sends.
//!
//! [`QueueGauge`] bounds admission: the seed's unbounded `mpsc` channel
//! admitted unlimited queued jobs (operand buffers included). The gauge
//! counts jobs that are admitted but not yet finalized — planner-queued,
//! parked on a flight, or queued for execution with their operands —
//! against `max_queue_depth`, either blocking the submitter
//! ([`Admission::Block`]) or refusing the job with a `JobResult::error`
//! ([`Admission::Reject`]).

use std::collections::HashMap;
use std::sync::{Condvar, Mutex, MutexGuard};
use std::time::Instant;

use crate::coordinator::cache::PlanKey;
use crate::coordinator::GemmJob;
use crate::util::lock_unpoisoned;

/// What `submit` does when the queue is at `max_queue_depth`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Admission {
    /// Block the submitting thread until a planner drains the queue.
    Block,
    /// Refuse the job immediately; it surfaces as a `JobResult::error`
    /// and counts in `CoordinatorStats::rejected_jobs`.
    Reject,
}

impl Admission {
    pub fn label(&self) -> &'static str {
        match self {
            Admission::Block => "block",
            Admission::Reject => "reject",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Admission> {
        match text {
            "block" => Ok(Admission::Block),
            "reject" => Ok(Admission::Reject),
            other => anyhow::bail!("unknown admission policy `{other}` (block|reject)"),
        }
    }
}

/// A job parked on an in-flight plan, stamped so its eventual
/// `JobResult::plan_time` reports the latency it actually experienced.
#[derive(Debug)]
pub struct ParkedJob {
    pub job: GemmJob,
    pub since: Instant,
}

/// Outcome of [`FlightTable::claim_or_park`].
#[derive(Debug)]
pub enum ClaimOutcome {
    /// No flight existed: the caller now owns the claim and must send the
    /// job to a planner (and guarantee an eventual [`FlightTable::resolve`]).
    Claimed(GemmJob),
    /// An identical plan is already in flight; the job was parked on it.
    Parked,
}

/// Per-key single-flight registry. A key is "in flight" from the moment
/// a job claims it until the planner that dequeues that job resolves it;
/// the entry's vector holds every job parked on the flight meanwhile.
#[derive(Debug, Default)]
pub struct FlightTable {
    slots: Mutex<HashMap<PlanKey, Vec<ParkedJob>>>,
}

impl FlightTable {
    pub fn new() -> FlightTable {
        FlightTable::default()
    }

    /// Claim the key for `job`, or park `job` on the existing flight.
    pub fn claim_or_park(&self, key: PlanKey, job: GemmJob) -> ClaimOutcome {
        let mut slots = lock_unpoisoned(&self.slots);
        match slots.entry(key) {
            std::collections::hash_map::Entry::Occupied(mut e) => {
                e.get_mut().push(ParkedJob {
                    job,
                    since: Instant::now(),
                });
                ClaimOutcome::Parked
            }
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(Vec::new());
                ClaimOutcome::Claimed(job)
            }
        }
    }

    /// Claim the key without a job to park — the graph planner's entry
    /// point: one claim covers every same-shape node of the graph, and
    /// regular jobs submitted meanwhile park on it as usual. Returns
    /// `false` when the key is already in flight elsewhere.
    pub fn try_claim(&self, key: PlanKey) -> bool {
        let mut slots = lock_unpoisoned(&self.slots);
        match slots.entry(key) {
            std::collections::hash_map::Entry::Occupied(_) => false,
            std::collections::hash_map::Entry::Vacant(v) => {
                v.insert(Vec::new());
                true
            }
        }
    }

    /// Remove the key's flight, returning every job parked on it. Called
    /// exactly once per claim — by the planner after it resolves the plan
    /// (publish or fail), or by `submit` when the planner pool is gone.
    pub fn resolve(&self, key: &PlanKey) -> Vec<ParkedJob> {
        lock_unpoisoned(&self.slots).remove(key).unwrap_or_default()
    }

    /// Tear down every flight (shutdown backstop for waiters stranded by
    /// a dead planner). Normal shutdown resolves all flights through the
    /// planners; this returns whatever is left.
    pub fn drain_all(&self) -> Vec<ParkedJob> {
        let mut slots = lock_unpoisoned(&self.slots);
        slots.drain().flat_map(|(_, parked)| parked).collect()
    }

    /// Number of keys currently in flight.
    pub fn in_flight(&self) -> usize {
        lock_unpoisoned(&self.slots).len()
    }

    /// Number of jobs parked across all flights.
    pub fn parked(&self) -> usize {
        lock_unpoisoned(&self.slots).values().map(Vec::len).sum()
    }
}

#[derive(Debug, Default)]
struct GaugeState {
    depth: usize,
    peak: u64,
}

/// Bounded admission gauge: tracks jobs admitted but not yet finalized
/// (planner-queued, parked on a flight, or awaiting execution).
#[derive(Debug)]
pub struct QueueGauge {
    state: Mutex<GaugeState>,
    drained: Condvar,
    limit: usize,
    policy: Admission,
}

impl QueueGauge {
    pub fn new(max_queue_depth: usize, policy: Admission) -> QueueGauge {
        QueueGauge {
            state: Mutex::new(GaugeState::default()),
            drained: Condvar::new(),
            limit: max_queue_depth.max(1),
            policy,
        }
    }

    fn lock(&self) -> MutexGuard<'_, GaugeState> {
        lock_unpoisoned(&self.state)
    }

    /// Try to admit one job. `Block` waits for the planners/executor to
    /// finish admitted work (they always make progress: explorations are
    /// finite and cancellable); `Reject` returns `false` when the queue
    /// is full.
    pub fn admit(&self) -> bool {
        let mut g = self.lock();
        while g.depth >= self.limit {
            match self.policy {
                Admission::Reject => return false,
                Admission::Block => {
                    g = self.drained.wait(g).unwrap_or_else(|e| e.into_inner());
                }
            }
        }
        g.depth += 1;
        g.peak = g.peak.max(g.depth as u64);
        true
    }

    /// Mark `n` admitted jobs as finished (result finalized, refused at
    /// send, or torn down at shutdown), waking blocked submitters.
    pub fn release(&self, n: usize) {
        if n == 0 {
            return;
        }
        let mut g = self.lock();
        g.depth = g.depth.saturating_sub(n);
        drop(g);
        self.drained.notify_all();
    }

    pub fn depth(&self) -> usize {
        self.lock().depth
    }

    /// High-water mark of the queue depth since start.
    pub fn peak(&self) -> u64 {
        self.lock().peak
    }

    pub fn limit(&self) -> usize {
        self.limit
    }

    pub fn policy(&self) -> Admission {
        self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dse::Objective;
    use crate::workloads::Gemm;

    fn job(id: u64, m: usize) -> GemmJob {
        GemmJob::plan_only(id, Gemm::new(m, 64, 64), Objective::Throughput)
    }

    fn key_of(j: &GemmJob) -> PlanKey {
        PlanKey::new(j.gemm, j.objective)
    }

    #[test]
    fn first_claims_rest_park_until_resolved() {
        let table = FlightTable::new();
        let k = key_of(&job(0, 128));
        match table.claim_or_park(k, job(0, 128)) {
            ClaimOutcome::Claimed(j) => assert_eq!(j.id, 0),
            ClaimOutcome::Parked => panic!("first job must claim"),
        }
        for id in 1..4 {
            assert!(matches!(
                table.claim_or_park(k, job(id, 128)),
                ClaimOutcome::Parked
            ));
        }
        assert_eq!((table.in_flight(), table.parked()), (1, 3));
        let parked = table.resolve(&k);
        assert_eq!(parked.len(), 3);
        let ids: Vec<u64> = parked.iter().map(|p| p.job.id).collect();
        assert_eq!(ids, vec![1, 2, 3]);
        // Released: the next job claims afresh (failed plans don't poison).
        assert!(matches!(
            table.claim_or_park(k, job(9, 128)),
            ClaimOutcome::Claimed(_)
        ));
        assert!(table.resolve(&k).is_empty());
    }

    #[test]
    fn try_claim_respects_existing_flights_and_parks_later_jobs() {
        let table = FlightTable::new();
        let k = key_of(&job(0, 128));
        // Jobless claim (graph planner) wins a free key exactly once.
        assert!(table.try_claim(k));
        assert!(!table.try_claim(k), "double-claimed an in-flight key");
        // A regular submit meanwhile parks on the graph's claim.
        assert!(matches!(table.claim_or_park(k, job(1, 128)), ClaimOutcome::Parked));
        let parked = table.resolve(&k);
        assert_eq!(parked.len(), 1);
        // Resolved: claimable again; and try_claim loses to a job claim.
        let _ = table.claim_or_park(k, job(2, 128));
        assert!(!table.try_claim(k));
        let _ = table.resolve(&k);
        assert!(table.try_claim(k));
    }

    #[test]
    fn distinct_keys_fly_independently() {
        let table = FlightTable::new();
        let (a, b) = (job(0, 128), job(1, 256));
        assert!(matches!(
            table.claim_or_park(key_of(&a), a.clone()),
            ClaimOutcome::Claimed(_)
        ));
        assert!(matches!(
            table.claim_or_park(key_of(&b), b.clone()),
            ClaimOutcome::Claimed(_)
        ));
        assert_eq!(table.in_flight(), 2);
        assert_eq!(table.parked(), 0);
        let leftovers = table.drain_all();
        assert!(leftovers.is_empty());
        assert_eq!(table.in_flight(), 0);
    }

    #[test]
    fn drain_all_returns_stranded_waiters() {
        let table = FlightTable::new();
        let k = key_of(&job(0, 128));
        let _ = table.claim_or_park(k, job(0, 128));
        let _ = table.claim_or_park(k, job(1, 128));
        let _ = table.claim_or_park(k, job(2, 128));
        let stranded = table.drain_all();
        assert_eq!(stranded.len(), 2);
        assert_eq!(table.in_flight(), 0);
    }

    #[test]
    fn reject_gauge_refuses_at_capacity_and_recovers() {
        let gauge = QueueGauge::new(2, Admission::Reject);
        assert!(gauge.admit());
        assert!(gauge.admit());
        assert!(!gauge.admit(), "admitted past the depth limit");
        assert_eq!(gauge.depth(), 2);
        assert_eq!(gauge.peak(), 2);
        gauge.release(1);
        assert!(gauge.admit());
        assert_eq!(gauge.peak(), 2);
        // Zero-clamped limit still admits one at a time.
        let tiny = QueueGauge::new(0, Admission::Reject);
        assert_eq!(tiny.limit(), 1);
        assert!(tiny.admit());
        assert!(!tiny.admit());
    }

    #[test]
    fn block_gauge_waits_for_release() {
        use std::sync::Arc;
        let gauge = Arc::new(QueueGauge::new(1, Admission::Block));
        assert!(gauge.admit());
        let waiter = {
            let gauge = Arc::clone(&gauge);
            std::thread::spawn(move || gauge.admit())
        };
        // The waiter is blocked on a full queue; draining unblocks it.
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(!waiter.is_finished(), "blocked submitter returned early");
        gauge.release(1);
        assert!(waiter.join().unwrap());
        assert_eq!(gauge.depth(), 1);
    }

    #[test]
    fn release_saturates_and_peak_is_sticky() {
        let gauge = QueueGauge::new(4, Admission::Reject);
        gauge.release(3); // spurious release: no underflow
        assert_eq!(gauge.depth(), 0);
        for _ in 0..3 {
            assert!(gauge.admit());
        }
        gauge.release(3);
        assert_eq!(gauge.depth(), 0);
        assert_eq!(gauge.peak(), 3);
    }

    #[test]
    fn admission_parse_roundtrip() {
        assert_eq!(Admission::parse("block").unwrap(), Admission::Block);
        assert_eq!(Admission::parse("reject").unwrap(), Admission::Reject);
        assert!(Admission::parse("drop").is_err());
        assert_eq!(Admission::Block.label(), "block");
        assert_eq!(Admission::Reject.label(), "reject");
    }
}
