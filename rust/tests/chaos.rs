//! Chaos suite (ISSUE 9): seeded fault schedules through the resilient
//! executor. The fault injector is deterministic, so these are real
//! tests of the coordinator's guarantees under failure — exactly-once
//! completion, honest accounting, deadline kills, breaker failover —
//! not flaky approximations of them.

use std::sync::OnceLock;
use std::time::{Duration, Instant};

use versal_gemm::config::Config;
use versal_gemm::coordinator::{
    BackendChoice, Coordinator, CoordinatorOptions, CpuProfileChoice, FaultPlan, GemmJob,
};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::{DseEngine, Objective};
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::server::client::Client;
use versal_gemm::server::daemon::{Daemon, DaemonOptions, DaemonSummary};
use versal_gemm::server::protocol::JobSpec;
use versal_gemm::server::Endpoint;
use versal_gemm::util::forall;
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::{training_workloads, Gemm};

/// One shared reduced dataset + model for every test (the offline phase
/// is the expensive part; chaos happens at execution time).
fn lab() -> &'static (Config, DseEngine) {
    static LAB: OnceLock<(Config, DseEngine)> = OnceLock::new();
    LAB.get_or_init(|| {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 8;
        cfg.dataset.bottom_k = 6;
        cfg.dataset.random_k = 20;
        cfg.train.n_trees = 40;
        cfg.train.learning_rate = 0.25;
        let wl: Vec<_> = training_workloads().into_iter().take(3).collect();
        let ds = Dataset::generate(&cfg, &wl);
        let engine =
            DseEngine::new(Predictors::train(&ds, &cfg, FeatureSet::SetIAndII), &cfg.board);
        (cfg, engine)
    })
}

/// A data job with deterministic operands over a small shape alphabet
/// (execution is where faults land, so every job carries operands).
fn data_job(rng: &mut Rng, id: u64, g: Gemm) -> GemmJob {
    let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
    GemmJob::with_data(id, g, Objective::Throughput, a, b)
}

fn data_jobs(rng: &mut Rng, n: usize) -> Vec<GemmJob> {
    let shapes = [Gemm::new(64, 64, 64), Gemm::new(128, 128, 64)];
    (0..n as u64)
        .map(|i| data_job(rng, i, shapes[rng.below(shapes.len())]))
        .collect()
}

fn chaos_opts(spec: &str, retry_budget: u32) -> CoordinatorOptions {
    CoordinatorOptions {
        backend: BackendChoice::Auto, // no artifacts: the cpu -> sim chain
        cpu_profile: CpuProfileChoice::Generic,
        retry_budget,
        faults: Some(FaultPlan::parse(spec).expect("valid fault spec")),
        ..CoordinatorOptions::default()
    }
}

#[test]
fn property_fault_schedules_preserve_exactly_once_accounting() {
    let (cfg, eng) = lab();
    forall(
        0xFA57,
        4,
        |r| {
            let n = r.range_usize(4, 10);
            let seed = r.below(1000) as u64;
            (data_jobs(r, n), format!("err:p=0.3;slow:p=0.1,x=2;seed:{seed}"))
        },
        |(jobs, spec)| {
            let n = jobs.len();
            let mut coord =
                Coordinator::start_with(cfg, eng.clone(), None, 2, chaos_opts(spec, 4));
            let results = coord.run_batch(jobs.clone());
            let stats = coord.stats();
            coord.shutdown();

            // Exactly one result per submitted id, in id order.
            assert_eq!(results.len(), n, "lost or duplicated jobs");
            let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
            assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());

            // completed + failed partitions the submitted set.
            assert_eq!(
                stats.jobs_completed + stats.jobs_failed,
                n as u64,
                "accounting leak under faults: {stats:?}"
            );

            // Energy iff success: a failed execution must not book an
            // energy draw, a successful one always does (data jobs).
            for r in &results {
                let ok = r.error.is_none();
                assert_eq!(r.energy_j.is_some(), ok, "job {}: energy/success disagree", r.id);
                assert_eq!(r.exec_time.is_some(), ok);
                assert!(r.backend_used.is_some(), "job {} hides its executor", r.id);
            }
        },
    );
}

#[test]
fn same_spec_and_seed_replays_an_identical_outcome_sequence() {
    let (cfg, eng) = lab();
    // Single planner + single executor: the backend-call order is the
    // job order, so the injected schedule — and therefore every retry
    // count, error string, and failover — must replay bit-identically.
    let run = || {
        let mut rng = Rng::new(0xD1CE);
        let jobs = data_jobs(&mut rng, 8);
        let mut coord = Coordinator::start_with(
            cfg,
            eng.clone(),
            None,
            1,
            chaos_opts("err:p=0.4;seed:11", 2),
        );
        let results = coord.run_batch(jobs);
        let stats = coord.stats();
        coord.shutdown();
        let outcomes: Vec<(u64, Option<String>, u32, Option<&'static str>, bool)> = results
            .into_iter()
            .map(|r| (r.id, r.error, r.retries, r.backend_used, r.timed_out))
            .collect();
        (outcomes, stats.retries_total, stats.faults_injected, stats.failovers_total)
    };
    let first = run();
    let second = run();
    assert_eq!(first, second, "same spec+seed diverged across runs");
    assert!(first.2 > 0, "p=0.4 over 8 jobs must inject at least once");
}

#[test]
fn hang_faults_are_killed_by_the_deadline() {
    let (cfg, eng) = lab();
    let mut opts = chaos_opts("hang:p=1,ms=1500;seed:1", 1);
    opts.job_deadline_ms = Some(150);
    let mut coord = Coordinator::start_with(cfg, eng.clone(), None, 1, opts);
    // Warm the plan first: plan-only jobs never touch the backend, so
    // the timed window below measures the deadline machinery alone, not
    // a cold DSE exploration.
    let g = Gemm::new(64, 64, 64);
    let warm = coord.run_batch(vec![GemmJob::plan_only(100, g, Objective::Throughput)]);
    assert!(warm[0].error.is_none(), "warm plan failed: {:?}", warm[0].error);
    let started = Instant::now();
    let mut rng = Rng::new(3);
    let results = coord.run_batch(vec![data_job(&mut rng, 0, g)]);
    let stats = coord.stats();
    coord.shutdown();

    // Every attempt hangs 1500ms against a 150ms deadline: the watchdog
    // kills both attempts and the job fails fast — well inside the
    // injected hang duration, and with no sleep of our own.
    assert!(
        started.elapsed() < Duration::from_secs(2),
        "deadline did not bound the hang: {:?}",
        started.elapsed()
    );
    let r = &results[0];
    assert!(r.timed_out, "timeout not recorded");
    let err = r.error.as_deref().expect("hung job must fail");
    assert!(err.contains("deadline exceeded"), "untyped timeout: {err}");
    assert!(err.contains("after 1 retries"), "retry count missing: {err}");
    assert_eq!(r.retries, 1);
    assert!(r.energy_j.is_none(), "timed-out job booked energy");
    assert_eq!(stats.timeouts_total, 2, "both attempts expired");
    assert_eq!(stats.jobs_failed, 1);
}

#[test]
fn permanent_cpu_fault_trips_the_breaker_and_fails_over_to_sim() {
    let (cfg, eng) = lab();
    // Every cpu call fails permanently; sim is untouched. The first job
    // trips the cpu breaker and fails over inside its own retry loop;
    // the rest of the burst routes straight to the demoted tier.
    let mut coord = Coordinator::start_with(
        cfg,
        eng.clone(),
        None,
        2,
        chaos_opts("perm:p=1,backend=cpu;seed:2", 3),
    );
    let mut rng = Rng::new(9);
    let results = coord.run_batch(data_jobs(&mut rng, 6));
    let stats = coord.stats();
    coord.shutdown();

    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
        // backend_used is the honest executor, not the tier we started on.
        assert_eq!(r.backend_used, Some("sim"), "job {}", r.id);
        assert!(r.energy_j.is_some());
    }
    assert_eq!(stats.jobs_completed, 6);
    assert_eq!(stats.jobs_failed, 0);
    assert!(stats.failovers_total >= 1, "breaker trip never failed over: {stats:?}");
    assert!(stats.faults_injected >= 1);
    assert!(stats.breaker_state >= 1, "cpu breaker should not be Closed");
}

#[test]
fn no_faults_is_passthrough_with_zero_resilience_counters() {
    let (cfg, eng) = lab();
    let opts = CoordinatorOptions {
        backend: BackendChoice::Cpu,
        cpu_profile: CpuProfileChoice::Generic,
        ..CoordinatorOptions::default()
    };
    let mut coord = Coordinator::start_with(cfg, eng.clone(), None, 2, opts);
    let mut rng = Rng::new(17);
    let results = coord.run_batch(data_jobs(&mut rng, 5));
    let stats = coord.stats();
    coord.shutdown();

    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
        assert_eq!(r.retries, 0);
        assert!(!r.timed_out);
        assert_eq!(r.backend_used, Some("cpu"));
    }
    assert_eq!(stats.jobs_completed, 5);
    assert_eq!(stats.retries_total, 0);
    assert_eq!(stats.timeouts_total, 0);
    assert_eq!(stats.failovers_total, 0);
    assert_eq!(stats.faults_injected, 0);
    assert_eq!(stats.breaker_state, 0);
}

// ---------------------------------------------------------------------------
// Daemon under chaos
// ---------------------------------------------------------------------------

fn test_dir(name: &str) -> std::path::PathBuf {
    let dir = std::env::temp_dir().join(format!("versal-gemm-chaos-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn spawn_daemon(opts: DaemonOptions) -> std::thread::JoinHandle<anyhow::Result<DaemonSummary>> {
    let (cfg, engine) = lab();
    let daemon = Daemon::start(cfg, engine.clone(), opts).expect("daemon start");
    std::thread::spawn(move || daemon.run())
}

/// Small data-job specs for the socket path (operands inline).
fn data_specs(n: usize) -> Vec<JobSpec> {
    let mut rng = Rng::new(0x5EA);
    (0..n as u64)
        .map(|id| {
            let g = Gemm::new(64, 64, 64);
            let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
            JobSpec {
                id,
                m: g.m,
                n: g.n,
                k: g.k,
                objective: Objective::Throughput,
                validate: false,
                a: Some(a),
                b: Some(b),
            }
        })
        .collect()
}

#[test]
fn daemon_survives_a_fault_burst_then_drains_and_persists() {
    let dir = test_dir("burst");
    let mut opts = DaemonOptions::new(Endpoint::Unix(dir.join("daemon.sock")), dir.clone());
    opts.coordinator = CoordinatorOptions {
        cache_path: Some(dir.join("plan-cache.json")),
        backend: BackendChoice::Auto,
        cpu_profile: CpuProfileChoice::Generic,
        retry_budget: 5,
        job_deadline_ms: Some(10_000),
        faults: Some(FaultPlan::parse("err:p=0.5;slow:p=0.2,x=2;seed:13").expect("spec")),
        ..CoordinatorOptions::default()
    };
    opts.n_planners = 2;
    let handle = spawn_daemon(opts);
    let mut client = Client::connect_retry(
        &Endpoint::Unix(dir.join("daemon.sock")),
        Duration::from_secs(30),
    )
    .expect("connect");

    // A 12-job burst under a 50% transient fault rate: every job gets
    // exactly one RESULT frame, and the wire carries the resilience
    // triple for each (honest executor even on failure).
    let n = 12usize;
    let wire = client.submit_burst(&data_specs(n)).expect("burst under faults");
    assert_eq!(wire.len(), n);
    let ids: Vec<u64> = wire.iter().map(|r| r.id).collect();
    assert_eq!(ids, (0..n as u64).collect::<Vec<u64>>());
    let (ok, failed): (Vec<_>, Vec<_>) = wire.iter().partition(|r| r.ok());
    assert_eq!(ok.len() + failed.len(), n);
    for r in &wire {
        assert!(r.backend_used.is_some(), "job {} hides its executor", r.id);
        if !r.ok() {
            let err = r.error.as_deref().unwrap_or("");
            assert!(err.contains("retries"), "failure lost its retry count: {err}");
        }
    }

    // The injector fired and the counters reached the wire.
    let stats = client.stats().expect("stats");
    assert!(stats.get("faults_injected").unwrap_or(0.0) > 0.0, "no faults injected");
    assert!(stats.get("retries_total").is_some());
    assert!(stats.get("timeouts_total").is_some());
    assert!(stats.get("failovers_total").is_some());
    assert!(stats.get("breaker_state").is_some());
    assert_eq!(
        stats.get("jobs_completed").unwrap_or(-1.0) + stats.get("jobs_failed").unwrap_or(-1.0),
        n as f64,
        "accounting leak under faults"
    );

    // Drain still closes admission and persists the plan cache.
    let drained = client.drain().expect("drain");
    assert_eq!(drained.state, "draining");
    assert_eq!(drained.get("jobs_pending"), Some(0.0));
    assert!(dir.join("plan-cache.json").exists(), "drain did not persist the cache");

    client.shutdown().expect("shutdown");
    let summary = handle.join().unwrap().expect("daemon run");
    assert_eq!(summary.jobs_submitted, n as u64);
    assert_eq!(summary.jobs_completed + summary.jobs_failed, n as u64);
    let _ = std::fs::remove_dir_all(&dir);
}
