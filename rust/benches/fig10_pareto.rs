//! Bench: Fig. 10 — Pareto-front generation quality and hypervolume.
use versal_gemm::config::Config;
use versal_gemm::report::{figures, Lab};
use versal_gemm::util::bench::once;

fn main() -> anyhow::Result<()> {
    let lab = Lab::prepare(Config::default(), "data".into())?;
    let fig = once("fig10: ARIES vs Ours vs actual fronts (5 workloads)", || {
        figures::fig10_pareto_fronts(&lab)
    });
    println!("{fig}");
    Ok(())
}
