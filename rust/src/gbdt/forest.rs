//! Unified forest-inference engine: every tree of every model in a
//! predictor bundle, compiled into **one contiguous node arena** and
//! traversed **row-blocked**.
//!
//! The DSE hot path evaluates ~900 trees per candidate across 7 models
//! (latency + power + 5 resource outputs). Stored per-tree, each
//! traversal chases a fresh heap allocation and the row loop restarts
//! the cache cold. [`CompiledForest`] flattens all trees at compile time
//! into structure-of-arrays storage:
//!
//! ```text
//!   feature:   Vec<u16>   u16::MAX marks a leaf
//!   threshold: Vec<f64>   split threshold, or the leaf value
//!   left:      Vec<u32>   left-child index; right child is left + 1
//!                         (children are laid out adjacently, so one
//!                          packed index addresses both)
//!   tree_roots: per-tree root offsets into the arena
//!   outputs:    per-output tree ranges + (base, learning_rate)
//! ```
//!
//! Traversal processes fixed blocks of [`ROW_BLOCK`] rows: for each
//! tree, all rows of the block walk it back-to-back, so the tree's top
//! levels stay in L1/L2 across the block and the row loop is a tight,
//! branch-predictable kernel. Accumulation order per (row, output) is
//! `base + Σ lr·leaf` in tree order — **bit-identical** to the legacy
//! `Gbdt::predict_one` chain, which the equivalence property tests and
//! the debug checks in `models::Predictors` rely on.

use std::ops::Range;
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::gbdt::boost::Gbdt;
use crate::gbdt::tree::{self, FeatureMatrix};

/// Sentinel feature id marking a leaf in the arena.
const LEAF: u16 = u16::MAX;

/// Rows traversed together per block. 16 keeps the block's feature rows
/// (16 x 17 features = ~2.2 KB) and the hot top of each tree resident
/// in L1 while giving the row loop enough independent walks to overlap.
pub const ROW_BLOCK: usize = 16;

/// One model's slice of the forest.
#[derive(Debug, Clone, Copy)]
struct OutputSpec {
    tree_start: u32,
    tree_end: u32,
    base: f64,
    learning_rate: f64,
}

/// Compile-time and runtime counters of a [`CompiledForest`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ForestMetrics {
    pub n_outputs: usize,
    pub n_trees: usize,
    pub n_nodes: usize,
    /// One-time arena compilation cost.
    pub compile_ms: f64,
    /// Full-row equivalents predicted through the batched entry points
    /// since compile: a partial-range traversal (the gated DSE stages)
    /// counts as `rows x outputs_walked / n_outputs`, so gate-on and
    /// gate-off runs report comparable throughput.
    pub rows_predicted: u64,
    /// Wall-clock spent inside the batched entry points.
    pub predict_s: f64,
}

impl ForestMetrics {
    /// Inference throughput: rows per second of engine busy time
    /// (`predict_s` sums per-call wall-clock, so with N threads
    /// predicting concurrently this is per-thread, not machine-wide).
    pub fn rows_per_s(&self) -> f64 {
        if self.predict_s > 0.0 {
            self.rows_predicted as f64 / self.predict_s
        } else {
            0.0
        }
    }
}

/// All trees of one or more GBDT models in a single SoA node arena.
#[derive(Debug)]
pub struct CompiledForest {
    feature: Vec<u16>,
    threshold: Vec<f64>,
    left: Vec<u32>,
    tree_roots: Vec<u32>,
    outputs: Vec<OutputSpec>,
    compile_time: Duration,
    /// (row, output) walks through the batched entry points; metrics
    /// normalize to full-row equivalents by dividing by `n_outputs`.
    output_walks: AtomicU64,
    predict_ns: AtomicU64,
}

impl CompiledForest {
    /// Flatten `models` (one forest output per model, in order) into a
    /// fresh arena. O(total nodes); recompiled whenever the owning
    /// bundle retrains or reloads from JSON.
    pub fn compile(models: &[&Gbdt]) -> CompiledForest {
        assert!(!models.is_empty(), "cannot compile an empty forest");
        let started = Instant::now();
        let n_nodes: usize = models
            .iter()
            .flat_map(|m| m.trees.iter())
            .map(|t| t.n_nodes())
            .sum();
        let n_trees: usize = models.iter().map(|m| m.trees.len()).sum();
        let mut forest = CompiledForest {
            feature: Vec::with_capacity(n_nodes),
            threshold: Vec::with_capacity(n_nodes),
            left: Vec::with_capacity(n_nodes),
            tree_roots: Vec::with_capacity(n_trees),
            outputs: Vec::with_capacity(models.len()),
            compile_time: Duration::default(),
            output_walks: AtomicU64::new(0),
            predict_ns: AtomicU64::new(0),
        };
        for m in models {
            let tree_start = forest.tree_roots.len() as u32;
            for t in &m.trees {
                let root = forest.flatten_tree(t.flat_nodes());
                forest.tree_roots.push(root);
            }
            forest.outputs.push(OutputSpec {
                tree_start,
                tree_end: forest.tree_roots.len() as u32,
                base: m.base,
                learning_rate: m.learning_rate,
            });
        }
        forest.compile_time = started.elapsed();
        forest
    }

    /// Single-model convenience (CV fold scoring, batch baselines).
    pub fn compile_single(model: &Gbdt) -> CompiledForest {
        CompiledForest::compile(&[model])
    }

    /// BFS re-layout of one tree into the shared arena so that every
    /// split's children occupy adjacent slots (right = left + 1).
    fn flatten_tree(&mut self, nodes: &[tree::FlatNode]) -> u32 {
        let root = self.push_placeholder();
        let mut queue = std::collections::VecDeque::with_capacity(nodes.len());
        queue.push_back((0usize, root as usize));
        while let Some((old, new)) = queue.pop_front() {
            let n = nodes[old];
            if n.feature == tree::LEAF {
                self.feature[new] = LEAF;
                self.threshold[new] = n.threshold;
            } else {
                assert!(
                    n.feature < LEAF as u32,
                    "feature id {} overflows the u16 arena encoding",
                    n.feature
                );
                let left_new = self.push_placeholder();
                let right_new = self.push_placeholder();
                debug_assert_eq!(right_new, left_new + 1);
                self.feature[new] = n.feature as u16;
                self.threshold[new] = n.threshold;
                self.left[new] = left_new;
                queue.push_back((n.left as usize, left_new as usize));
                queue.push_back((n.right as usize, right_new as usize));
            }
        }
        root
    }

    fn push_placeholder(&mut self) -> u32 {
        let id = self.feature.len() as u32;
        self.feature.push(LEAF);
        self.threshold.push(0.0);
        self.left.push(0);
        id
    }

    pub fn n_outputs(&self) -> usize {
        self.outputs.len()
    }

    pub fn n_trees(&self) -> usize {
        self.tree_roots.len()
    }

    pub fn n_nodes(&self) -> usize {
        self.feature.len()
    }

    pub fn compile_time(&self) -> Duration {
        self.compile_time
    }

    pub fn metrics(&self) -> ForestMetrics {
        ForestMetrics {
            n_outputs: self.n_outputs(),
            n_trees: self.n_trees(),
            n_nodes: self.n_nodes(),
            compile_ms: self.compile_time.as_secs_f64() * 1e3,
            rows_predicted: self.output_walks.load(Ordering::Relaxed) / self.outputs.len() as u64,
            predict_s: self.predict_ns.load(Ordering::Relaxed) as f64 / 1e9,
        }
    }

    /// Walk one tree for one row. NaN features compare false and take
    /// the right branch, matching `RegressionTree::predict_one`.
    #[inline(always)]
    fn traverse(&self, mut node: usize, row: &[f64]) -> f64 {
        loop {
            let f = self.feature[node];
            if f == LEAF {
                return self.threshold[node];
            }
            let go_right = !(row[f as usize] <= self.threshold[node]);
            node = self.left[node] as usize + go_right as usize;
        }
    }

    /// Predict every output for every row of a flat row-major feature
    /// buffer (`rows.len() == n_rows * n_feat`). `out` is resized to
    /// `n_rows * n_outputs`, row-major. The hot entry of the DSE.
    pub fn predict_rows(&self, rows: &[f64], n_feat: usize, out: &mut Vec<f64>) {
        self.predict_outputs(rows, n_feat, 0..self.outputs.len(), out);
    }

    /// Predict a contiguous `outputs` range for every row of a flat
    /// row-major feature buffer. `out` is resized to `n_rows *
    /// outputs.len()`, row-major in range order, and each (row, output)
    /// value is bit-identical to the corresponding column of the full
    /// [`CompiledForest::predict_rows`] traversal (per-output tree walks
    /// are independent, so restricting the range never changes the
    /// accumulation order within an output). The resource-gated DSE path
    /// predicts the 𝓡 range for every candidate and the 𝓛/𝓟 range only
    /// for rows that survive the fits() filter.
    pub fn predict_outputs(
        &self,
        rows: &[f64],
        n_feat: usize,
        outputs: Range<usize>,
        out: &mut Vec<f64>,
    ) {
        assert!(n_feat > 0 && rows.len() % n_feat == 0, "ragged row buffer");
        assert!(outputs.end <= self.outputs.len(), "output range out of bounds");
        let started = Instant::now();
        let n_rows = rows.len() / n_feat;
        let n_out = outputs.len();
        out.clear();
        out.resize(n_rows * n_out, 0.0);
        if n_out == 0 {
            return;
        }
        let mut r0 = 0usize;
        while r0 < n_rows {
            let r1 = (r0 + ROW_BLOCK).min(n_rows);
            self.predict_block(
                &rows[r0 * n_feat..r1 * n_feat],
                n_feat,
                outputs.clone(),
                &mut out[r0 * n_out..r1 * n_out],
            );
            r0 = r1;
        }
        self.output_walks
            .fetch_add((n_rows * n_out) as u64, Ordering::Relaxed);
        self.predict_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
    }

    /// Row-blocked kernel over one block (`rows.len() / n_feat <=
    /// ROW_BLOCK` rows) restricted to the `outputs` range: for each
    /// tree, every row of the block walks it back-to-back so node data
    /// stays hot across the row loop.
    fn predict_block(&self, rows: &[f64], n_feat: usize, outputs: Range<usize>, out: &mut [f64]) {
        let n_rows = rows.len() / n_feat;
        let specs = &self.outputs[outputs];
        let n_out = specs.len();
        debug_assert_eq!(out.len(), n_rows * n_out);
        for r in 0..n_rows {
            for (o, spec) in specs.iter().enumerate() {
                out[r * n_out + o] = spec.base;
            }
        }
        for (o, spec) in specs.iter().enumerate() {
            let lr = spec.learning_rate;
            for t in spec.tree_start..spec.tree_end {
                let root = self.tree_roots[t as usize] as usize;
                for r in 0..n_rows {
                    let row = &rows[r * n_feat..(r + 1) * n_feat];
                    out[r * n_out + o] += lr * self.traverse(root, row);
                }
            }
        }
    }

    /// Predict every output for a single row (`out.len() == n_outputs`).
    pub fn predict_row_into(&self, row: &[f64], out: &mut [f64]) {
        assert!(!row.is_empty());
        self.predict_block(row, row.len(), 0..self.outputs.len(), out);
    }

    /// Row-blocked traversal of a single output's trees over a feature
    /// matrix — the latency-only / power-only batch paths and CV fold
    /// scoring, which would waste 6/7 of the full-bundle walk.
    pub fn predict_output(&self, output: usize, x: &FeatureMatrix) -> Vec<f64> {
        let started = Instant::now();
        let spec = self.outputs[output];
        let mut out = vec![spec.base; x.n_rows];
        let mut r0 = 0usize;
        while r0 < x.n_rows {
            let r1 = (r0 + ROW_BLOCK).min(x.n_rows);
            for t in spec.tree_start..spec.tree_end {
                let root = self.tree_roots[t as usize] as usize;
                for (r, slot) in out[r0..r1].iter_mut().enumerate() {
                    *slot += spec.learning_rate * self.traverse(root, x.row(r0 + r));
                }
            }
            r0 = r1;
        }
        self.output_walks.fetch_add(x.n_rows as u64, Ordering::Relaxed);
        self.predict_ns
            .fetch_add(started.elapsed().as_nanos() as u64, Ordering::Relaxed);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::TrainConfig;
    use crate::util::forall;
    use crate::util::rng::Rng;

    fn synth(n: usize, n_feat: usize, rng: &mut Rng) -> (FeatureMatrix, Vec<f64>) {
        let mut rows = Vec::with_capacity(n);
        let mut y = Vec::with_capacity(n);
        for _ in 0..n {
            let row: Vec<f64> = (0..n_feat).map(|_| rng.range_f64(-5.0, 5.0)).collect();
            let target = row.iter().enumerate().map(|(j, v)| v * (j as f64 + 1.0)).sum::<f64>()
                + (row[0] * row[n_feat - 1]).sin();
            rows.push(row);
            y.push(target);
        }
        (FeatureMatrix::from_rows(&rows), y)
    }

    fn fit_random(rng: &mut Rng) -> (Gbdt, FeatureMatrix) {
        let n_feat = 2 + rng.below(4);
        let (x, y) = synth(40 + rng.below(120), n_feat, rng);
        let cfg = TrainConfig {
            n_trees: 5 + rng.below(40),
            max_depth: 2 + rng.below(5),
            learning_rate: rng.range_f64(0.05, 0.4),
            min_samples_leaf: 1 + rng.below(4),
            subsample: rng.range_f64(0.6, 1.0),
            colsample: rng.range_f64(0.6, 1.0),
            lambda: rng.range_f64(0.0, 3.0),
            ..TrainConfig::default()
        };
        let model = Gbdt::fit(&x, &y, &cfg, None, &mut rng.fork(7));
        (model, x)
    }

    #[test]
    fn forest_bit_matches_predict_one_property() {
        // Property: over randomly-fitted ensembles and random rows, the
        // compiled arena returns *bit-identical* values to the legacy
        // per-tree traversal.
        forall(
            0xF0_5E57,
            12,
            fit_random,
            |(model, x)| {
                let forest = CompiledForest::compile_single(model);
                assert_eq!(forest.n_trees(), model.n_trees());
                let batched = forest.predict_output(0, x);
                for i in 0..x.n_rows {
                    let want = model.predict_one(x.row(i));
                    assert_eq!(batched[i], want, "row {i} diverged");
                }
            },
        );
    }

    #[test]
    fn multi_output_forest_matches_each_model() {
        let mut rng = Rng::new(41);
        let (m0, x) = fit_random(&mut rng);
        // Second model over the same feature space.
        let y2: Vec<f64> = (0..x.n_rows).map(|i| x.get(i, 0) * 3.0 - 1.0).collect();
        let cfg = TrainConfig {
            n_trees: 30,
            learning_rate: 0.2,
            ..TrainConfig::default()
        };
        let m1 = Gbdt::fit(&x, &y2, &cfg, None, &mut Rng::new(5));
        let forest = CompiledForest::compile(&[&m0, &m1]);
        assert_eq!(forest.n_outputs(), 2);
        assert_eq!(forest.n_trees(), m0.n_trees() + m1.n_trees());

        let mut out = Vec::new();
        forest.predict_rows(&x.data, x.n_cols, &mut out);
        assert_eq!(out.len(), x.n_rows * 2);
        for i in 0..x.n_rows {
            assert_eq!(out[i * 2], m0.predict_one(x.row(i)));
            assert_eq!(out[i * 2 + 1], m1.predict_one(x.row(i)));
        }

        // Single-row entry agrees with the batched one.
        let mut single = [0.0; 2];
        forest.predict_row_into(x.row(3), &mut single);
        assert_eq!(single[0], out[6]);
        assert_eq!(single[1], out[7]);
    }

    #[test]
    fn output_range_traversal_matches_full_prediction() {
        // `predict_outputs` over any contiguous subrange must reproduce
        // the corresponding columns of the full traversal bit-exactly —
        // the invariant the two-stage gated DSE path leans on.
        let mut rng = Rng::new(71);
        let (m0, x) = fit_random(&mut rng);
        let y1: Vec<f64> = (0..x.n_rows).map(|i| x.get(i, 0) * 2.0 + 0.5).collect();
        let y2: Vec<f64> = (0..x.n_rows).map(|i| x.get(i, 0) - 1.5).collect();
        let cfg = TrainConfig {
            n_trees: 25,
            learning_rate: 0.2,
            ..TrainConfig::default()
        };
        let m1 = Gbdt::fit(&x, &y1, &cfg, None, &mut Rng::new(11));
        let m2 = Gbdt::fit(&x, &y2, &cfg, None, &mut Rng::new(13));
        let forest = CompiledForest::compile(&[&m0, &m1, &m2]);
        let mut full = Vec::new();
        forest.predict_rows(&x.data, x.n_cols, &mut full);
        assert_eq!(full.len(), x.n_rows * 3);
        for (lo, hi) in [(0, 3), (0, 1), (0, 2), (1, 3), (2, 3), (1, 2), (0, 0), (3, 3)] {
            let mut part = Vec::new();
            forest.predict_outputs(&x.data, x.n_cols, lo..hi, &mut part);
            let w = hi - lo;
            assert_eq!(part.len(), x.n_rows * w, "range {lo}..{hi}");
            for r in 0..x.n_rows {
                for o in 0..w {
                    assert_eq!(
                        part[r * w + o],
                        full[r * 3 + lo + o],
                        "row {r} output {} via range {lo}..{hi}",
                        lo + o
                    );
                }
            }
        }
    }

    #[test]
    fn json_roundtrip_recompiles_to_identical_predictions() {
        let mut rng = Rng::new(77);
        let (model, x) = fit_random(&mut rng);
        let before = CompiledForest::compile_single(&model).predict_output(0, &x);
        let back = Gbdt::from_json(&model.to_json()).unwrap();
        let after = CompiledForest::compile_single(&back).predict_output(0, &x);
        assert_eq!(before, after);
    }

    #[test]
    fn block_boundaries_do_not_change_results() {
        // n_rows not a multiple of ROW_BLOCK exercises the tail block.
        let mut rng = Rng::new(99);
        let (model, x) = fit_random(&mut rng);
        let forest = CompiledForest::compile_single(&model);
        for take in [1usize, ROW_BLOCK - 1, ROW_BLOCK, ROW_BLOCK + 3] {
            let take = take.min(x.n_rows);
            let sub = FeatureMatrix {
                data: x.data[..take * x.n_cols].to_vec(),
                n_rows: take,
                n_cols: x.n_cols,
            };
            let got = forest.predict_output(0, &sub);
            for i in 0..take {
                assert_eq!(got[i], model.predict_one(x.row(i)));
            }
        }
    }

    #[test]
    fn nan_rows_traverse_right_like_the_legacy_path() {
        let mut rng = Rng::new(123);
        let (model, x) = fit_random(&mut rng);
        let forest = CompiledForest::compile_single(&model);
        let mut row = x.row(0).to_vec();
        row[0] = f64::NAN;
        let mut out = [0.0];
        forest.predict_row_into(&row, &mut out);
        assert_eq!(out[0], model.predict_one(&row));
        assert!(out[0].is_finite());
    }

    #[test]
    fn metrics_count_compile_and_rows() {
        let mut rng = Rng::new(55);
        let (model, x) = fit_random(&mut rng);
        let forest = CompiledForest::compile_single(&model);
        let m0 = forest.metrics();
        assert_eq!(m0.rows_predicted, 0);
        assert!(m0.n_nodes > 0 && m0.n_trees > 0 && m0.n_outputs == 1);
        let _ = forest.predict_output(0, &x);
        let m1 = forest.metrics();
        assert_eq!(m1.rows_predicted, x.n_rows as u64);
        assert!(m1.rows_per_s() >= 0.0);
    }
}
