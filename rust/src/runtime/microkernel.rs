//! GotoBLAS2-style packed-panel GEMM core for the CPU backend
//! (DESIGN.md §3): cache-blocked packing plus a fixed-size
//! autovectorizable microkernel.
//!
//! The three-level pipeline (arXiv:2404.15043, the paper's own SOTA
//! baseline) decomposes `C[m,n] += A[m,k] @ B[k,n]` as
//!
//! ```text
//! for jc in 0..n  step NC          # B column block   → L3
//!   for pc in 0..k  step KC        # pack B[pc:, jc:] → KC×NC panel
//!     for ic in 0..m  step MC      # pack A[ic:, pc:] → MC×KC panel (L2)
//!       for jr in 0..NC step NR    #   B sliver: KC×NR (streams from L3)
//!         for ir in 0..MC step MR  #   A sliver: MR×KC (hot in L2)
//!           microkernel: MR×NR register tile over KC
//! ```
//!
//! Both panels are repacked into *microkernel order*: the A panel as
//! MR-row slivers (for each `k`, the MR column values are adjacent) and
//! the B panel as NR-column slivers (for each `k`, the NR row values
//! are adjacent), so the inner loop reads both operands with stride 1
//! regardless of the original matrix shapes. Ragged M/N edges are
//! zero-padded at pack time into full MR/NR slivers — the microkernel
//! always computes a full register tile and a masked tail write-back
//! discards the padded lanes, which keeps the floating-point reduction
//! order identical for interior and edge tiles (bit-controlled output).
//! K is never padded: the reduction loop runs exactly `kc_eff` steps.
//!
//! ## Autovectorization contract
//!
//! The microkernel promises rustc/LLVM exactly the shape they
//! auto-vectorize on stable: a `[[f32; NR]; MR]` accumulator whose
//! inner loops have compile-time trip counts (MR = NR = 8), operands
//! delivered through `chunks_exact` so every slice has a
//! length known to the optimizer (no bounds checks survive), and no
//! data-dependent branches in the loop body (the legacy kernel's
//! `if av == 0.0 { continue }` defeated SIMD). Each `acc[i][j] += ai *
//! b[j]` row update lowers to f32x8 fused multiply-adds on any x86-64
//! target with AVX/FMA and to 2×f32x4 on baseline SSE2/NEON.
//!
//! Blocking parameters live in [`KernelProfile`] — selectable per
//! backend via `--cpu-profile` (see [`CpuProfileChoice`]), with `auto`
//! probing the L2 size once at startup.

use std::cell::RefCell;
use std::sync::OnceLock;

use anyhow::{bail, Result};

/// Microkernel register-tile rows. Fixed at compile time: the
/// accumulator array shape is what makes the kernel autovectorize.
pub const MR: usize = 8;
/// Microkernel register-tile columns (one f32x8 vector per row).
pub const NR: usize = 8;

/// Cache-blocking parameters for the packed-panel pipeline. MR/NR are
/// compile-time constants (the register tile is baked into the
/// microkernel); MC/KC/NC select how much of each operand stays
/// resident per cache level:
///
/// * `kc × NR` B sliver — L1-resident, streamed per microkernel call;
/// * `mc × kc` packed A panel — L2-resident (the profile's knob);
/// * `kc × nc` packed B panel — L3-resident.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KernelProfile {
    /// Stable identifier surfaced in stats and the serve summary.
    pub name: &'static str,
    /// Register-tile rows (= [`MR`]; kept in the profile for display).
    pub mr: usize,
    /// Register-tile columns (= [`NR`]).
    pub nr: usize,
    /// A-panel rows per pack (multiple of MR).
    pub mc: usize,
    /// Reduction depth per packed panel pair.
    pub kc: usize,
    /// B-panel columns per pack (multiple of NR).
    pub nc: usize,
}

impl KernelProfile {
    /// Middle-of-the-road blocking: 128 KiB A panel, 4 MiB B panel —
    /// safe on any core with ≥256 KiB of private L2.
    pub fn generic() -> KernelProfile {
        KernelProfile {
            name: "generic",
            mr: MR,
            nr: NR,
            mc: 128,
            kc: 256,
            nc: 4096,
        }
    }

    /// Small-L2 cores (≤256 KiB): 32 KiB A panel, 1 MiB B panel.
    pub fn l2_small() -> KernelProfile {
        KernelProfile {
            name: "l2-small",
            mr: MR,
            nr: NR,
            mc: 64,
            kc: 128,
            nc: 2048,
        }
    }

    /// Big-L2 cores (≥1 MiB): 512 KiB A panel, 8 MiB B panel.
    pub fn l2_large() -> KernelProfile {
        KernelProfile {
            name: "l2-large",
            mr: MR,
            nr: NR,
            mc: 256,
            kc: 512,
            nc: 4096,
        }
    }

    /// Probe the per-core L2 size once (process-wide) and pick the
    /// matching profile; unreadable/absent sysfs falls back to
    /// [`KernelProfile::generic`]. The result is cached in a
    /// `OnceLock`, so `auto` costs one sysfs read per process.
    pub fn detect() -> KernelProfile {
        static DETECTED: OnceLock<KernelProfile> = OnceLock::new();
        *DETECTED.get_or_init(|| match probe_l2_bytes() {
            Some(bytes) if bytes >= 1024 * 1024 => KernelProfile::l2_large(),
            Some(bytes) if bytes <= 256 * 1024 => KernelProfile::l2_small(),
            _ => KernelProfile::generic(),
        })
    }
}

/// Per-core L2 data/unified cache size from Linux sysfs, `None` when
/// the hierarchy is unreadable (non-Linux, restricted container).
fn probe_l2_bytes() -> Option<usize> {
    for idx in 0..8 {
        let base = format!("/sys/devices/system/cpu/cpu0/cache/index{idx}");
        let Ok(level) = std::fs::read_to_string(format!("{base}/level")) else {
            continue;
        };
        if level.trim() != "2" {
            continue;
        }
        if let Ok(ty) = std::fs::read_to_string(format!("{base}/type")) {
            if ty.trim() == "Instruction" {
                continue;
            }
        }
        let size = std::fs::read_to_string(format!("{base}/size")).ok()?;
        return parse_cache_size(&size);
    }
    None
}

/// Parse sysfs cache sizes like `512K` / `1024K` / `2M` into bytes.
fn parse_cache_size(text: &str) -> Option<usize> {
    let t = text.trim();
    let (digits, mult) = match t.as_bytes().last()? {
        b'K' | b'k' => (&t[..t.len() - 1], 1024usize),
        b'M' | b'm' => (&t[..t.len() - 1], 1024 * 1024),
        _ => (t, 1),
    };
    digits.parse::<usize>().ok().map(|v| v.saturating_mul(mult))
}

/// Which [`KernelProfile`] the CPU backend runs
/// (`CoordinatorOptions::cpu_profile`, `serve --cpu-profile`).
/// Precedence: an explicit named profile always wins; `auto` (the
/// default) defers to the one-time L2 probe in
/// [`KernelProfile::detect`], which itself falls back to `generic`
/// when the cache hierarchy is unreadable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum CpuProfileChoice {
    Generic,
    L2Small,
    L2Large,
    /// Probe L2 size once at startup, then behave like the named
    /// profile it resolved to.
    #[default]
    Auto,
}

impl CpuProfileChoice {
    pub fn parse(text: &str) -> Result<CpuProfileChoice> {
        match text {
            "generic" => Ok(CpuProfileChoice::Generic),
            "l2-small" => Ok(CpuProfileChoice::L2Small),
            "l2-large" => Ok(CpuProfileChoice::L2Large),
            "auto" => Ok(CpuProfileChoice::Auto),
            other => bail!("unknown cpu profile `{other}` (generic|l2-small|l2-large|auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            CpuProfileChoice::Generic => "generic",
            CpuProfileChoice::L2Small => "l2-small",
            CpuProfileChoice::L2Large => "l2-large",
            CpuProfileChoice::Auto => "auto",
        }
    }

    /// The concrete blocking this choice runs with.
    pub fn resolve(&self) -> KernelProfile {
        match self {
            CpuProfileChoice::Generic => KernelProfile::generic(),
            CpuProfileChoice::L2Small => KernelProfile::l2_small(),
            CpuProfileChoice::L2Large => KernelProfile::l2_large(),
            CpuProfileChoice::Auto => KernelProfile::detect(),
        }
    }
}

// ---------------------------------------------------------------------------
// packing

thread_local! {
    /// Per-thread packed A panel, reused across panels/jobs for the
    /// thread's lifetime — pool workers and the executor thread each
    /// own one, so the hot path allocates nothing after warm-up.
    static A_PANEL: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Per-thread packed B panel. A *separate* TLS cell from the A
    /// panel on purpose: the fan-out path holds the caller's B borrow
    /// across `run_scoped` while each worker (possibly the same
    /// thread) borrows its own A scratch.
    static B_PANEL: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
}

/// Run `f` with this thread's reusable A-panel buffer.
pub fn with_a_panel<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    A_PANEL.with(|cell| f(&mut cell.borrow_mut()))
}

/// Run `f` with this thread's reusable B-panel buffer.
pub fn with_b_panel<R>(f: impl FnOnce(&mut Vec<f32>) -> R) -> R {
    B_PANEL.with(|cell| f(&mut cell.borrow_mut()))
}

/// Pack the `mc_eff × kc_eff` block of row-major `a` (`m×k_dim`, top
/// left at `(ic, pc)`) into MR-row slivers: sliver `s` stores, for each
/// reduction step `kk`, the MR adjacent values `A[ic + s·MR + r][pc +
/// kk]`. Rows past `mc_eff` are zero-filled so every sliver is full
/// height.
pub fn pack_a(
    a: &[f32],
    k_dim: usize,
    ic: usize,
    pc: usize,
    mc_eff: usize,
    kc_eff: usize,
    out: &mut Vec<f32>,
) {
    let slivers = mc_eff.div_ceil(MR);
    out.clear();
    out.resize(slivers * MR * kc_eff, 0.0);
    for s in 0..slivers {
        let sliver = &mut out[s * MR * kc_eff..(s + 1) * MR * kc_eff];
        let rows = MR.min(mc_eff - s * MR);
        for r in 0..rows {
            let row = ic + s * MR + r;
            let src = &a[row * k_dim + pc..row * k_dim + pc + kc_eff];
            for (kk, &v) in src.iter().enumerate() {
                sliver[kk * MR + r] = v;
            }
        }
    }
}

/// Pack the `kc_eff × nc_eff` block of row-major `b` (`k×n_dim`, top
/// left at `(pc, jc)`) into NR-column slivers: sliver `s` stores, for
/// each reduction step `kk`, the NR adjacent values `B[pc + kk][jc +
/// s·NR + c]`. Columns past `nc_eff` are zero-filled.
pub fn pack_b(
    b: &[f32],
    n_dim: usize,
    pc: usize,
    jc: usize,
    kc_eff: usize,
    nc_eff: usize,
    out: &mut Vec<f32>,
) {
    let slivers = nc_eff.div_ceil(NR);
    out.clear();
    out.resize(slivers * NR * kc_eff, 0.0);
    for kk in 0..kc_eff {
        let row = pc + kk;
        let src = &b[row * n_dim + jc..row * n_dim + jc + nc_eff];
        for s in 0..slivers {
            let cols = NR.min(nc_eff - s * NR);
            let dst = &mut out[s * NR * kc_eff + kk * NR..][..cols];
            dst.copy_from_slice(&src[s * NR..s * NR + cols]);
        }
    }
}

// ---------------------------------------------------------------------------
// microkernel

/// The register-tile reduction shared by the interior and tail paths:
/// `MR×NR` accumulator over `kc` steps of packed slivers. See the
/// module docs for the autovectorization contract this body upholds.
#[inline(always)]
fn accumulate(kc: usize, ap: &[f32], bp: &[f32]) -> [[f32; NR]; MR] {
    let mut acc = [[0.0f32; NR]; MR];
    let a_steps = ap[..kc * MR].chunks_exact(MR);
    let b_steps = bp[..kc * NR].chunks_exact(NR);
    for (a, b) in a_steps.zip(b_steps) {
        for i in 0..MR {
            let ai = a[i];
            let row = &mut acc[i];
            for j in 0..NR {
                row[j] += ai * b[j];
            }
        }
    }
    acc
}

/// Interior microkernel: `C[0..MR][0..NR] += Ap · Bp` where `c` points
/// at the tile's top-left element and rows are `ldc` apart. `ap`/`bp`
/// are one packed A/B sliver (`kc×MR` / `kc×NR`).
#[inline]
pub fn microkernel(kc: usize, ap: &[f32], bp: &[f32], c: &mut [f32], ldc: usize) {
    let acc = accumulate(kc, ap, bp);
    for (i, acc_row) in acc.iter().enumerate() {
        let row = &mut c[i * ldc..i * ldc + NR];
        for j in 0..NR {
            row[j] += acc_row[j];
        }
    }
}

/// Masked tail microkernel for ragged M/N edges: the reduction is the
/// *same* full-tile `accumulate` (padded lanes hold zeros from pack
/// time), only the write-back is masked to the valid `mr_eff × nr_eff`
/// region — identical rounding to the interior path, bit-controlled
/// output.
#[inline]
pub fn microkernel_tail(
    kc: usize,
    ap: &[f32],
    bp: &[f32],
    c: &mut [f32],
    ldc: usize,
    mr_eff: usize,
    nr_eff: usize,
) {
    let acc = accumulate(kc, ap, bp);
    for (i, acc_row) in acc.iter().enumerate().take(mr_eff) {
        let row = &mut c[i * ldc..i * ldc + nr_eff];
        for (cv, av) in row.iter_mut().zip(acc_row) {
            *cv += av;
        }
    }
}

/// Multiply one packed A panel (`mc_eff×kc_eff`) by one packed B panel
/// (`kc_eff×nc_eff`), accumulating into the C block whose top-left
/// element is `c[col0]`; `c` must cover `mc_eff` rows of stride `ldc`.
/// Loop order jr→ir keeps each B sliver hot while the A panel streams
/// from L2.
#[allow(clippy::too_many_arguments)]
pub fn packed_block(
    apanel: &[f32],
    bpanel: &[f32],
    kc_eff: usize,
    mc_eff: usize,
    nc_eff: usize,
    c: &mut [f32],
    ldc: usize,
    col0: usize,
) {
    debug_assert!(col0 + nc_eff <= ldc);
    debug_assert!(c.len() >= mc_eff * ldc);
    let m_slivers = mc_eff.div_ceil(MR);
    let n_slivers = nc_eff.div_ceil(NR);
    for js in 0..n_slivers {
        let bp = &bpanel[js * NR * kc_eff..(js + 1) * NR * kc_eff];
        let nr_eff = NR.min(nc_eff - js * NR);
        for is in 0..m_slivers {
            let ap = &apanel[is * MR * kc_eff..(is + 1) * MR * kc_eff];
            let mr_eff = MR.min(mc_eff - is * MR);
            let c0 = is * MR * ldc + col0 + js * NR;
            if mr_eff == MR && nr_eff == NR {
                microkernel(kc_eff, ap, bp, &mut c[c0..], ldc);
            } else {
                microkernel_tail(kc_eff, ap, bp, &mut c[c0..], ldc, mr_eff, nr_eff);
            }
        }
    }
}

/// Serial three-level packed GEMM: `c += a @ b` for row-major f32
/// operands (callers pass a zeroed `c` for a plain product). This is
/// both the single-thread path of `CpuBackend` and the per-(jc,pc)
/// body its pool fan-out distributes — the (jc, pc, ic) decomposition
/// is a pure function of the shape and profile, so serial and fanned
/// executions produce bit-identical output.
pub fn packed_gemm_serial(
    p: &KernelProfile,
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    c: &mut [f32],
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    for jc in (0..n).step_by(p.nc) {
        let nc_eff = p.nc.min(n - jc);
        for pc in (0..k).step_by(p.kc) {
            let kc_eff = p.kc.min(k - pc);
            with_b_panel(|bbuf| {
                pack_b(b, n, pc, jc, kc_eff, nc_eff, bbuf);
                for ic in (0..m).step_by(p.mc) {
                    let mc_eff = p.mc.min(m - ic);
                    with_a_panel(|abuf| {
                        pack_a(a, k, ic, pc, mc_eff, kc_eff, abuf);
                        packed_block(
                            abuf,
                            bbuf,
                            kc_eff,
                            mc_eff,
                            nc_eff,
                            &mut c[ic * n..(ic + mc_eff) * n],
                            n,
                            jc,
                        );
                    });
                }
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{matmul_ref, max_abs_diff};
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    fn packed(p: &KernelProfile, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut c = vec![0.0f32; m * n];
        packed_gemm_serial(p, a, b, m, n, k, &mut c);
        c
    }

    #[test]
    fn profiles_are_mr_nr_aligned_and_distinct() {
        let all = [
            KernelProfile::generic(),
            KernelProfile::l2_small(),
            KernelProfile::l2_large(),
        ];
        for p in &all {
            assert_eq!(p.mr, MR);
            assert_eq!(p.nr, NR);
            assert_eq!(p.mc % MR, 0, "{}: MC must be a multiple of MR", p.name);
            assert_eq!(p.nc % NR, 0, "{}: NC must be a multiple of NR", p.name);
            assert!(p.kc > 0);
        }
        assert_ne!(all[0], all[1]);
        assert_ne!(all[1], all[2]);
    }

    #[test]
    fn profile_choice_parses_and_resolves() {
        for (text, label) in [
            ("generic", "generic"),
            ("l2-small", "l2-small"),
            ("l2-large", "l2-large"),
            ("auto", "auto"),
        ] {
            let c = CpuProfileChoice::parse(text).unwrap();
            assert_eq!(c.label(), label);
        }
        assert!(CpuProfileChoice::parse("huge").is_err());
        assert_eq!(CpuProfileChoice::default(), CpuProfileChoice::Auto);
        // Auto resolves to one of the three named profiles on any host.
        let auto = CpuProfileChoice::Auto.resolve();
        assert!(["generic", "l2-small", "l2-large"].contains(&auto.name));
        // And resolves identically on repeat calls (OnceLock).
        assert_eq!(auto, CpuProfileChoice::Auto.resolve());
    }

    #[test]
    fn cache_size_parsing() {
        assert_eq!(parse_cache_size("512K\n"), Some(512 * 1024));
        assert_eq!(parse_cache_size("1024K"), Some(1024 * 1024));
        assert_eq!(parse_cache_size("2M"), Some(2 * 1024 * 1024));
        assert_eq!(parse_cache_size("65536"), Some(65536));
        assert_eq!(parse_cache_size("junk"), None);
    }

    #[test]
    fn pack_a_layout_and_padding() {
        // 3×2 block of a 4×5 matrix at (1,2): one MR sliver, rows 3..MR
        // zero-padded, per-k values adjacent.
        let a: Vec<f32> = (0..20).map(|v| v as f32).collect();
        let mut out = Vec::new();
        pack_a(&a, 5, 1, 2, 3, 2, &mut out);
        assert_eq!(out.len(), MR * 2);
        for kk in 0..2 {
            for r in 0..MR {
                let want = if r < 3 { a[(1 + r) * 5 + 2 + kk] } else { 0.0 };
                assert_eq!(out[kk * MR + r], want, "kk={kk} r={r}");
            }
        }
    }

    #[test]
    fn pack_b_layout_and_padding() {
        // 2×10 block of a 3×12 matrix at (1,1): two NR slivers, cols
        // 10.. zero-padded in the second sliver.
        let b: Vec<f32> = (0..36).map(|v| v as f32).collect();
        let mut out = Vec::new();
        pack_b(&b, 12, 1, 1, 2, 10, &mut out);
        assert_eq!(out.len(), 2 * NR * 2);
        for kk in 0..2 {
            for c in 0..2 * NR {
                let s = c / NR;
                let want = if c < 10 { b[(1 + kk) * 12 + 1 + c] } else { 0.0 };
                assert_eq!(out[s * NR * 2 + kk * NR + (c % NR)], want, "kk={kk} c={c}");
            }
        }
    }

    #[test]
    fn microkernel_matches_scalar_tile() {
        let mut rng = Rng::new(7);
        let kc = 17;
        let ap = randn(&mut rng, kc * MR);
        let bp = randn(&mut rng, kc * NR);
        let ldc = NR + 3;
        let mut c = vec![0.5f32; MR * ldc];
        let before = c.clone();
        microkernel(kc, &ap, &bp, &mut c, ldc);
        for i in 0..MR {
            for j in 0..NR {
                let mut want = before[i * ldc + j];
                for kk in 0..kc {
                    want += ap[kk * MR + i] * bp[kk * NR + j];
                }
                let got = c[i * ldc + j];
                assert!((got - want).abs() <= 1e-4 * want.abs().max(1.0), "({i},{j})");
            }
        }
        // Lanes past NR in each row are untouched.
        for i in 0..MR {
            for j in NR..ldc {
                assert_eq!(c[i * ldc + j], 0.5, "({i},{j}) clobbered");
            }
        }
    }

    #[test]
    fn tail_microkernel_masks_writeback_exactly() {
        let mut rng = Rng::new(8);
        let kc = 9;
        let ap = randn(&mut rng, kc * MR);
        let bp = randn(&mut rng, kc * NR);
        let (mr_eff, nr_eff) = (3, 5);
        let ldc = NR;
        let mut full = vec![0.0f32; MR * ldc];
        microkernel(kc, &ap, &bp, &mut full, ldc);
        let mut tail = vec![7.0f32; MR * ldc];
        microkernel_tail(kc, &ap, &bp, &mut tail, ldc, mr_eff, nr_eff);
        for i in 0..MR {
            for j in 0..NR {
                if i < mr_eff && j < nr_eff {
                    // Same reduction as the interior kernel, bit-exact.
                    assert_eq!(tail[i * ldc + j], 7.0 + full[i * ldc + j], "({i},{j})");
                } else {
                    assert_eq!(tail[i * ldc + j], 7.0, "({i},{j}) clobbered");
                }
            }
        }
    }

    #[test]
    fn packed_gemm_matches_reference_across_uneven_shapes() {
        let p = KernelProfile::l2_small(); // smallest blocks → most edges
        let mut rng = Rng::new(21);
        for (m, n, k) in [
            (1, 1, 1),
            (1, 17, 131),
            (31, 1, 7),
            (9, 9, 9),
            (MR + 1, NR + 1, 3),
            (67, 129, 130),
            (200, 96, 131),
        ] {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let got = packed(&p, &a, &b, m, n, k);
            let want = matmul_ref(&a, &b, m, n, k);
            let err = max_abs_diff(&got, &want);
            assert!(err < 1e-3, "{m}x{n}x{k}: err {err}");
        }
    }

    #[test]
    fn profiles_agree_bitwise_on_integer_operands() {
        // Integer-valued f32 operands make every product and partial
        // sum exact, so blocking cannot change the result at all.
        let mut rng = Rng::new(22);
        let (m, n, k) = (130, 70, 300); // crosses MC/KC/NC for all profiles
        let a: Vec<f32> = (0..m * k).map(|_| (rng.below(13) as f32) - 6.0).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.below(13) as f32) - 6.0).collect();
        let want = matmul_ref(&a, &b, m, n, k);
        for p in [
            KernelProfile::generic(),
            KernelProfile::l2_small(),
            KernelProfile::l2_large(),
        ] {
            let got = packed(&p, &a, &b, m, n, k);
            assert_eq!(got, want, "profile {}", p.name);
        }
    }
}
