//! Pluggable execution backends — how a planned GEMM job's numerics
//! actually run (DESIGN.md §3).
//!
//! Until this layer existed, execution was hard-wired to the PJRT
//! [`GemmEngine`]: without the AOT artifacts (the default in CI and
//! every offline checkout) a `GemmJob::with_data` died with "no
//! artifact engine" and the coordinator could not serve a single data
//! job end-to-end. [`ExecBackend`] breaks that coupling with three
//! implementations:
//!
//! * [`PjrtBackend`] — the original path: tiles streamed through the
//!   AOT-compiled Pallas artifacts on the PJRT CPU client;
//! * [`CpuBackend`] — always available: a GotoBLAS2-style packed-panel
//!   GEMM (see [`crate::runtime::microkernel`]) whose MC row-panel
//!   tasks fan out as cooperative turns on the shared process-wide
//!   [`DsePool`], so execution honors the same worker budget as
//!   planning instead of spawning its own threads;
//! * [`SimBackend`] — executes via [`CpuBackend`] for real numerics but
//!   stamps the result with a [`VersalSim`] measurement, so the serving
//!   path reports the latency/power the *selected mapping* would
//!   achieve on the VCK190 — plan-quality evaluation as a service.
//!
//! [`BackendChoice::Auto`] (the default) selects PJRT when the
//! artifacts load and falls back to CPU otherwise, which is what
//! deletes the "plan-only mode" limitation the vendored `xla` stub used
//! to force.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::dse::DsePool;
use crate::runtime::microkernel::{
    pack_a, pack_b, packed_block, packed_gemm_serial, with_a_panel, with_b_panel,
    CpuProfileChoice, KernelProfile,
};
use crate::runtime::{accumulate_tile, extract_tile, pick_variant, GemmEngine};
use crate::tiling::Tiling;
use crate::util::lock_unpoisoned;
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::Gemm;

/// One way of executing a GEMM's numerics. Implementations are owned by
/// the coordinator's executor thread (PJRT handles are not `Send`, so
/// the trait deliberately requires neither `Send` nor `Sync`).
pub trait ExecBackend {
    /// Stable identifier surfaced in the `serve` summary and stats.
    fn name(&self) -> &'static str;

    /// Whether this backend can execute the given workload.
    fn supports(&self, g: &Gemm) -> bool {
        g.m > 0 && g.n > 0 && g.k > 0
    }

    /// Execute `C[m,n] = A[m,k] @ B[k,n]` (row-major FP32).
    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>>;

    /// Artifact-variant key for executor batch grouping (PJRT reuses
    /// compiled executables across same-variant jobs; others have no
    /// variant notion).
    fn variant_hint(&self, _m: usize, _n: usize, _k: usize) -> Option<usize> {
        None
    }

    /// Selected CPU [`KernelProfile`] name — `Some` for backends whose
    /// numerics run through the packed-panel microkernel (cpu, sim),
    /// `None` for PJRT. Surfaced in stats and the serve summary so
    /// operators can see which profile a daemon is running.
    fn kernel_profile(&self) -> Option<&'static str> {
        None
    }

    /// Board-level measurement stamp for an executed job: `Some` only
    /// for [`SimBackend`], whose results report the simulated VCK190
    /// latency/power of the job's selected mapping instead of host
    /// wall-clock.
    fn board_measurement(&self, _g: &Gemm, _t: &Tiling) -> Option<Measurement> {
        None
    }
}

/// Which backend `Coordinator::start` builds
/// (`CoordinatorOptions::backend`, `serve --backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// PJRT when the artifacts load, else [`CpuBackend`].
    #[default]
    Auto,
    Pjrt,
    Cpu,
    Sim,
}

impl BackendChoice {
    pub fn parse(text: &str) -> Result<BackendChoice> {
        match text {
            "auto" => Ok(BackendChoice::Auto),
            "pjrt" => Ok(BackendChoice::Pjrt),
            "cpu" => Ok(BackendChoice::Cpu),
            "sim" => Ok(BackendChoice::Sim),
            other => bail!("unknown backend `{other}` (pjrt|cpu|sim|auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Pjrt => "pjrt",
            BackendChoice::Cpu => "cpu",
            BackendChoice::Sim => "sim",
        }
    }

    /// The ordered tier list the resilient executor runs over. `Auto`
    /// is the full capability chain — PJRT (only worth probing when an
    /// artifacts directory is configured), then cpu, then sim — so a
    /// tripped breaker demotes down it at runtime; an explicit choice
    /// pins a single tier and never fails over.
    pub fn capability_chain(&self, has_artifacts: bool) -> Vec<BackendChoice> {
        match self {
            BackendChoice::Auto if has_artifacts => {
                vec![BackendChoice::Pjrt, BackendChoice::Cpu, BackendChoice::Sim]
            }
            BackendChoice::Auto => vec![BackendChoice::Cpu, BackendChoice::Sim],
            concrete => vec![*concrete],
        }
    }
}

/// Build one *concrete* tier (`Auto` is a chain, not a tier — resolve
/// it via [`BackendChoice::capability_chain`] first). This is the
/// constructor the resilient executor and its watchdog worker share.
pub fn make_single_backend(
    tier: BackendChoice,
    cpu_profile: CpuProfileChoice,
    artifacts_dir: Option<&Path>,
    sim: VersalSim,
) -> Result<Box<dyn ExecBackend>> {
    match tier {
        BackendChoice::Cpu => Ok(Box::new(CpuBackend::new().with_profile(cpu_profile.resolve()))),
        BackendChoice::Sim => Ok(Box::new(SimBackend::with_cpu(
            CpuBackend::new().with_profile(cpu_profile.resolve()),
            sim,
        ))),
        BackendChoice::Pjrt => {
            let dir = artifacts_dir
                .ok_or_else(|| anyhow!("backend `pjrt` requires an artifacts directory"))?;
            Ok(Box::new(PjrtBackend::load(dir)?))
        }
        BackendChoice::Auto => bail!("`auto` is a capability chain, not a concrete tier"),
    }
}

/// Build the backend a coordinator will execute on. `Auto` tries PJRT
/// when an artifacts directory is configured and falls back to the
/// always-available CPU backend (logged); explicit `Pjrt` propagates
/// the load error so a misconfigured deployment fails loudly.
/// `cpu_profile` selects the packed-panel blocking for the cpu/sim
/// paths (`Auto` probes L2 once); it is ignored by PJRT.
pub fn make_backend(
    choice: BackendChoice,
    cpu_profile: CpuProfileChoice,
    artifacts_dir: Option<&Path>,
    sim: VersalSim,
) -> Result<Box<dyn ExecBackend>> {
    match choice {
        BackendChoice::Auto => {
            if artifacts_dir.is_some() {
                match make_single_backend(BackendChoice::Pjrt, cpu_profile, artifacts_dir, sim.clone())
                {
                    Ok(b) => return Ok(b),
                    Err(e) => {
                        eprintln!("exec backend: PJRT unavailable ({e}); falling back to cpu")
                    }
                }
            }
            make_single_backend(BackendChoice::Cpu, cpu_profile, artifacts_dir, sim)
        }
        concrete => make_single_backend(concrete, cpu_profile, artifacts_dir, sim),
    }
}

/// The PJRT path: the AOT-compiled Pallas artifacts behind the
/// [`ExecBackend`] trait.
pub struct PjrtBackend {
    engine: GemmEngine,
}

impl PjrtBackend {
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            engine: GemmEngine::load(dir)?,
        })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        self.engine.gemm(a, b, m, n, k)
    }

    fn variant_hint(&self, m: usize, n: usize, k: usize) -> Option<usize> {
        Some(pick_variant(&self.engine.manifest.variants, m, n, k))
    }
}

/// GEMMs at or below this total MAC count run inline unconditionally —
/// the pool round-trip costs more than the whole product (one 64-cube).
const CPU_INLINE_MACS: usize = 64 * 64 * 64;

/// Minimum MACs one fanned-out (jc, pc) turn must carry for the pool
/// dispatch to pay for itself. This is *per-panel* work — rows-per-MC-
/// panel × clamped-NC columns × clamped-KC depth — not total work: the
/// old total-MAC gate let tall-skinny shapes (large m, tiny n·k) fan
/// out turns worth only a few thousand MACs each, where the `run_scoped`
/// round-trip dominated. A 64-cube of work per turn (~0.5 MFLOP,
/// hundreds of µs) safely amortizes the ~µs dispatch.
const CPU_MIN_PANEL_MACS: usize = 64 * 64 * 64;

/// Always-available host execution: GotoBLAS2-style packed-panel GEMM
/// (see [`crate::runtime::microkernel`]). The caller packs each KC×NC
/// B panel once into its thread-local scratch, then the MC×KC A-panel
/// tasks fan out as cooperative turns on the shared [`DsePool`] — each
/// worker packs its own A panel into *its* thread-local scratch and
/// writes a disjoint row block of C, so execution and planning draw
/// from the same process-wide worker budget and the hot path allocates
/// nothing after warm-up.
pub struct CpuBackend {
    /// `None` routes through the process-global pool.
    pool: Option<Arc<DsePool>>,
    profile: KernelProfile,
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend::new()
    }
}

impl CpuBackend {
    /// Default construction uses the `generic` profile — deterministic
    /// everywhere; callers that want the L2 probe pass
    /// `CpuProfileChoice::Auto.resolve()` via [`CpuBackend::with_profile`].
    pub fn new() -> CpuBackend {
        CpuBackend {
            pool: None,
            profile: KernelProfile::generic(),
        }
    }

    /// Route panel tasks through a dedicated pool (tests, benches).
    pub fn with_pool(mut self, pool: Arc<DsePool>) -> CpuBackend {
        self.pool = Some(pool);
        self
    }

    /// Select the packed-panel blocking parameters.
    pub fn with_profile(mut self, profile: KernelProfile) -> CpuBackend {
        self.profile = profile;
        self
    }

    pub fn profile(&self) -> &KernelProfile {
        &self.profile
    }

    fn pool(&self) -> &DsePool {
        match &self.pool {
            Some(p) => p,
            None => DsePool::global(),
        }
    }
}

/// The PR-5 blocked tiled GEMM (64-tiles over
/// [`extract_tile`]/[`accumulate_tile`]), kept verbatim and serial as
/// the comparison oracle for `benches/runtime_gemm.rs` and CI's
/// microkernel-vs-legacy perf gate. Not reachable from any serving
/// path: [`CpuBackend::gemm`] drives the packed-panel microkernel.
pub fn gemm_blocked_legacy(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    const TILE: usize = 64;
    let mut c = vec![0f32; m * n];
    for (idx, panel) in c.chunks_mut(TILE * n).enumerate() {
        gemm_panel(a, b, m, n, k, idx * TILE, TILE, panel);
    }
    c
}

/// `C_tile = A_tile @ B_tile` for square `t`-tiles (overwrites `c`).
/// Zero-padded lanes contribute nothing, so padded edge tiles are free.
fn tile_kernel(a: &[f32], b: &[f32], t: usize, c: &mut [f32]) {
    c.fill(0.0);
    for i in 0..t {
        for kk in 0..t {
            let av = a[i * t + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * t..(kk + 1) * t];
            let crow = &mut c[i * t..(i + 1) * t];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Per-thread A/B/C tile scratch for the legacy oracle path.
#[derive(Default)]
struct TileScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

thread_local! {
    static TILE_SCRATCH: std::cell::RefCell<TileScratch> =
        std::cell::RefCell::new(TileScratch::default());
}

/// Compute one row panel (`rows r0 .. r0+panel_rows` of C) of the
/// blocked product. `panel` is that slice of the output matrix.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    r0: usize,
    tile: usize,
    panel: &mut [f32],
) {
    let panel_rows = (m - r0).min(tile);
    debug_assert_eq!(panel.len(), panel_rows * n);
    TILE_SCRATCH.with(|cell| {
        let scratch = &mut *cell.borrow_mut();
        // resize is a no-op after the first panel at this tile size;
        // extract_tile and tile_kernel overwrite every lane they read.
        scratch.a.resize(tile * tile, 0.0);
        scratch.b.resize(tile * tile, 0.0);
        scratch.c.resize(tile * tile, 0.0);
        for kk in (0..k).step_by(tile) {
            extract_tile(a, m, k, r0, kk, tile, tile, &mut scratch.a);
            for j in (0..n).step_by(tile) {
                extract_tile(b, k, n, kk, j, tile, tile, &mut scratch.b);
                tile_kernel(&scratch.a, &scratch.b, tile, &mut scratch.c);
                accumulate_tile(panel, panel_rows, n, 0, j, tile, tile, &scratch.c);
            }
        }
    });
}

impl ExecBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        if a.len() != m * k || b.len() != k * n {
            bail!("operand shapes do not match {m}x{n}x{k}");
        }
        let p = self.profile;
        let mut c = vec![0f32; m * n];
        let n_panels = m.div_ceil(p.mc);
        // Fan-out decision from *per-panel* work: what one pool turn
        // actually computes is an MC-row × min(NC,n) × min(KC,k) block,
        // so that product — not m·n·k — must clear the dispatch cost.
        // Decided before touching the pool, so GEMMs that stay serial
        // never lazily spin up the global worker threads.
        let panel_macs = p.mc.min(m) * p.nc.min(n) * p.kc.min(k);
        if n_panels <= 1 || m * n * k <= CPU_INLINE_MACS || panel_macs < CPU_MIN_PANEL_MACS {
            packed_gemm_serial(&p, a, b, m, n, k, &mut c);
            return Ok(c);
        }
        let pool = self.pool();
        if pool.n_threads() == 1 {
            packed_gemm_serial(&p, a, b, m, n, k, &mut c);
            return Ok(c);
        }
        // Outer jc/pc loops run on the calling thread, which packs the
        // B panel once into its TLS scratch; the MC-row A panels of
        // each (jc, pc) step fan out as cooperative pool turns. The
        // (jc, pc, ic) decomposition is a pure function of shape and
        // profile, panels are disjoint row blocks of C each claimed
        // exactly once off the shared counter, and pc steps accumulate
        // sequentially — so the result is bit-identical to the serial
        // path for any pool width and any worker interleaving.
        for jc in (0..n).step_by(p.nc) {
            let nc_eff = p.nc.min(n - jc);
            for pc in (0..k).step_by(p.kc) {
                let kc_eff = p.kc.min(k - pc);
                let panics = with_b_panel(|bbuf| {
                    pack_b(b, n, pc, jc, kc_eff, nc_eff, bbuf);
                    let bpanel: &[f32] = bbuf;
                    let next = AtomicUsize::new(0);
                    let panels: Vec<Mutex<(usize, &mut [f32])>> = c
                        .chunks_mut(p.mc * n)
                        .enumerate()
                        .map(Mutex::new)
                        .collect();
                    let n_tasks = pool.n_threads().min(n_panels);
                    pool.run_scoped(n_tasks, |_| {
                        let pi = next.fetch_add(1, Ordering::SeqCst);
                        if pi >= n_panels {
                            return false;
                        }
                        let mut guard = lock_unpoisoned(&panels[pi]);
                        let (idx, chunk) = &mut *guard;
                        let ic = *idx * p.mc;
                        let mc_eff = p.mc.min(m - ic);
                        with_a_panel(|abuf| {
                            pack_a(a, k, ic, pc, mc_eff, kc_eff, abuf);
                            packed_block(abuf, bpanel, kc_eff, mc_eff, nc_eff, chunk, n, jc);
                        });
                        true
                    })
                });
                if panics > 0 {
                    bail!("cpu backend worker panicked executing {m}x{n}x{k}");
                }
            }
        }
        Ok(c)
    }

    fn kernel_profile(&self) -> Option<&'static str> {
        Some(self.profile.name)
    }
}

/// Plan-quality evaluation as a service: real numerics via
/// [`CpuBackend`], but the result is stamped with the [`VersalSim`]
/// measurement of the job's selected mapping, so `exec_time`, power,
/// and GFLOPS/W report what the plan would deliver on the VCK190.
pub struct SimBackend {
    cpu: CpuBackend,
    sim: VersalSim,
}

impl SimBackend {
    pub fn new(sim: VersalSim) -> SimBackend {
        SimBackend {
            cpu: CpuBackend::new(),
            sim,
        }
    }

    pub fn with_cpu(cpu: CpuBackend, sim: VersalSim) -> SimBackend {
        SimBackend { cpu, sim }
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        self.cpu.gemm(a, b, m, n, k)
    }

    fn kernel_profile(&self) -> Option<&'static str> {
        self.cpu.kernel_profile()
    }

    fn board_measurement(&self, g: &Gemm, t: &Tiling) -> Option<Measurement> {
        self.sim.evaluate(g, t, BufferPlacement::UramFirst).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::runtime::{matmul_ref, max_abs_diff};
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn cpu_backend_matches_reference() {
        let cpu = CpuBackend::new();
        let mut rng = Rng::new(11);
        for (m, n, k) in [
            (1, 1, 1),
            (1, 33, 7),
            (70, 50, 90),
            (64, 64, 64),
            (65, 63, 66),
            (1, 256, 130),
            (97, 1, 5),
            (128, 128, 1),
            (200, 96, 131),
        ] {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let got = cpu.gemm(&a, &b, m, n, k).unwrap();
            let want = matmul_ref(&a, &b, m, n, k);
            let err = max_abs_diff(&got, &want);
            assert!(err < 1e-3, "{m}x{n}x{k}: err {err}");
        }
    }

    #[test]
    fn cpu_backend_rejects_bad_shapes() {
        let cpu = CpuBackend::new();
        assert!(cpu.gemm(&[0.0; 10], &[0.0; 16], 4, 4, 4).is_err());
        assert!(cpu.gemm(&[0.0; 16], &[0.0; 10], 4, 4, 4).is_err());
    }

    #[test]
    fn cpu_backend_identical_across_pool_widths() {
        // The (jc, pc, ic) decomposition is fixed, so any worker
        // interleaving produces bit-identical output. Shape sized to
        // actually fan out (multiple MC panels, panel work above the
        // per-panel floor for every profile).
        let mut rng = Rng::new(5);
        let (m, n, k) = (300, 129, 170);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        for profile in [KernelProfile::l2_small(), KernelProfile::generic()] {
            let base = CpuBackend::new()
                .with_profile(profile)
                .with_pool(Arc::new(DsePool::new(1)))
                .gemm(&a, &b, m, n, k)
                .unwrap();
            for width in [2usize, 4, 8] {
                let got = CpuBackend::new()
                    .with_profile(profile)
                    .with_pool(Arc::new(DsePool::new(width)))
                    .gemm(&a, &b, m, n, k)
                    .unwrap();
                assert_eq!(got, base, "profile {} width {width}", profile.name);
            }
        }
    }

    #[test]
    fn cpu_backend_matches_legacy_oracle_on_integers() {
        // Integer-valued operands are exact in f32, so the packed
        // microkernel and the legacy blocked loop must agree bitwise.
        let mut rng = Rng::new(17);
        let (m, n, k) = (130, 96, 150);
        let a: Vec<f32> = (0..m * k).map(|_| (rng.below(9) as f32) - 4.0).collect();
        let b: Vec<f32> = (0..k * n).map(|_| (rng.below(9) as f32) - 4.0).collect();
        let packed = CpuBackend::new().gemm(&a, &b, m, n, k).unwrap();
        let legacy = gemm_blocked_legacy(&a, &b, m, n, k);
        assert_eq!(packed, legacy);
        assert_eq!(packed, matmul_ref(&a, &b, m, n, k));
    }

    #[test]
    fn tall_skinny_shapes_stay_serial_but_correct() {
        // The per-panel-work gate: large m with tiny n·k used to fan
        // out µs-scale turns; now it must run serially (observable only
        // as "no pool spin-up", so assert numerics on a 1-thread pool —
        // identical either way — and that the gate math says serial).
        let p = KernelProfile::generic();
        let (m, n, k) = (4096, 8, 8);
        assert!(p.mc.min(m) * p.nc.min(n) * p.kc.min(k) < CPU_MIN_PANEL_MACS);
        let mut rng = Rng::new(19);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let got = CpuBackend::new().gemm(&a, &b, m, n, k).unwrap();
        let err = max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k));
        assert!(err < 1e-3, "err {err}");
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(), BackendChoice::Pjrt);
        assert_eq!(BackendChoice::parse("cpu").unwrap(), BackendChoice::Cpu);
        assert_eq!(BackendChoice::parse("sim").unwrap(), BackendChoice::Sim);
        assert!(BackendChoice::parse("tpu").is_err());
        assert_eq!(BackendChoice::default().label(), "auto");
    }

    #[test]
    fn capability_chain_orders_tiers_and_pins_explicit_choices() {
        assert_eq!(
            BackendChoice::Auto.capability_chain(true),
            vec![BackendChoice::Pjrt, BackendChoice::Cpu, BackendChoice::Sim]
        );
        assert_eq!(
            BackendChoice::Auto.capability_chain(false),
            vec![BackendChoice::Cpu, BackendChoice::Sim]
        );
        for concrete in [BackendChoice::Pjrt, BackendChoice::Cpu, BackendChoice::Sim] {
            assert_eq!(concrete.capability_chain(true), vec![concrete]);
            assert_eq!(concrete.capability_chain(false), vec![concrete]);
        }
        let cfg = Config::default();
        assert!(make_single_backend(
            BackendChoice::Auto,
            CpuProfileChoice::Generic,
            None,
            VersalSim::new(&cfg)
        )
        .is_err());
        let b = make_single_backend(
            BackendChoice::Sim,
            CpuProfileChoice::Generic,
            None,
            VersalSim::new(&cfg),
        )
        .unwrap();
        assert_eq!(b.name(), "sim");
    }

    #[test]
    fn auto_without_artifacts_is_cpu_and_explicit_pjrt_fails_loudly() {
        let cfg = Config::default();
        let missing = Path::new("definitely/not/artifacts");
        let auto = CpuProfileChoice::Auto;
        let b =
            make_backend(BackendChoice::Auto, auto, Some(missing), VersalSim::new(&cfg)).unwrap();
        assert_eq!(b.name(), "cpu");
        assert!(b.kernel_profile().is_some());
        let b = make_backend(BackendChoice::Auto, auto, None, VersalSim::new(&cfg)).unwrap();
        assert_eq!(b.name(), "cpu");
        let pjrt = make_backend(BackendChoice::Pjrt, auto, Some(missing), VersalSim::new(&cfg));
        assert!(pjrt.is_err());
        assert!(make_backend(BackendChoice::Pjrt, auto, None, VersalSim::new(&cfg)).is_err());
    }

    #[test]
    fn explicit_profile_choice_reaches_the_backend() {
        let cfg = Config::default();
        for (choice, want) in [
            (CpuProfileChoice::Generic, "generic"),
            (CpuProfileChoice::L2Small, "l2-small"),
            (CpuProfileChoice::L2Large, "l2-large"),
        ] {
            let b = make_backend(BackendChoice::Cpu, choice, None, VersalSim::new(&cfg)).unwrap();
            assert_eq!(b.kernel_profile(), Some(want));
            let b = make_backend(BackendChoice::Sim, choice, None, VersalSim::new(&cfg)).unwrap();
            assert_eq!(b.kernel_profile(), Some(want), "sim delegates to cpu");
        }
    }

    #[test]
    fn sim_backend_stamps_measurement_and_matches_cpu_numerics() {
        let cfg = Config::default();
        let sim = SimBackend::new(VersalSim::new(&cfg));
        assert_eq!(sim.name(), "sim");
        let mut rng = Rng::new(9);
        let (m, n, k) = (64, 96, 32);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let got = sim.gemm(&a, &b, m, n, k).unwrap();
        assert!(max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k)) < 1e-3);
        let g = Gemm::new(1024, 1024, 1024);
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let mea = sim.board_measurement(&g, &t).expect("buildable design");
        assert!(mea.latency_s > 0.0 && mea.power_w > 0.0);
        // Non-sim backends never stamp.
        assert!(CpuBackend::new().board_measurement(&g, &t).is_none());
    }

    #[test]
    fn supports_rejects_degenerate_dims() {
        let cpu = CpuBackend::new();
        assert!(cpu.supports(&Gemm::new(64, 64, 64)));
        assert!(!cpu.supports(&Gemm::new(0, 64, 64)));
    }
}
