//! §Perf profiling driver: the measurements behind EXPERIMENTS.md §Perf.
//!
//! * `dse`    — enumeration vs prediction split of the DSE hot path;
//! * `kernel` — L1 block-shape comparison (blocked 32³ grid vs fused
//!   MXU-edge blocks) on pre-staged device buffers;
//! * `decode` — executor variant head-to-head on the decode GEMM shape.
//!
//! Run with: `cargo run --release --example perf_profile [-- dse|kernel|decode|all]`

use std::time::Instant;

use versal_gemm::config::Config;
use versal_gemm::report::Lab;
use versal_gemm::runtime::GemmEngine;
use versal_gemm::tiling::{enumerate_candidates, TilingLimits};
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::Gemm;

fn profile_dse() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;
    let engine = lab.engine();
    let g = Gemm::new(1576, 3072, 768); // worst eval workload (G8)
    let limits = TilingLimits::from_board(&cfg.board);
    let t0 = Instant::now();
    let cands = enumerate_candidates(&g, 32, &limits);
    println!("dse: enumerate {:?} for {} candidates", t0.elapsed(), cands.len());
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t1 = Instant::now();
        let r = engine.explore(&g)?;
        best = best.min(t1.elapsed().as_secs_f64());
        std::hint::black_box(r.n_feasible);
    }
    println!("dse: explore best-of-3 {:.1} ms (predict+filter+pareto)", best * 1e3);
    Ok(())
}

fn profile_kernel() -> anyhow::Result<()> {
    let engine = GemmEngine::load(std::path::Path::new("artifacts"))?;
    let mut rng = Rng::new(5);
    let a: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    for name in ["tile_128", "tile_128_fused"] {
        let idx = engine.variant_index(name).unwrap();
        let la = engine.tile_buffer(&a, 128, 128)?;
        let lb = engine.tile_buffer(&b, 128, 128)?;
        let _ = engine.execute_buffers(idx, &la, &lb)?;
        let t = Instant::now();
        let iters = 200;
        for _ in 0..iters {
            std::hint::black_box(engine.execute_buffers(idx, &la, &lb)?);
        }
        let per = t.elapsed().as_secs_f64() / iters as f64;
        println!(
            "kernel: {name:<16} {:>9.1} us/call  {:>6.2} GFLOP/s",
            per * 1e6,
            2.0 * 128f64.powi(3) / per / 1e9
        );
    }
    Ok(())
}

fn profile_decode() -> anyhow::Result<()> {
    let engine = GemmEngine::load(std::path::Path::new("artifacts"))?;
    let (m, n, k) = (32usize, 896usize, 896usize);
    let mut rng = Rng::new(5);
    let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
    for name in ["tile_32x128x128", "tile_32x512x512_fused", "tile_128_fused"] {
        let idx = engine.variant_index(name).unwrap();
        let _ = engine.gemm_with(idx, &a, &b, m, n, k)?;
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t = Instant::now();
            std::hint::black_box(engine.gemm_with(idx, &a, &b, m, n, k)?);
            best = best.min(t.elapsed().as_secs_f64());
        }
        println!(
            "decode: {name:<24} best {:>8.2} ms  {:>6.2} GFLOP/s",
            best * 1e3,
            2.0 * (m * n * k) as f64 / best / 1e9
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let which = std::env::args().nth(1).unwrap_or_else(|| "all".into());
    if which == "dse" || which == "all" {
        profile_dse()?;
    }
    if which == "kernel" || which == "all" {
        profile_kernel()?;
    }
    if which == "decode" || which == "all" {
        profile_decode()?;
    }
    Ok(())
}
