//! Model graphs as first-class workloads: named GEMM nodes wired into
//! a DAG (DESIGN.md §11).
//!
//! A [`GemmGraph`] is the unit the serving stack calls a *graph job*:
//! each node is one GEMM whose A/B operands come either from the client
//! ([`OperandSource::External`]) or from the output of an upstream node
//! ([`OperandSource::Node`]). Validation is total and deterministic —
//! duplicate names, unknown edge targets, shape-incompatible edges and
//! cycles all surface as typed errors, and the topological order used
//! for execution is a deterministic Kahn sweep (lowest node index
//! first), so the same graph always plans and executes identically.
//!
//! The module also owns the two shape validators the coordinator reuses
//! for single jobs ([`operand_shape_error`]) and for edges
//! ([`edge_shape_error`]), and constructors that lift the structural
//! model zoo ([`TransformerSpec::block_gemms`], [`SwinStage`],
//! [`ncf_gemms`]) into graphs whose intermediates flow node-to-node.

use std::collections::{BTreeSet, HashMap};

use crate::util::rng::fnv1a;
use crate::workloads::Gemm;

use super::models::{ncf_gemms, SwinStage, TransformerSpec};

/// Where one operand of a graph node comes from.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum OperandSource {
    /// Client-provided buffer, shipped with the job.
    External,
    /// The C output of the named upstream node.
    Node(String),
}

/// Which operand of `C = A @ B` a source feeds.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Slot {
    A,
    B,
}

impl Slot {
    pub fn label(&self) -> &'static str {
        match self {
            Slot::A => "A",
            Slot::B => "B",
        }
    }
}

/// One named GEMM in a graph.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct GraphNode {
    pub name: String,
    pub gemm: Gemm,
    pub a: OperandSource,
    pub b: OperandSource,
}

impl GraphNode {
    pub fn source(&self, slot: Slot) -> &OperandSource {
        match slot {
            Slot::A => &self.a,
            Slot::B => &self.b,
        }
    }
}

/// A DAG of named GEMMs — the payload of a graph job.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct GemmGraph {
    pub nodes: Vec<GraphNode>,
}

/// The expected element count of one operand buffer.
fn slot_len(g: &Gemm, slot: Slot) -> usize {
    match slot {
        Slot::A => g.m * g.k,
        Slot::B => g.k * g.n,
    }
}

/// Shared operand-size validator: a present buffer whose length does not
/// match the GEMM's A (`m*k`) / B (`k*n`) extent is a shape error. Used
/// by the graph path for external inputs and by `Coordinator::submit`
/// for plain [`crate::coordinator::GemmJob`]s, so both reject
/// k-mismatched operands *before* any planning happens.
pub fn operand_shape_error(g: &Gemm, a_len: Option<usize>, b_len: Option<usize>) -> Option<String> {
    if let Some(len) = a_len {
        if len != slot_len(g, Slot::A) {
            return Some(format!(
                "operand A has {len} elements but GEMM {} needs {} ({}x{})",
                g.label(),
                g.m * g.k,
                g.m,
                g.k
            ));
        }
    }
    if let Some(len) = b_len {
        if len != slot_len(g, Slot::B) {
            return Some(format!(
                "operand B has {len} elements but GEMM {} needs {} ({}x{})",
                g.label(),
                g.k * g.n,
                g.k,
                g.n
            ));
        }
    }
    None
}

/// Edge-shape validator: the producer's `m x n` output must match the
/// consumer slot's expected extent (`m x k` for A, `k x n` for B).
pub fn edge_shape_error(producer: &Gemm, consumer: &Gemm, slot: Slot) -> Option<String> {
    let (want_rows, want_cols) = match slot {
        Slot::A => (consumer.m, consumer.k),
        Slot::B => (consumer.k, consumer.n),
    };
    if producer.m != want_rows || producer.n != want_cols {
        return Some(format!(
            "edge feeds {}x{} output into slot {} expecting {}x{}",
            producer.m,
            producer.n,
            slot.label(),
            want_rows,
            want_cols
        ));
    }
    None
}

impl GemmGraph {
    pub fn new() -> GemmGraph {
        GemmGraph::default()
    }

    /// Append a node (builder style).
    pub fn push(
        mut self,
        name: &str,
        gemm: Gemm,
        a: OperandSource,
        b: OperandSource,
    ) -> GemmGraph {
        self.nodes.push(GraphNode {
            name: name.to_string(),
            gemm,
            a,
            b,
        });
        self
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.is_empty()
    }

    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.nodes.iter().position(|n| n.name == name)
    }

    /// Total floating-point operations across all nodes.
    pub fn flops(&self) -> f64 {
        self.nodes.iter().map(|n| n.gemm.flops()).sum()
    }

    /// Resolve one operand source to the producing node's index.
    fn resolve(
        &self,
        by_name: &HashMap<&str, usize>,
        idx: usize,
        slot: Slot,
    ) -> Result<Option<usize>, String> {
        let node = &self.nodes[idx];
        match node.source(slot) {
            OperandSource::External => Ok(None),
            OperandSource::Node(src) => match by_name.get(src.as_str()) {
                Some(&p) => Ok(Some(p)),
                None => Err(format!(
                    "node `{}` reads {} from unknown node `{src}`",
                    node.name,
                    slot.label()
                )),
            },
        }
    }

    /// Per-node edge dependencies `(producer_idx, slot)` in (A, B) order.
    fn deps(&self) -> Result<Vec<Vec<(usize, Slot)>>, String> {
        let mut by_name: HashMap<&str, usize> = HashMap::new();
        for (i, node) in self.nodes.iter().enumerate() {
            if by_name.insert(node.name.as_str(), i).is_some() {
                return Err(format!("duplicate node name `{}`", node.name));
            }
        }
        let mut deps = Vec::with_capacity(self.nodes.len());
        for (i, node) in self.nodes.iter().enumerate() {
            let mut d = Vec::new();
            for slot in [Slot::A, Slot::B] {
                if let Some(p) = self.resolve(&by_name, i, slot)? {
                    if let Some(why) = edge_shape_error(&self.nodes[p].gemm, &node.gemm, slot) {
                        return Err(format!(
                            "node `{}` <- `{}`: {why}",
                            node.name, self.nodes[p].name
                        ));
                    }
                    d.push((p, slot));
                }
            }
            deps.push(d);
        }
        Ok(deps)
    }

    /// Validate the DAG and return its deterministic topological order
    /// (Kahn's algorithm, always releasing the lowest-index ready node
    /// first). Errors: empty graph, duplicate names, unknown edge
    /// targets, shape-incompatible edges, cycles.
    pub fn validate(&self) -> Result<Vec<usize>, String> {
        if self.nodes.is_empty() {
            return Err("graph has no nodes".to_string());
        }
        let deps = self.deps()?;
        let mut indegree: Vec<usize> = deps.iter().map(Vec::len).collect();
        let mut consumers: Vec<Vec<usize>> = vec![Vec::new(); self.nodes.len()];
        for (i, d) in deps.iter().enumerate() {
            for &(p, _) in d {
                consumers[p].push(i);
            }
        }
        let mut ready: BTreeSet<usize> = indegree
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect();
        let mut order = Vec::with_capacity(self.nodes.len());
        while let Some(&i) = ready.iter().next() {
            ready.remove(&i);
            order.push(i);
            for &c in &consumers[i] {
                indegree[c] -= 1;
                if indegree[c] == 0 {
                    ready.insert(c);
                }
            }
        }
        if order.len() < self.nodes.len() {
            let stuck: Vec<&str> = (0..self.nodes.len())
                .filter(|i| !order.contains(i))
                .map(|i| self.nodes[i].name.as_str())
                .collect();
            return Err(format!("cycle detected among nodes: {}", stuck.join(", ")));
        }
        Ok(order)
    }

    /// How many downstream operand slots consume each node's output —
    /// the refcounts the executor's operand arena frees against.
    pub fn consumer_counts(&self) -> Result<Vec<usize>, String> {
        let deps = self.deps()?;
        let mut counts = vec![0usize; self.nodes.len()];
        for d in &deps {
            for &(p, _) in d {
                counts[p] += 1;
            }
        }
        Ok(counts)
    }

    /// All external operand slots in deterministic (node, A-then-B)
    /// order — the buffers a client must ship with a data graph job.
    pub fn external_slots(&self) -> Vec<(usize, Slot)> {
        let mut out = Vec::new();
        for (i, node) in self.nodes.iter().enumerate() {
            for slot in [Slot::A, Slot::B] {
                if *node.source(slot) == OperandSource::External {
                    out.push((i, slot));
                }
            }
        }
        out
    }

    /// Expected element count of one node's operand buffer.
    pub fn slot_elems(&self, idx: usize, slot: Slot) -> usize {
        slot_len(&self.nodes[idx].gemm, slot)
    }

    /// Structural hash of the whole DAG (names, shapes, wiring) plus the
    /// planning objective — the key of the graph-level plan cache.
    pub fn dag_hash(&self, objective_tag: u8) -> u64 {
        let mut bytes = Vec::with_capacity(self.nodes.len() * 48 + 2);
        bytes.push(objective_tag);
        bytes.extend_from_slice(&(self.nodes.len() as u64).to_le_bytes());
        for node in &self.nodes {
            bytes.extend_from_slice(&(node.name.len() as u64).to_le_bytes());
            bytes.extend_from_slice(node.name.as_bytes());
            for d in [node.gemm.m, node.gemm.n, node.gemm.k] {
                bytes.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for slot in [Slot::A, Slot::B] {
                match node.source(slot) {
                    OperandSource::External => bytes.push(0),
                    OperandSource::Node(src) => {
                        bytes.push(1);
                        bytes.extend_from_slice(&(src.len() as u64).to_le_bytes());
                        bytes.extend_from_slice(src.as_bytes());
                    }
                }
            }
        }
        fnv1a(&bytes)
    }

    /// Lift a named chain into a graph: node `i` reads its A operand
    /// from node `i-1` whenever the shapes agree exactly (producer
    /// `m x n` equals consumer `m x k`); every other operand stays
    /// external. This is the honest dataflow approximation for model
    /// chains — activations flow layer to layer where the GEMM algebra
    /// permits, weights and reshaped attention intermediates arrive from
    /// the client.
    pub fn from_chain(chain: &[(String, Gemm)]) -> GemmGraph {
        let mut graph = GemmGraph::new();
        for (i, (name, gemm)) in chain.iter().enumerate() {
            let a = match i.checked_sub(1).map(|p| &chain[p]) {
                Some((prev_name, prev)) if edge_shape_error(prev, gemm, Slot::A).is_none() => {
                    OperandSource::Node(prev_name.clone())
                }
                _ => OperandSource::External,
            };
            graph = graph.push(name, *gemm, a, OperandSource::External);
        }
        graph
    }

    /// Graph of `n_layers` transformer blocks for `m` token rows: the
    /// per-block GEMMs of [`TransformerSpec::block_gemms`], chained
    /// within and across layers (node names are `L<i>.<gemm>`).
    pub fn transformer(spec: &TransformerSpec, m: usize, n_layers: usize) -> GemmGraph {
        let mut chain = Vec::new();
        for layer in 0..n_layers.max(1) {
            for (name, gemm) in spec.block_gemms(m) {
                chain.push((format!("L{layer}.{name}"), gemm));
            }
        }
        GemmGraph::from_chain(&chain)
    }

    /// Graph of one Swin stage block (proj -> mlp intermediates chained).
    pub fn swin(stage: &SwinStage) -> GemmGraph {
        GemmGraph::from_chain(&stage.block_gemms())
    }

    /// Graph of the NCF MLP tower — a fully chained funnel.
    pub fn ncf(batch: usize) -> GemmGraph {
        GemmGraph::from_chain(&ncf_gemms(batch))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::models::{deit_base, qwen25_05b, swin_tiny_stages};

    fn ext() -> OperandSource {
        OperandSource::External
    }

    fn edge(name: &str) -> OperandSource {
        OperandSource::Node(name.to_string())
    }

    #[test]
    fn diamond_validates_with_deterministic_topo_order() {
        // root -> (left, right) -> join: a classic diamond.
        let g = GemmGraph::new()
            .push("join", Gemm::new(8, 8, 8), edge("left"), edge("right"))
            .push("left", Gemm::new(8, 8, 8), edge("root"), ext())
            .push("right", Gemm::new(8, 8, 8), ext(), edge("root"))
            .push("root", Gemm::new(8, 8, 8), ext(), ext());
        let order = g.validate().expect("diamond is a DAG");
        // Kahn with lowest-index-first release: root(3) first, then the
        // ready set drains in index order (1=left, 2=right), then join.
        assert_eq!(order, vec![3, 1, 2, 0]);
        for _ in 0..10 {
            assert_eq!(g.validate().expect("stable"), order);
        }
        // Refcounts: root feeds two slots, left/right one each.
        assert_eq!(g.consumer_counts().expect("counts"), vec![0, 1, 1, 2]);
    }

    #[test]
    fn cycle_is_rejected() {
        let g = GemmGraph::new()
            .push("a", Gemm::new(8, 8, 8), edge("b"), ext())
            .push("b", Gemm::new(8, 8, 8), edge("a"), ext());
        let err = g.validate().expect_err("cycle must fail");
        assert!(err.contains("cycle"), "unexpected error: {err}");
        assert!(err.contains('a') && err.contains('b'));
        // Self-loop is the degenerate cycle.
        let g = GemmGraph::new().push("x", Gemm::new(8, 8, 8), edge("x"), ext());
        assert!(g.validate().expect_err("self loop").contains("cycle"));
    }

    #[test]
    fn duplicate_and_unknown_names_are_typed_errors() {
        let g = GemmGraph::new()
            .push("a", Gemm::new(8, 8, 8), ext(), ext())
            .push("a", Gemm::new(8, 8, 8), ext(), ext());
        assert!(g.validate().expect_err("dup").contains("duplicate"));
        let g = GemmGraph::new().push("a", Gemm::new(8, 8, 8), edge("ghost"), ext());
        let err = g.validate().expect_err("unknown");
        assert!(err.contains("unknown node `ghost`"), "got: {err}");
    }

    #[test]
    fn edge_shape_mismatch_is_rejected() {
        // Producer emits 8x8 but consumer's A slot needs 8x16 (k=16).
        let g = GemmGraph::new()
            .push("p", Gemm::new(8, 8, 8), ext(), ext())
            .push("c", Gemm::new(8, 8, 16), edge("p"), ext());
        let err = g.validate().expect_err("shape mismatch");
        assert!(err.contains("8x8") && err.contains("8x16"), "got: {err}");
        // Same producer into the B slot of a compatible consumer passes.
        let g = GemmGraph::new()
            .push("p", Gemm::new(8, 8, 8), ext(), ext())
            .push("c", Gemm::new(4, 8, 8), ext(), edge("p"));
        assert!(g.validate().is_ok());
    }

    #[test]
    fn operand_shape_validator_catches_k_mismatch() {
        let g = Gemm::new(4, 8, 16);
        assert!(operand_shape_error(&g, Some(4 * 16), Some(16 * 8)).is_none());
        // A sized for k=8 instead of 16: typed error naming the extent.
        let err = operand_shape_error(&g, Some(4 * 8), Some(16 * 8)).expect("bad A");
        assert!(err.contains("operand A") && err.contains("64"), "got: {err}");
        let err = operand_shape_error(&g, Some(4 * 16), Some(8 * 8)).expect("bad B");
        assert!(err.contains("operand B"), "got: {err}");
        // Absent operands are not this validator's business.
        assert!(operand_shape_error(&g, None, None).is_none());
    }

    #[test]
    fn ncf_funnel_chains_every_layer() {
        let g = GemmGraph::ncf(256);
        assert_eq!(g.len(), 3);
        let order = g.validate().expect("ncf chain");
        assert_eq!(order, vec![0, 1, 2]);
        // Every layer past the first consumes its predecessor's output.
        assert!(g.nodes[1].a == edge("mlp_l1") && g.nodes[2].a == edge("mlp_l2"));
        assert_eq!(g.external_slots().len(), 4); // l1's A + all three Bs
    }

    #[test]
    fn transformer_graphs_chain_within_and_across_layers() {
        // Gated (qwen): attn_out -> ffn_gate_up chains; ffn_down closes
        // the residual stream into the next layer's qkv_proj.
        let g = GemmGraph::transformer(&qwen25_05b(), 32, 2);
        assert_eq!(g.len(), 8);
        g.validate().expect("transformer graph is a DAG");
        assert_eq!(g.nodes[2].a, edge("L0.attn_out"));
        assert_eq!(g.nodes[4].a, edge("L0.ffn_down"));
        // Non-gated (deit): ffn_up additionally feeds ffn_down directly.
        let d = GemmGraph::transformer(&deit_base(), 197, 1);
        assert_eq!(d.index_of("L0.ffn_down").map(|i| &d.nodes[i].a), Some(&edge("L0.ffn_up")));
        // Repeated layers repeat shapes: that is what plan sharing keys on.
        assert_eq!(g.nodes[0].gemm, g.nodes[4].gemm);
    }

    #[test]
    fn swin_stage_graph_is_valid() {
        for stage in swin_tiny_stages() {
            let g = GemmGraph::swin(&stage);
            assert_eq!(g.len(), 4);
            g.validate().expect("swin stage");
        }
    }

    #[test]
    fn dag_hash_is_stable_and_structure_sensitive() {
        let g = GemmGraph::ncf(256);
        let h = g.dag_hash(0);
        assert_eq!(h, GemmGraph::ncf(256).dag_hash(0));
        assert_ne!(h, g.dag_hash(1), "objective must key the hash");
        assert_ne!(h, GemmGraph::ncf(128).dag_hash(0), "shapes must key the hash");
        let mut rewired = g.clone();
        rewired.nodes[1].a = OperandSource::External;
        assert_ne!(h, rewired.dag_hash(0), "wiring must key the hash");
    }
}
