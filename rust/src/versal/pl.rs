//! PL resource model: BRAM/URAM packing for the reuse buffers plus
//! LUT/FF/DSP for the stream/dataflow infrastructure.
//!
//! Buffer placement differs by framework (visible in Table III): CHARM's
//! generated designs keep operand buffers in BRAM (URAM column is 0 for
//! most workloads), while ARIES and our framework pack the deep operand
//! tiles URAM-first. The placement policy is therefore a parameter.

use crate::config::BoardConfig;
use crate::tiling::Tiling;

/// Absolute PL resource counts for one design.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Resources {
    pub bram: usize,
    pub uram: usize,
    pub lut: usize,
    pub ff: usize,
    pub dsp: usize,
}

/// Utilization as a fraction of the board totals (Table III reports %).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct ResourceUtil {
    pub bram: f64,
    pub uram: f64,
    pub lut: f64,
    pub ff: f64,
    pub dsp: f64,
}

impl Resources {
    pub fn utilization(&self, board: &BoardConfig) -> ResourceUtil {
        ResourceUtil {
            bram: self.bram as f64 / board.bram_total as f64,
            uram: self.uram as f64 / board.uram_total as f64,
            lut: self.lut as f64 / board.lut_total as f64,
            ff: self.ff as f64 / board.ff_total as f64,
            dsp: self.dsp as f64 / board.dsp_total as f64,
        }
    }

    pub fn fits(&self, board: &BoardConfig) -> bool {
        self.bram <= board.bram_total
            && self.uram <= board.uram_total
            && self.lut <= board.lut_total
            && self.ff <= board.ff_total
            && self.dsp <= board.dsp_total
    }

    /// Worst-dimension utilization (drives the build-failure model).
    pub fn max_utilization(&self, board: &BoardConfig) -> f64 {
        let u = self.utilization(board);
        u.bram.max(u.uram).max(u.lut).max(u.ff).max(u.dsp)
    }

    /// Vector view for the multi-output resource model
    /// (order: BRAM, URAM, LUT, FF, DSP — as percentages 0..100).
    pub fn as_percent_vec(&self, board: &BoardConfig) -> [f64; 5] {
        let u = self.utilization(board);
        [
            100.0 * u.bram,
            100.0 * u.uram,
            100.0 * u.lut,
            100.0 * u.ff,
            100.0 * u.dsp,
        ]
    }
}

/// Buffer placement policy of the generating framework.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferPlacement {
    /// Operand tiles in BRAM only (CHARM-style codegen).
    BramOnly,
    /// Deep operand tiles URAM-first, spill to BRAM (ARIES / ours).
    UramFirst,
}

/// Compute the full resource allocation of a design.
pub fn resources(t: &Tiling, board: &BoardConfig, placement: BufferPlacement) -> Resources {
    let buf = t.buffer_bytes(board.micro_tile);
    let n_aie = t.n_aie();

    // --- memory packing -------------------------------------------------
    // C tiles need read-modify-write ports => BRAM. A/B operand tiles are
    // streamed sequentially => URAM candidates under UramFirst.
    let (mut bram_bytes, mut uram_bytes) = match placement {
        BufferPlacement::BramOnly => (buf.a + buf.b + buf.c, 0usize),
        BufferPlacement::UramFirst => (buf.c, buf.a + buf.b),
    };
    // Tiny operand tiles are not worth a URAM bank: keep them in BRAM.
    if placement == BufferPlacement::UramFirst && uram_bytes < board.uram_bytes {
        bram_bytes += uram_bytes;
        uram_bytes = 0;
    }
    let mut uram = uram_bytes.div_ceil(board.uram_bytes);
    // Each buffer bank also needs minimum-width allocation per parallel
    // stream: one BRAM per AIE row/column port group.
    let mut bram = bram_bytes.div_ceil(board.bram_bytes) + (t.p_m * t.p_k + t.p_k * t.p_n).div_ceil(4);
    // Spill URAM overflow into BRAM (and vice versa) so big designs still
    // place if one pool is exhausted.
    if uram > board.uram_total {
        let spill = (uram - board.uram_total) * board.uram_bytes;
        uram = board.uram_total;
        bram += spill.div_ceil(board.bram_bytes);
    }
    if bram > board.bram_total && uram < board.uram_total {
        let spill = (bram - board.bram_total) * board.bram_bytes;
        bram = board.bram_total;
        uram += spill.div_ceil(board.uram_bytes);
    }

    // --- logic / dataflow infrastructure ---------------------------------
    // Stream splitters/mergers, DMA descriptors, address generators: a
    // fixed base plus per-AIE and per-buffer-bank terms (fit to the scale
    // of Table III).
    let lut = 9_000 + 420 * n_aie + 16 * (bram + uram);
    let ff = 11_000 + 540 * n_aie + 22 * (bram + uram);
    // Partial-sum adders on the PL when the cascade is cut (P_K chains),
    // plus per-stream address math.
    let dsp = 6 + t.p_m * t.p_n * t.p_k.saturating_sub(1) + n_aie / 2;

    Resources {
        bram,
        uram,
        lut,
        ff,
        dsp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> BoardConfig {
        BoardConfig::default()
    }

    #[test]
    fn small_design_fits_easily() {
        let t = Tiling::new((1, 1, 1), (1, 1, 1));
        let r = resources(&t, &board(), BufferPlacement::UramFirst);
        assert!(r.fits(&board()));
        assert!(r.max_utilization(&board()) < 0.05);
        assert_eq!(r.uram, 0); // tiny tiles stay in BRAM
    }

    #[test]
    fn bram_only_uses_no_uram() {
        // Moderate design: fits in BRAM alone, so no URAM spill occurs.
        let t = Tiling::new((8, 8, 4), (1, 2, 1));
        let r = resources(&t, &board(), BufferPlacement::BramOnly);
        assert_eq!(r.uram, 0);
        let r2 = resources(&t, &board(), BufferPlacement::UramFirst);
        assert!(r2.uram > 0);
        assert!(r2.bram < r.bram);
    }

    #[test]
    fn bram_only_spills_to_uram_when_exhausted() {
        // CHARM's biggest designs (Table III G10-G13) do show URAM use:
        // once BRAM is exhausted the packer spills.
        let t = Tiling::new((8, 8, 4), (4, 4, 1));
        let r = resources(&t, &board(), BufferPlacement::BramOnly);
        assert_eq!(r.bram, board().bram_total);
        assert!(r.uram > 0);
    }

    #[test]
    fn bigger_buffers_cost_more_memory() {
        let small = resources(
            &Tiling::new((8, 8, 4), (1, 1, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        let big = resources(
            &Tiling::new((8, 8, 4), (4, 8, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        assert!(big.bram + big.uram > small.bram + small.uram);
    }

    #[test]
    fn logic_scales_with_aies() {
        let few = resources(
            &Tiling::new((2, 2, 1), (1, 1, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        let many = resources(
            &Tiling::new((8, 8, 4), (1, 1, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        assert!(many.lut > few.lut);
        assert!(many.ff > few.ff);
        assert!(many.dsp > few.dsp);
    }

    #[test]
    fn cascade_cut_needs_dsp_adders() {
        let chained = resources(
            &Tiling::new((8, 8, 1), (1, 1, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        let cut = resources(
            &Tiling::new((8, 8, 4), (1, 1, 1)),
            &board(),
            BufferPlacement::UramFirst,
        );
        assert!(cut.dsp > chained.dsp);
    }

    #[test]
    fn percent_vec_order() {
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let r = resources(&t, &board(), BufferPlacement::UramFirst);
        let v = r.as_percent_vec(&board());
        assert!((v[0] - 100.0 * r.bram as f64 / 963.0).abs() < 1e-9);
        assert!((v[4] - 100.0 * r.dsp as f64 / 1968.0).abs() < 1e-9);
    }

    #[test]
    fn utilization_consistency() {
        let r = Resources {
            bram: 963,
            uram: 463,
            lut: 900_000,
            ff: 1_800_000,
            dsp: 1968,
        };
        let u = r.utilization(&board());
        assert!((u.bram - 1.0).abs() < 1e-12);
        assert!((u.dsp - 1.0).abs() < 1e-12);
        assert!(r.fits(&board()));
        assert_eq!(r.max_utilization(&board()), 1.0);
    }
}
