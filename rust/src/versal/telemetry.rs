//! BEAM-style power telemetry (paper §V: "each workload is executed for
//! 60 seconds, during which power data is collected via BEAM tool
//! running on Versal's System Controller").
//!
//! The simulator's [`crate::versal::Measurement`] carries the
//! steady-state mean; this module expands it into the *trace* a BEAM
//! session would log — launch ramp, steady phase with AR(1) supply
//! noise, and trailing idle — and the aggregation the paper applies
//! (window mean of total board power). Used by the offline-phase
//! example, the telemetry tests, and the `sweep` reporting.

use crate::util::rng::{fnv1a, Rng};
use crate::versal::Measurement;

/// A sampled power trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerTrace {
    /// Watts per sample.
    pub samples: Vec<f64>,
    /// Sampling period in seconds (BEAM default ~100 ms).
    pub period_s: f64,
}

impl PowerTrace {
    pub fn duration_s(&self) -> f64 {
        self.samples.len() as f64 * self.period_s
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Energy over the window (J).
    pub fn energy_j(&self) -> f64 {
        self.samples.iter().sum::<f64>() * self.period_s
    }

    /// Mean over the steady phase only (what the paper reports as the
    /// workload's power: ramp and tail excluded).
    pub fn steady_mean(&self) -> f64 {
        let n = self.samples.len();
        if n < 10 {
            return self.mean();
        }
        let lo = n / 10;
        let hi = n - n / 20;
        let window = &self.samples[lo..hi];
        window.iter().sum::<f64>() / window.len() as f64
    }
}

/// Parameters of the telemetry session.
#[derive(Debug, Clone, Copy)]
pub struct BeamSession {
    pub duration_s: f64,
    pub sample_rate_hz: f64,
    /// Idle board power before the kernel launches.
    pub idle_w: f64,
    /// AR(1) coefficient and noise scale of the supply regulation.
    pub ar_coeff: f64,
    pub noise_w: f64,
}

impl Default for BeamSession {
    fn default() -> Self {
        BeamSession {
            duration_s: 60.0,
            sample_rate_hz: 10.0,
            idle_w: 11.5,
            ar_coeff: 0.85,
            noise_w: 0.35,
        }
    }
}

impl BeamSession {
    /// Deterministically synthesize the trace a BEAM run of `m` would
    /// log. Keyed by `design_key` so re-measuring a design reproduces
    /// the same trace (as the simulator's noise model does).
    pub fn trace(&self, m: &Measurement, design_key: u64) -> PowerTrace {
        let n = (self.duration_s * self.sample_rate_hz).round() as usize;
        let mut rng = Rng::new(fnv1a(&design_key.to_le_bytes()) ^ 0xBEA0_BEA0);
        let mut samples = Vec::with_capacity(n);
        let ramp = (n / 20).max(1); // launch + clock ramp
        let tail = (n / 40).max(1); // drain + idle return
        let mut ar = 0.0f64;
        for i in 0..n {
            let phase = if i < ramp {
                // Exponential approach to the steady level.
                let x = i as f64 / ramp as f64;
                self.idle_w + (m.power_w - self.idle_w) * (1.0 - (-4.0 * x).exp())
            } else if i >= n - tail {
                self.idle_w + (m.power_w - self.idle_w) * 0.3
            } else {
                m.power_w
            };
            ar = self.ar_coeff * ar + self.noise_w * rng.normal();
            samples.push((phase + ar).max(0.0));
        }
        PowerTrace {
            samples,
            period_s: 1.0 / self.sample_rate_hz,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::versal::Resources;

    fn measurement(power: f64) -> Measurement {
        Measurement {
            latency_s: 1e-3,
            power_w: power,
            resources: Resources::default(),
            gflops: 100.0,
            energy_eff: 100.0 / power,
            busy: 0.9,
        }
    }

    #[test]
    fn steady_mean_recovers_measurement_power() {
        let session = BeamSession::default();
        let m = measurement(30.0);
        let trace = session.trace(&m, 42);
        assert_eq!(trace.samples.len(), 600);
        let err = (trace.steady_mean() - 30.0).abs();
        assert!(err < 0.5, "steady mean off by {err} W");
        // Plain mean is pulled down by ramp/tail.
        assert!(trace.mean() < trace.steady_mean());
    }

    #[test]
    fn trace_is_deterministic_per_design() {
        let session = BeamSession::default();
        let m = measurement(25.0);
        assert_eq!(session.trace(&m, 7), session.trace(&m, 7));
        assert_ne!(session.trace(&m, 7), session.trace(&m, 8));
    }

    #[test]
    fn ramp_starts_near_idle() {
        let session = BeamSession::default();
        let m = measurement(40.0);
        let trace = session.trace(&m, 1);
        assert!(trace.samples[0] < 20.0, "first sample {}", trace.samples[0]);
        assert!(trace.max() > 38.0);
    }

    #[test]
    fn energy_consistent_with_mean() {
        let session = BeamSession::default();
        let m = measurement(20.0);
        let trace = session.trace(&m, 3);
        let e = trace.energy_j();
        assert!((e - trace.mean() * trace.duration_s()).abs() < 1e-9);
        assert!((trace.duration_s() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn short_trace_falls_back_to_mean() {
        let t = PowerTrace {
            samples: vec![10.0, 12.0],
            period_s: 0.1,
        };
        assert_eq!(t.steady_mean(), t.mean());
        assert_eq!(t.min(), 10.0);
    }
}
