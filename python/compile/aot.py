"""AOT bridge: lower every GEMM variant to HLO **text** + manifest.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly.  See
/opt/xla-example/gen_hlo.py.

Usage:  python -m compile.aot --out-dir ../artifacts
Writes one ``<name>.hlo.txt`` per variant plus ``manifest.json`` with the
shape/dtype contract the Rust runtime validates against.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
from jax._src.lib import xla_client as xc

from compile.model import ARTIFACT_VARIANTS, lower_variant

MANIFEST_VERSION = 1


def to_hlo_text(lowered: jax.stages.Lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (id-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    entries = []
    for variant in ARTIFACT_VARIANTS:
        text = to_hlo_text(lower_variant(variant))
        fname = f"{variant.name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        entries.append(
            {
                "name": variant.name,
                "file": fname,
                "m": variant.m,
                "n": variant.n,
                "k": variant.k,
                "block_m": variant.block_m,
                "block_n": variant.block_n,
                "block_k": variant.block_k,
                "dtype": "f32",
                "flops": variant.flops,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
                "bytes": len(text),
            }
        )
        print(f"  wrote {fname}: {len(text)} chars")
    manifest = {
        "version": MANIFEST_VERSION,
        "jax_version": jax.__version__,
        "variants": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote manifest.json ({len(entries)} variants)")
    return manifest


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    args = parser.parse_args()
    build_all(args.out_dir)


if __name__ == "__main__":
    main()
