//! Backend-equivalence suite: the pluggable execution backends must
//! agree with the reference GEMM, `auto` selection must fall back to
//! the CPU backend whenever PJRT artifacts are absent (the default in
//! CI and offline checkouts), and every executed job's energy
//! accounting must be finite and internally consistent.

use std::sync::Arc;

use versal_gemm::config::Config;
use versal_gemm::coordinator::{
    BackendChoice, Coordinator, CoordinatorOptions, GemmJob, JobResult,
};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::{DseEngine, DsePool, Objective};
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::runtime::backend::{CpuBackend, ExecBackend, SimBackend};
use versal_gemm::runtime::microkernel::KernelProfile;
use versal_gemm::runtime::{matmul_ref, max_abs_diff};
use versal_gemm::util::forall;
use versal_gemm::util::rng::Rng;
use versal_gemm::versal::VersalSim;
use versal_gemm::workloads::{training_workloads, Gemm};

fn quick_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.dataset.top_k = 10;
    cfg.dataset.bottom_k = 6;
    cfg.dataset.random_k = 30;
    cfg.train.n_trees = 60;
    cfg.train.learning_rate = 0.2;
    cfg
}

fn dse_engine(cfg: &Config) -> DseEngine {
    let wl: Vec<_> = training_workloads().into_iter().take(4).collect();
    let ds = Dataset::generate(cfg, &wl);
    DseEngine::new(Predictors::train(&ds, cfg, FeatureSet::SetIAndII), &cfg.board)
}

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

/// Assert the energy triple is present, finite, and mutually
/// consistent: `energy_j ≈ avg_power_w * exec_time` and
/// `gflops_per_w ≈ executed GFLOP/s ÷ avg power`.
fn assert_energy_consistent(r: &JobResult) {
    let exec = r.exec_time.expect("executed").as_secs_f64();
    assert!(exec > 0.0);
    let energy = r.energy_j.expect("energy_j");
    let avg_w = r.avg_power_w.expect("avg_power_w");
    let gpw = r.gflops_per_w.expect("gflops_per_w");
    assert!(energy.is_finite() && energy > 0.0, "energy {energy}");
    assert!(avg_w.is_finite() && avg_w > 0.0, "avg power {avg_w}");
    assert!(gpw.is_finite() && gpw > 0.0, "gflops/W {gpw}");
    let drift = (energy - avg_w * exec).abs() / energy;
    assert!(drift < 1e-9, "energy {energy} != {avg_w} W * {exec} s ({drift})");
    let want_gpw = r.gemm.flops() / exec / 1e9 / avg_w;
    assert!(
        (gpw - want_gpw).abs() / want_gpw < 1e-9,
        "gflops_per_w {gpw} != {want_gpw}"
    );
}

#[test]
fn cpu_backend_tolerance_matches_reference_across_uneven_shapes() {
    // Non-multiples of the 64-tile, degenerate m=1 / n=1 / k=1 edges,
    // and shapes that span several row panels.
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(2024);
    for (m, n, k) in [
        (1, 1, 1),
        (1, 33, 7),
        (97, 1, 5),
        (70, 50, 90),
        (63, 65, 64),
        (1, 896, 896),
        (130, 257, 66),
        (197, 128, 1),
    ] {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let got = cpu.gemm(&a, &b, m, n, k).unwrap();
        let want = matmul_ref(&a, &b, m, n, k);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-3, "{m}x{n}x{k}: err {err}");
    }
}

#[test]
fn cpu_backend_bit_identical_across_pool_widths_and_exact_on_integers() {
    // Integer-valued operands make the blocked accumulation exact, so
    // the backend must *bit*-match the reference, at every pool width.
    let (m, n, k) = (200, 96, 131);
    let a: Vec<f32> = (0..m * k).map(|i| ((i * 7 % 13) as f32) - 6.0).collect();
    let b: Vec<f32> = (0..k * n).map(|i| ((i * 5 % 11) as f32) - 5.0).collect();
    let want = matmul_ref(&a, &b, m, n, k);
    for width in [1usize, 2, 8] {
        let cpu = CpuBackend::new().with_pool(Arc::new(DsePool::new(width)));
        let got = cpu.gemm(&a, &b, m, n, k).unwrap();
        assert_eq!(got, want, "width {width}");
    }
}

/// Dimension pool for the packed-GEMM property tests: degenerate 1s,
/// primes, and values straddling the MR/NR (8), KC, and MC block
/// boundaries of every kernel profile.
const DIM_POOL: [usize; 12] = [1, 3, 7, 13, 31, 65, 97, 127, 129, 131, 200, 257];

fn pick_shape(rng: &mut Rng) -> (usize, usize, usize) {
    (
        DIM_POOL[rng.below(DIM_POOL.len())],
        DIM_POOL[rng.below(DIM_POOL.len())],
        DIM_POOL[rng.below(DIM_POOL.len())],
    )
}

/// Integer-valued f32 operands in [-6, 6]: every product and partial
/// sum is an integer well below 2^24, so GEMM is exact and any two
/// correct evaluation orders must agree to the bit.
fn randi(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.below(13) as f32) - 6.0).collect()
}

/// Forward-error bound for a k-term f32 dot product: per-element
/// tolerance `k · eps · Σ|a||b| + MIN_POSITIVE`, i.e. ulp-scaled to the
/// operand magnitude rather than a fixed absolute epsilon.
fn assert_within_ulp_bound(got: &[f32], a: &[f32], b: &[f32], m: usize, n: usize, k: usize) {
    let want = matmul_ref(a, b, m, n, k);
    let aa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
    let ab: Vec<f32> = b.iter().map(|v| v.abs()).collect();
    let bound = matmul_ref(&aa, &ab, m, n, k);
    for (i, ((g, w), s)) in got.iter().zip(&want).zip(&bound).enumerate() {
        let tol = (k as f32) * f32::EPSILON * s + f32::MIN_POSITIVE;
        assert!((g - w).abs() <= tol, "{m}x{n}x{k} element {i}: got {g} want {w} (tol {tol})");
    }
}

#[test]
fn packed_gemm_property_matches_reference_within_ulp_bound() {
    // Property: for any shape drawn from the boundary-heavy dimension
    // pool (m/n/k = 1, primes, non-multiples of MR/NR/KC), the packed
    // three-level pipeline stays within the k·eps forward-error bound
    // of the naive reference — under both the smallest and largest
    // blocking profiles so pack-time padding edges are exercised.
    for profile in [KernelProfile::l2_small(), KernelProfile::l2_large()] {
        let cpu = CpuBackend::new().with_profile(profile);
        forall(4242, 16, pick_shape, |&(m, n, k)| {
            let mut rng = Rng::new((m * 1_000_003 + n * 1009 + k) as u64);
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let got = cpu.gemm(&a, &b, m, n, k).unwrap();
            assert_within_ulp_bound(&got, &a, &b, m, n, k);
        });
    }
}

#[test]
fn packed_gemm_property_bit_identical_across_pool_widths() {
    // Property: the (jc, pc, ic) work decomposition is fixed by shape
    // and profile, never by thread count, so integer operands (exact
    // arithmetic) must give *bit*-identical results at every width.
    // l2-small blocking makes even modest shapes span several MC/KC/NC
    // blocks so the fan-out path really runs.
    let profile = KernelProfile::l2_small();
    forall(
        7171,
        6,
        |rng| {
            let (m, n, k) = pick_shape(rng);
            (m + 64, n + 32, k + 64) // shift up: cross MC/KC boundaries
        },
        |&(m, n, k)| {
            let mut rng = Rng::new((m * 31 + n * 17 + k) as u64);
            let a = randi(&mut rng, m * k);
            let b = randi(&mut rng, k * n);
            let base = CpuBackend::new()
                .with_profile(profile)
                .with_pool(Arc::new(DsePool::new(1)))
                .gemm(&a, &b, m, n, k)
                .unwrap();
            assert_eq!(base, matmul_ref(&a, &b, m, n, k), "{m}x{n}x{k} vs ref");
            for width in [2usize, 8] {
                let got = CpuBackend::new()
                    .with_profile(profile)
                    .with_pool(Arc::new(DsePool::new(width)))
                    .gemm(&a, &b, m, n, k)
                    .unwrap();
                assert_eq!(got, base, "{m}x{n}x{k} at width {width}");
            }
        },
    );
}

#[test]
fn packed_gemm_property_profiles_agree_bitwise_on_integer_operands() {
    // Property: blocking profiles reorder the loop nest but never the
    // per-element accumulation order over k, so on exact (integer)
    // operands generic and l2-large — opposite ends of the blocking
    // spectrum — must agree to the bit, and both with the reference.
    forall(9090, 10, pick_shape, |&(m, n, k)| {
        let mut rng = Rng::new((m * 131 + n * 13 + k) as u64);
        let a = randi(&mut rng, m * k);
        let b = randi(&mut rng, k * n);
        let want = matmul_ref(&a, &b, m, n, k);
        for profile in [KernelProfile::generic(), KernelProfile::l2_large()] {
            let got = CpuBackend::new()
                .with_profile(profile)
                .gemm(&a, &b, m, n, k)
                .unwrap();
            assert_eq!(got, want, "{m}x{n}x{k} profile {}", profile.name);
        }
    });
}

#[test]
fn auto_selection_falls_back_to_cpu_when_artifacts_are_absent() {
    // The acceptance case: artifacts directory configured but missing
    // (every CI/offline checkout) — the data job must complete via the
    // CPU backend with full energy accounting, not die with "no
    // artifact engine".
    let cfg = quick_cfg();
    let missing = std::env::temp_dir().join("versal_gemm_no_such_artifacts");
    let _ = std::fs::remove_dir_all(&missing);
    let mut coord = Coordinator::start(&cfg, dse_engine(&cfg), Some(missing), 2);
    let g = Gemm::new(96, 160, 64);
    let mut rng = Rng::new(5);
    let a = randn(&mut rng, g.m * g.k);
    let b = randn(&mut rng, g.k * g.n);
    let mut job = GemmJob::with_data(0, g, Objective::EnergyEfficiency, a.clone(), b.clone());
    job.validate = true;
    let results = coord.run_batch(vec![job]);
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert!(r.error.is_none(), "auto fallback failed: {:?}", r.error);
    assert_eq!(coord.backend_name(), "cpu");
    assert!(r.plan.is_some());
    assert!(r.validation_err.expect("validated") < 1e-3);
    assert_eq!(r.c.as_deref().map(|c| c.len()), Some(g.m * g.n));
    assert_energy_consistent(r);
    let s = coord.stats();
    assert_eq!((s.executed_jobs, s.jobs_completed), (1, 1));
    assert!(s.executed_energy_j > 0.0 && s.executed_gflops_per_w > 0.0);
}

#[test]
fn executed_energy_fields_consistent_across_a_batch() {
    let cfg = quick_cfg();
    let opts = CoordinatorOptions {
        backend: BackendChoice::Cpu,
        ..CoordinatorOptions::default()
    };
    let mut coord = Coordinator::start_with(&cfg, dse_engine(&cfg), None, 2, opts);
    let mut rng = Rng::new(7);
    let jobs: Vec<GemmJob> = (0..6u64)
        .map(|i| {
            let g = Gemm::new(64 * (1 + i as usize % 3), 128, 96);
            let a = randn(&mut rng, g.m * g.k);
            let b = randn(&mut rng, g.k * g.n);
            GemmJob::with_data(i, g, Objective::Throughput, a, b)
        })
        .collect();
    let results = coord.run_batch(jobs);
    assert_eq!(results.len(), 6);
    let mut total_energy = 0.0;
    for r in &results {
        assert!(r.error.is_none(), "job {}: {:?}", r.id, r.error);
        assert_energy_consistent(r);
        total_energy += r.energy_j.unwrap();
    }
    let s = coord.stats();
    assert!((s.executed_energy_j - total_energy).abs() / total_energy < 1e-9);
    assert!(s.executed_gflops_per_w > 0.0);
}

#[test]
fn sim_backend_serves_plan_quality_measurements() {
    // `--backend sim`: numerics via the CPU path, but exec_time/power
    // are the simulated VCK190 measurement of the selected mapping.
    let cfg = quick_cfg();
    let opts = CoordinatorOptions {
        backend: BackendChoice::Sim,
        ..CoordinatorOptions::default()
    };
    let mut coord = Coordinator::start_with(&cfg, dse_engine(&cfg), None, 2, opts);
    let g = Gemm::new(256, 512, 256);
    let mut rng = Rng::new(11);
    let a = randn(&mut rng, g.m * g.k);
    let b = randn(&mut rng, g.k * g.n);
    let mut job = GemmJob::with_data(0, g, Objective::Throughput, a, b);
    job.validate = true;
    let results = coord.run_batch(vec![job]);
    assert_eq!(results.len(), 1);
    let r = &results[0];
    assert!(r.error.is_none(), "sim backend failed: {:?}", r.error);
    assert_eq!(coord.backend_name(), "sim");
    assert!(r.validation_err.expect("validated") < 1e-3);
    assert_energy_consistent(r);
    // The stamped execution time is the plan's simulated board latency,
    // not host wall-clock.
    let plan = r.plan.expect("plan");
    let exec = r.exec_time.unwrap().as_secs_f64();
    let sim = VersalSim::new(&cfg);
    let mea = sim
        .evaluate(
            &g,
            &plan.tiling,
            versal_gemm::versal::BufferPlacement::UramFirst,
        )
        .expect("plan was buildable");
    // Duration has ns resolution, so allow the rounding of
    // from_secs_f64 on a ~100 µs latency.
    assert!(
        (exec - mea.latency_s).abs() / mea.latency_s < 1e-4,
        "exec {exec} != simulated latency {}",
        mea.latency_s
    );
    assert!((r.avg_power_w.unwrap() - mea.power_w).abs() / mea.power_w < 0.25);
}

#[test]
fn sim_backend_direct_trait_surface() {
    let cfg = quick_cfg();
    let sim = SimBackend::new(VersalSim::new(&cfg));
    assert_eq!(sim.name(), "sim");
    assert!(sim.supports(&Gemm::new(64, 64, 64)));
    let mut rng = Rng::new(13);
    let (m, n, k) = (64, 70, 33);
    let a = randn(&mut rng, m * k);
    let b = randn(&mut rng, k * n);
    let got = sim.gemm(&a, &b, m, n, k).unwrap();
    assert!(max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k)) < 1e-3);
}
