//! Online phase: ML-driven design space exploration (paper §IV-B).
//!
//! Given a GEMM and an objective, the engine enumerates every tiling
//! configuration, computes Set-II features, batch-predicts
//! `{𝓛, 𝓟, 𝓡}` with the pretrained models, filters configurations that
//! do not fit the PL, extracts the Pareto front on the
//! (throughput, energy-efficiency) plane, and returns the best mapping
//! for the requested objective. Paper: "less than 2 sec. per workload".
//!
//! [`ExhaustiveExplorer`] is the ground-truth twin used for Fig. 4 / 10:
//! it measures every candidate on the simulator instead of predicting.

pub mod compare;

use crate::metrics::{hypervolume_2d, pareto_front_max};
use crate::models::{Prediction, Predictors};
use crate::tiling::{enumerate_candidates, Tiling, TilingLimits};
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::Gemm;

/// Optimization objective of the online phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Throughput,
    EnergyEfficiency,
}

impl Objective {
    pub fn label(&self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::EnergyEfficiency => "energy-eff",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Objective> {
        match text {
            "throughput" | "thr" | "perf" => Ok(Objective::Throughput),
            "energy" | "energy-eff" | "eff" => Ok(Objective::EnergyEfficiency),
            other => anyhow::bail!("unknown objective `{other}` (throughput|energy)"),
        }
    }
}

/// One candidate with its predicted metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateEval {
    pub tiling: Tiling,
    pub prediction: Prediction,
    pub gflops: f64,
    pub energy_eff: f64,
}

/// Result of one DSE run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub gemm: Gemm,
    /// Number of enumerated candidates (|C(G)|).
    pub n_candidates: usize,
    /// Candidates surviving the resource filter.
    pub n_feasible: usize,
    /// Predicted Pareto front (throughput x energy-eff, maximization).
    pub pareto: Vec<CandidateEval>,
    /// Every feasible candidate (resource-filtered), unordered.
    pub feasible: Vec<CandidateEval>,
    pub best_throughput: CandidateEval,
    pub best_energy: CandidateEval,
    pub elapsed: std::time::Duration,
}

impl DseResult {
    pub fn select(&self, objective: Objective) -> &CandidateEval {
        match objective {
            Objective::Throughput => &self.best_throughput,
            Objective::EnergyEfficiency => &self.best_energy,
        }
    }

    /// All feasible candidates, best-first by the objective — the retry
    /// order when a selected design fails to build.
    pub fn ranked(&self, objective: Objective) -> Vec<CandidateEval> {
        let mut out = self.feasible.clone();
        out.sort_by(|a, b| {
            let (ka, kb) = match objective {
                Objective::Throughput => (a.gflops, b.gflops),
                Objective::EnergyEfficiency => (a.energy_eff, b.energy_eff),
            };
            kb.partial_cmp(&ka).unwrap()
        });
        out
    }
}

/// The ML-driven DSE engine.
#[derive(Debug, Clone)]
pub struct DseEngine {
    pub predictors: Predictors,
    pub limits: TilingLimits,
    pub micro: usize,
    /// Safety margin (percent) on predicted resource utilization —
    /// absorbs 𝓡-model error so selected designs actually build.
    pub resource_margin_pct: f64,
}

impl DseEngine {
    pub fn new(predictors: Predictors, board: &crate::config::BoardConfig) -> DseEngine {
        DseEngine {
            predictors,
            limits: TilingLimits::from_board(board),
            micro: board.micro_tile,
            resource_margin_pct: 4.0,
        }
    }

    /// Featurize + predict + resource-filter a candidate slice.
    /// Parallelized across threads for large spaces (the DSE hot path:
    /// ~1350 tree traversals per candidate over up to ~25k candidates).
    fn evaluate_candidates(&self, g: &Gemm, candidates: &[Tiling]) -> Vec<CandidateEval> {
        let n_threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(8);
        let chunk_work = |chunk: &[Tiling]| -> Vec<CandidateEval> {
            let mut out = Vec::with_capacity(chunk.len());
            let n_feat = self.predictors.feature_set.len();
            for t in chunk {
                let full = crate::features::featurize(g, t, self.micro);
                let prediction = self.predictors.predict_row(&full[..n_feat]);
                if !prediction.fits(self.resource_margin_pct) {
                    continue;
                }
                out.push(CandidateEval {
                    tiling: *t,
                    prediction,
                    gflops: prediction.gflops(g),
                    energy_eff: prediction.energy_eff(g),
                });
            }
            out
        };
        if candidates.len() < 2048 || n_threads <= 1 {
            return chunk_work(candidates);
        }
        let chunk_size = candidates.len().div_ceil(n_threads);
        std::thread::scope(|scope| {
            let handles: Vec<_> = candidates
                .chunks(chunk_size)
                .map(|chunk| scope.spawn(move || chunk_work(chunk)))
                .collect();
            let mut out = Vec::with_capacity(candidates.len() / 2);
            for h in handles {
                out.extend(h.join().expect("dse worker panicked"));
            }
            out
        })
    }

    /// Run the full online phase for one workload.
    pub fn explore(&self, g: &Gemm) -> anyhow::Result<DseResult> {
        let start = std::time::Instant::now();
        let candidates = enumerate_candidates(g, self.micro, &self.limits);
        let n_candidates = candidates.len();
        if n_candidates == 0 {
            anyhow::bail!("no tiling candidates for {}", g.label());
        }

        let feasible = self.evaluate_candidates(g, &candidates);
        if feasible.is_empty() {
            anyhow::bail!("no feasible design for {}", g.label());
        }

        let best_throughput = *feasible
            .iter()
            .max_by(|a, b| a.gflops.partial_cmp(&b.gflops).unwrap())
            .unwrap();
        let best_energy = *feasible
            .iter()
            .max_by(|a, b| a.energy_eff.partial_cmp(&b.energy_eff).unwrap())
            .unwrap();
        let pareto = pareto_candidates(&feasible);

        Ok(DseResult {
            gemm: *g,
            n_candidates,
            n_feasible: feasible.len(),
            pareto,
            feasible,
            best_throughput,
            best_energy,
            elapsed: start.elapsed(),
        })
    }
}

/// The best design that actually builds on the simulator, walking the
/// ranked list (absorbs resource-model error — the real flow re-runs
/// codegen on the next candidate after a failed bitstream).
pub fn best_buildable(
    r: &DseResult,
    sim: &VersalSim,
    g: &Gemm,
    objective: Objective,
) -> Option<(CandidateEval, Measurement)> {
    r.ranked(objective).into_iter().take(64).find_map(|c| {
        sim.evaluate(g, &c.tiling, BufferPlacement::UramFirst)
            .ok()
            .map(|m| (c, m))
    })
}

/// Epsilon-relaxed Pareto front: keeps every candidate not dominated by
/// a strict-front member with margin `eps` on BOTH axes. Prediction
/// error collapses many truly-Pareto designs onto near-misses; the
/// relaxed front (paper's "set with candidate GEMM mappings") recovers
/// them for Fig. 10-style frontier construction.
pub fn epsilon_pareto(cands: &[CandidateEval], eps: f64, cap: usize) -> Vec<CandidateEval> {
    let front = pareto_candidates(cands);
    let mut out: Vec<CandidateEval> = cands
        .iter()
        .filter(|c| {
            !front.iter().any(|f| {
                f.gflops >= c.gflops * (1.0 + eps)
                    && f.energy_eff >= c.energy_eff * (1.0 + eps)
            })
        })
        .copied()
        .collect();
    out.sort_by(|a, b| b.gflops.partial_cmp(&a.gflops).unwrap());
    out.truncate(cap);
    out
}

/// Extract the Pareto-optimal subset of candidate evaluations.
pub fn pareto_candidates(cands: &[CandidateEval]) -> Vec<CandidateEval> {
    let mut idx: Vec<usize> = (0..cands.len()).collect();
    idx.sort_by(|&a, &b| {
        cands[b]
            .gflops
            .partial_cmp(&cands[a].gflops)
            .unwrap()
            .then(cands[b].energy_eff.partial_cmp(&cands[a].energy_eff).unwrap())
    });
    let mut front = Vec::new();
    let mut best_eff = f64::NEG_INFINITY;
    for i in idx {
        if cands[i].energy_eff > best_eff {
            front.push(cands[i]);
            best_eff = cands[i].energy_eff;
        }
    }
    front
}

/// Ground-truth exploration: measure every candidate on the simulator
/// (the paper's "actual Pareto front from exhaustive experiments").
#[derive(Debug, Clone)]
pub struct ExhaustiveExplorer {
    pub sim: VersalSim,
    pub limits: TilingLimits,
    pub placement: BufferPlacement,
}

impl ExhaustiveExplorer {
    pub fn new(sim: VersalSim) -> ExhaustiveExplorer {
        let limits = TilingLimits::from_board(&sim.board);
        ExhaustiveExplorer {
            sim,
            limits,
            placement: BufferPlacement::UramFirst,
        }
    }

    /// All buildable designs with their measurements.
    pub fn explore(&self, g: &Gemm) -> Vec<(Tiling, Measurement)> {
        enumerate_candidates(g, self.sim.board.micro_tile, &self.limits)
            .into_iter()
            .filter_map(|t| self.sim.evaluate(g, &t, self.placement).ok().map(|m| (t, m)))
            .collect()
    }

    pub fn best_by(&self, g: &Gemm, objective: Objective) -> Option<(Tiling, Measurement)> {
        self.explore(g).into_iter().max_by(|a, b| {
            let ka = match objective {
                Objective::Throughput => a.1.gflops,
                Objective::EnergyEfficiency => a.1.energy_eff,
            };
            let kb = match objective {
                Objective::Throughput => b.1.gflops,
                Objective::EnergyEfficiency => b.1.energy_eff,
            };
            ka.partial_cmp(&kb).unwrap()
        })
    }

    /// The true Pareto front as (throughput, energy-eff) points.
    pub fn true_front(&self, g: &Gemm) -> Vec<(f64, f64)> {
        let pts: Vec<(f64, f64)> = self
            .explore(g)
            .iter()
            .map(|(_, m)| (m.gflops, m.energy_eff))
            .collect();
        pareto_front_max(&pts)
    }
}

/// Hypervolume of a set of measured designs against a reference scale
/// (Fig. 10's quality metric).
pub fn measured_hypervolume(points: &[(f64, f64)], scale: (f64, f64)) -> f64 {
    hypervolume_2d(points, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::dataset::Dataset;
    use crate::features::FeatureSet;
    use crate::workloads::training_workloads;

    fn quick_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 12;
        cfg.dataset.bottom_k = 8;
        cfg.dataset.random_k = 60;
        cfg.train.n_trees = 100;
        cfg.train.learning_rate = 0.15;
        cfg
    }

    fn engine(cfg: &Config) -> DseEngine {
        let wl: Vec<_> = training_workloads().into_iter().take(6).collect();
        let ds = Dataset::generate(cfg, &wl);
        let predictors = Predictors::train(&ds, cfg, FeatureSet::SetIAndII);
        DseEngine::new(predictors, &cfg.board)
    }

    #[test]
    fn explore_returns_consistent_result() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(512, 1024, 768);
        let r = eng.explore(&g).unwrap();
        assert!(r.n_candidates > 100);
        assert!(r.n_feasible > 0 && r.n_feasible <= r.n_candidates);
        assert!(!r.pareto.is_empty());
        // Objective winners lie on the Pareto front extremes.
        assert!(r.best_throughput.gflops >= r.pareto.iter().map(|c| c.gflops).fold(0.0, f64::max) - 1e-9);
        assert!(
            r.best_energy.energy_eff
                >= r.pareto.iter().map(|c| c.energy_eff).fold(0.0, f64::max) - 1e-9
        );
        assert_eq!(r.select(Objective::Throughput).tiling, r.best_throughput.tiling);
    }

    #[test]
    fn dse_under_two_seconds() {
        // Paper §V-A: DSE with the ML model takes < 2 s per workload.
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(1024, 4864, 896); // large candidate space
        let r = eng.explore(&g).unwrap();
        assert!(
            r.elapsed.as_secs_f64() < 2.0,
            "DSE took {:?} for {} candidates",
            r.elapsed,
            r.n_candidates
        );
    }

    #[test]
    fn pareto_front_is_nondominated_and_sorted() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let r = eng.explore(&Gemm::new(256, 2048, 512)).unwrap();
        let front = &r.pareto;
        for i in 0..front.len() {
            for j in 0..front.len() {
                if i == j {
                    continue;
                }
                let dominates = front[j].gflops >= front[i].gflops
                    && front[j].energy_eff >= front[i].energy_eff
                    && (front[j].gflops > front[i].gflops
                        || front[j].energy_eff > front[i].energy_eff);
                assert!(!dominates, "front member {i} dominated by {j}");
            }
        }
    }

    #[test]
    fn exhaustive_best_matches_objective() {
        let cfg = quick_cfg();
        let ex = ExhaustiveExplorer::new(VersalSim::new(&cfg));
        let g = Gemm::new(224, 768, 768);
        let all = ex.explore(&g);
        assert!(all.len() > 50);
        let (_, thr) = ex.best_by(&g, Objective::Throughput).unwrap();
        let (_, eff) = ex.best_by(&g, Objective::EnergyEfficiency).unwrap();
        for (_, m) in &all {
            assert!(m.gflops <= thr.gflops + 1e-9);
            assert!(m.energy_eff <= eff.energy_eff + 1e-9);
        }
    }

    #[test]
    fn ml_selection_close_to_true_optimum() {
        // The point of the paper: ML-selected designs land near the true
        // best (analytical selections often do not).
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let ex = ExhaustiveExplorer::new(VersalSim::new(&cfg));
        let g = Gemm::new(512, 768, 768); // near training distribution
        let r = eng.explore(&g).unwrap();
        let sim = VersalSim::new(&cfg);
        let measured = sim
            .evaluate(&g, &r.best_throughput.tiling, BufferPlacement::UramFirst)
            .unwrap();
        let (_, true_best) = ex.best_by(&g, Objective::Throughput).unwrap();
        let ratio = measured.gflops / true_best.gflops;
        assert!(ratio > 0.7, "ML pick at {ratio:.2} of true optimum");
    }
}
