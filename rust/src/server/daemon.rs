//! The serving daemon: one nonblocking accept/tick loop that owns a
//! [`Coordinator`] and speaks [`super::protocol`] to any number of
//! clients.
//!
//! Lifecycle state machine (DESIGN.md §4):
//!
//! ```text
//!   start ──▶ ready ──▶ draining ──▶ stopped
//!              │            ▲
//!              └── DRAIN/SHUTDOWN frame, SIGINT, or SIGTERM
//! ```
//!
//! * **ready** — submits admitted, results streamed as they complete.
//! * **draining** — admission closed ([`Coordinator::begin_drain`]);
//!   queued-but-unsubmitted specs are refused with error results;
//!   in-flight jobs run to completion. Once quiescent the plan cache is
//!   persisted and every DRAIN waiter gets a `Drained` frame — this path
//!   also serves SIGINT/SIGTERM, so an interrupted daemon persists its
//!   cache and reports honest final stats instead of dying mid-flight.
//! * **stopped** — socket closed, state file removed, process exits.
//!
//! Backpressure maps client traffic onto the coordinator's
//! `QueueGauge`: under `Admission::Block` the daemon defers submits
//! while the queue is full *and* stops reading any connection whose
//! spec backlog exceeds [`MAX_PENDING_SUBMITS`] — the kernel socket
//! buffer fills and the client's writes block, end to end. Under
//! `Admission::Reject` specs are submitted eagerly and refusals come
//! back as error results over the wire.
//!
//! A client that disconnects mid-stream loses nothing but its own
//! result delivery: its in-flight jobs complete on the coordinator
//! (plans land in the cache for everyone else) and the undeliverable
//! results are counted in `results_dropped`.

use std::collections::{HashMap, VecDeque};
use std::io::{Read, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant, SystemTime, UNIX_EPOCH};

use crate::config::Config;
use crate::coordinator::{Admission, Coordinator, CoordinatorOptions};
use crate::dse::DseEngine;

use crate::util::backoff;

use super::protocol::{
    encode_frame, Frame, FrameReader, GraphSpec, JobSpec, WireGraphResult, WireResult, WireStats,
};
use super::state::{self, StateFile};
use super::{Endpoint, Listener, NetStream};

/// Per-connection cap on decoded-but-unsubmitted specs; beyond it the
/// daemon stops reading that socket (client-side backpressure).
pub const MAX_PENDING_SUBMITS: usize = 64;

/// Read chunk per connection per tick.
const READ_BUF: usize = 64 << 10;

/// How the daemon is wired together.
#[derive(Debug, Clone)]
pub struct DaemonOptions {
    pub endpoint: Endpoint,
    /// Directory for the state file, log, and default plan cache.
    pub state_dir: PathBuf,
    pub coordinator: CoordinatorOptions,
    pub n_planners: usize,
    pub artifacts: Option<PathBuf>,
    /// Tick period of the accept/pump loop.
    pub tick: Duration,
    /// Rotate the daemon log once it reaches this size.
    pub log_rotate_bytes: u64,
    /// Take over from a live daemon (SIGTERM it) instead of refusing.
    pub force: bool,
}

impl DaemonOptions {
    pub fn new(endpoint: Endpoint, state_dir: PathBuf) -> DaemonOptions {
        DaemonOptions {
            endpoint,
            state_dir,
            coordinator: CoordinatorOptions::default(),
            n_planners: 2,
            artifacts: None,
            tick: Duration::from_millis(2),
            log_rotate_bytes: 1 << 20,
            force: false,
        }
    }

    pub fn state_file_path(&self) -> PathBuf {
        self.state_dir.join("daemon.json")
    }

    pub fn log_path(&self) -> PathBuf {
        self.state_dir.join("daemon.log")
    }
}

/// Daemon lifecycle position (the wire `stats.state` field).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DaemonState {
    Ready,
    Draining,
    Stopped,
}

impl DaemonState {
    pub fn label(&self) -> &'static str {
        match self {
            DaemonState::Ready => "ready",
            DaemonState::Draining => "draining",
            DaemonState::Stopped => "stopped",
        }
    }
}

/// Final accounting returned by [`Daemon::run`].
#[derive(Debug, Clone, Copy)]
pub struct DaemonSummary {
    pub uptime: Duration,
    pub jobs_submitted: u64,
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub results_dropped: u64,
}

/// Size-rotating line logger (mirrors to stderr).
pub struct Logger {
    path: PathBuf,
    max_bytes: u64,
}

impl Logger {
    pub fn new(path: PathBuf, max_bytes: u64) -> Logger {
        Logger {
            path,
            max_bytes: max_bytes.max(1),
        }
    }

    pub fn log(&self, line: &str) {
        if let Ok(md) = std::fs::metadata(&self.path) {
            if md.len() >= self.max_bytes {
                let _ = std::fs::rename(&self.path, self.path.with_extension("log.1"));
            }
        }
        let ts = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        if let Ok(mut f) = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
        {
            let _ = writeln!(f, "[{ts}] {line}");
        }
        eprintln!("daemon: {line}");
    }
}

/// Where a daemon-global job id routes back to.
struct Route {
    conn_id: u64,
    client_id: u64,
}

/// One connected client.
struct Conn {
    id: u64,
    stream: NetStream,
    reader: FrameReader,
    /// Encoded frames awaiting (possibly partial) write.
    outbox: VecDeque<Vec<u8>>,
    /// Bytes of `outbox.front()` already written.
    out_pos: usize,
    /// Decoded SUBMITs not yet handed to the coordinator.
    pending_submits: VecDeque<JobSpec>,
    /// Decoded SUBMIT_GRAPHs not yet handed to the coordinator.
    pending_graphs: VecDeque<GraphSpec>,
    /// Owed a `Drained` frame when the drain completes.
    drain_waiter: bool,
    /// Owed an `Ack` just before the daemon stops.
    stop_waiter: bool,
    /// Flush the outbox, then close (protocol error path).
    closing: bool,
    dead: bool,
}

impl Conn {
    fn send(&mut self, frame: &Frame) {
        if !self.dead {
            self.outbox.push_back(encode_frame(frame));
        }
    }
}

/// The daemon. Construct with [`Daemon::start`], then either call
/// [`Daemon::run`] on the current thread (it blocks until stopped) or
/// hand it to a thread.
pub struct Daemon {
    opts: DaemonOptions,
    coord: Coordinator,
    listener: Listener,
    logger: Logger,
    conns: Vec<Conn>,
    routes: HashMap<u64, Route>,
    /// Separate routing map for graph jobs: graph ids and job ids share
    /// the same daemon-global counter but come back on different
    /// result streams.
    graph_routes: HashMap<u64, Route>,
    next_job_id: u64,
    next_conn_id: u64,
    state: DaemonState,
    started: Instant,
    /// Signal count already acted upon.
    signals_seen: u64,
    /// Drain has completed (cache persisted, waiters notified).
    drained: bool,
    shutdown_after_drain: bool,
    /// Grace deadline for flushing final frames before exit.
    stop_deadline: Option<Instant>,
    jobs_submitted: u64,
    results_dropped: u64,
}

impl Daemon {
    /// Bind the socket, claim the state file (with stale-PID recovery
    /// and `--force` takeover), and boot the coordinator.
    pub fn start(cfg: &Config, engine: DseEngine, opts: DaemonOptions) -> anyhow::Result<Daemon> {
        std::fs::create_dir_all(&opts.state_dir)?;
        let logger = Logger::new(opts.log_path(), opts.log_rotate_bytes);
        let state_path = opts.state_file_path();

        if let Some(prev) = StateFile::load(&state_path)? {
            let alive = prev.pid != std::process::id() && state::pid_alive(prev.pid);
            if alive && !opts.force {
                anyhow::bail!(
                    "daemon already running (pid {} on {}); use `serve stop` or --force",
                    prev.pid,
                    prev.socket
                );
            }
            if alive {
                logger.log(&format!("--force: terminating running daemon pid {}", prev.pid));
                state::terminate(prev.pid);
                let deadline = Instant::now() + Duration::from_secs(5);
                while state::pid_alive(prev.pid) && Instant::now() < deadline {
                    backoff::pause(Duration::from_millis(20));
                }
                anyhow::ensure!(
                    !state::pid_alive(prev.pid),
                    "pid {} did not exit within 5s of SIGTERM",
                    prev.pid
                );
            } else {
                logger.log(&format!(
                    "recovering from stale state file (pid {} is dead)",
                    prev.pid
                ));
            }
            StateFile::remove(&state_path);
        }

        // A crashed daemon leaves its socket inode behind; bind() would
        // fail with AddrInUse, so clear it once ownership is settled.
        if let Endpoint::Unix(path) = &opts.endpoint {
            let _ = std::fs::remove_file(path);
        }
        let listener = Listener::bind(&opts.endpoint)?;

        let coord = Coordinator::start_with(
            cfg,
            engine,
            opts.artifacts.clone(),
            opts.n_planners,
            opts.coordinator.clone(),
        );
        StateFile::current(opts.endpoint.label()).save(&state_path)?;
        logger.log(&format!(
            "listening on {} (backend `{}`, {} planners)",
            opts.endpoint.label(),
            coord.backend_name(),
            opts.n_planners.max(1)
        ));

        Ok(Daemon {
            signals_seen: state::signals_received(),
            opts,
            coord,
            listener,
            logger,
            conns: Vec::new(),
            routes: HashMap::new(),
            graph_routes: HashMap::new(),
            next_job_id: 0,
            next_conn_id: 0,
            state: DaemonState::Ready,
            started: Instant::now(),
            drained: false,
            shutdown_after_drain: false,
            stop_deadline: None,
            jobs_submitted: 0,
            results_dropped: 0,
        })
    }

    /// Serve until stopped (SHUTDOWN frame, or drain triggered by
    /// SIGINT/SIGTERM). Consumes the daemon; cleans up socket and state
    /// file on the way out.
    pub fn run(mut self) -> anyhow::Result<DaemonSummary> {
        while self.state != DaemonState::Stopped {
            self.check_signals();
            self.accept_new();
            let frames = self.read_conns();
            for (idx, frame) in frames {
                self.handle_frame(idx, frame);
            }
            self.pump_submits();
            self.pump_results();
            self.maybe_finish_drain();
            self.flush_writes();
            // Keep a dead conn around while it still has decoded submits
            // (deferred by backpressure) so its jobs are not lost.
            self.conns
                .retain(|c| !c.dead || !c.pending_submits.is_empty() || !c.pending_graphs.is_empty());
            self.maybe_stop();
            if self.state != DaemonState::Stopped {
                backoff::pause(self.opts.tick);
            }
        }

        // Final stats *before* shutdown cancels anything, so the log
        // reflects what was actually served.
        let stats = self.coord.stats();
        self.coord.shutdown();
        while self.coord.try_next_result().is_some() {
            self.results_dropped += 1; // no client left to route these to
        }
        while self.coord.try_next_graph_result().is_some() {
            self.results_dropped += 1;
        }
        if let Endpoint::Unix(path) = &self.opts.endpoint {
            let _ = std::fs::remove_file(path);
        }
        StateFile::remove(&self.opts.state_file_path());
        let summary = DaemonSummary {
            uptime: self.started.elapsed(),
            jobs_submitted: self.jobs_submitted,
            jobs_completed: stats.jobs_completed,
            jobs_failed: stats.jobs_failed,
            results_dropped: self.results_dropped,
        };
        self.logger.log(&format!(
            "stopped after {:.1}s: {} submitted, {} completed, {} failed, {} results dropped",
            summary.uptime.as_secs_f64(),
            summary.jobs_submitted,
            summary.jobs_completed,
            summary.jobs_failed,
            summary.results_dropped
        ));
        Ok(summary)
    }

    /// First SIGINT/SIGTERM drains (cache persisted, honest stats);
    /// a second one stops hard.
    fn check_signals(&mut self) {
        let n = state::signals_received();
        if n == self.signals_seen {
            return;
        }
        self.signals_seen = n;
        if self.state == DaemonState::Ready {
            self.logger.log("signal received: draining before exit");
            self.begin_drain(true);
        } else {
            self.logger.log("second signal: stopping without drain");
            self.state = DaemonState::Stopped;
        }
    }

    fn accept_new(&mut self) {
        loop {
            match self.listener.accept() {
                Ok(Some(stream)) => {
                    let id = self.next_conn_id;
                    self.next_conn_id += 1;
                    self.conns.push(Conn {
                        id,
                        stream,
                        reader: FrameReader::new(),
                        outbox: VecDeque::new(),
                        out_pos: 0,
                        pending_submits: VecDeque::new(),
                        pending_graphs: VecDeque::new(),
                        drain_waiter: false,
                        stop_waiter: false,
                        closing: false,
                        dead: false,
                    });
                }
                Ok(None) => break,
                Err(e) => {
                    self.logger.log(&format!("accept failed: {e}"));
                    break;
                }
            }
        }
    }

    /// Sweep every connection for readable bytes and decode complete
    /// frames. Returns `(conn index, frame)` pairs; handling is a
    /// separate phase so frame handlers can borrow `self` freely.
    fn read_conns(&mut self) -> Vec<(usize, Frame)> {
        let mut out = Vec::new();
        let mut buf = [0u8; READ_BUF];
        for (idx, conn) in self.conns.iter_mut().enumerate() {
            if conn.dead || conn.closing {
                continue;
            }
            // Backpressure: a client that has outrun the coordinator
            // keeps its bytes in the kernel buffer until we catch up.
            // Graph submissions count against the same budget.
            if conn.pending_submits.len() + conn.pending_graphs.len() >= MAX_PENDING_SUBMITS {
                continue;
            }
            loop {
                match conn.stream.read(&mut buf) {
                    Ok(0) => {
                        conn.dead = true; // clean disconnect
                        break;
                    }
                    Ok(n) => {
                        conn.reader.push(&buf[..n]);
                        if n < buf.len() {
                            break;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true;
                        break;
                    }
                }
            }
            // Decode even after EOF: bytes the client pushed before
            // disconnecting were received in full — their jobs still run
            // (plans warm the cache); only result delivery is dropped.
            loop {
                match conn.reader.next_frame() {
                    Ok(Some(frame)) => out.push((idx, frame)),
                    Ok(None) => break,
                    Err(e) => {
                        // Malformed stream: report, flush, close. The
                        // daemon itself never panics on bad bytes.
                        conn.send(&Frame::Error {
                            job_id: 0,
                            message: e.to_string(),
                        });
                        conn.closing = true;
                        break;
                    }
                }
            }
        }
        out
    }

    fn handle_frame(&mut self, idx: usize, frame: Frame) {
        match frame {
            Frame::Submit(spec) => {
                if self.state == DaemonState::Ready {
                    self.conns[idx].pending_submits.push_back(spec);
                } else {
                    let wire = WireResult::refused(
                        spec.id,
                        spec.gemm(),
                        "daemon draining: admission closed",
                    );
                    self.conns[idx].send(&Frame::Result(wire));
                }
            }
            Frame::StatsReq => {
                let stats = self.wire_stats();
                self.conns[idx].send(&Frame::Stats(stats));
            }
            Frame::Drain => {
                if self.drained {
                    let stats = self.wire_stats();
                    self.conns[idx].send(&Frame::Drained(stats));
                } else {
                    self.begin_drain(false);
                    self.conns[idx].drain_waiter = true;
                }
            }
            Frame::Shutdown => {
                self.begin_drain(true);
                if self.drained {
                    self.conns[idx].send(&Frame::Ack);
                } else {
                    self.conns[idx].stop_waiter = true;
                }
            }
            Frame::SubmitGraph(spec) => {
                if self.state == DaemonState::Ready {
                    self.conns[idx].pending_graphs.push_back(spec);
                } else {
                    let wire = WireGraphResult::refused(
                        spec.id,
                        spec.nodes.len() as u64,
                        "daemon draining: admission closed",
                    );
                    self.conns[idx].send(&Frame::GraphResult(wire));
                }
            }
            // Server-to-client kinds arriving at the server: protocol
            // violation; tell the client and hang up.
            Frame::Result(_) | Frame::Stats(_) | Frame::Drained(_) | Frame::Ack
            | Frame::GraphResult(_) => {
                self.conns[idx].send(&Frame::Error {
                    job_id: 0,
                    message: "protocol violation: server-only frame kind".to_string(),
                });
                self.conns[idx].closing = true;
            }
            Frame::Error { job_id, message } => {
                self.logger
                    .log(&format!("client error (job {job_id}): {message}"));
            }
        }
    }

    /// Hand queued specs to the coordinator. Under `Admission::Block`
    /// defer while the queue is full — the daemon is the coordinator's
    /// only submitter, so checking `queue_room` first cannot race.
    fn pump_submits(&mut self) {
        if self.state != DaemonState::Ready {
            return;
        }
        // Dead connections are not skipped: their decoded submits still
        // run (the results are dropped at routing time).
        for conn in &mut self.conns {
            while !conn.pending_submits.is_empty() {
                if self.coord.admission() == Admission::Block && !self.coord.queue_room() {
                    return; // try again next tick; reads stay gated
                }
                let Some(spec) = conn.pending_submits.pop_front() else {
                    break; // emptied between the loop check and here
                };
                let gid = self.next_job_id;
                self.next_job_id += 1;
                let route = Route { conn_id: conn.id, client_id: spec.id };
                self.routes.insert(gid, route);
                self.jobs_submitted += 1;
                self.coord.submit(spec.into_job(gid));
            }
            while !conn.pending_graphs.is_empty() {
                if self.coord.admission() == Admission::Block && !self.coord.queue_room() {
                    return;
                }
                let Some(spec) = conn.pending_graphs.pop_front() else {
                    break;
                };
                let gid = self.next_job_id;
                self.next_job_id += 1;
                let route = Route { conn_id: conn.id, client_id: spec.id };
                self.graph_routes.insert(gid, route);
                self.jobs_submitted += 1;
                self.coord.submit_graph(spec.into_job(gid));
            }
        }
    }

    /// Stream completed jobs back to their submitters. Results whose
    /// connection is gone are dropped (counted), never wedging the loop.
    fn pump_results(&mut self) {
        while let Some(r) = self.coord.try_next_result() {
            let Some(route) = self.routes.remove(&r.id) else {
                self.results_dropped += 1;
                continue;
            };
            let wire = WireResult::from_result(route.client_id, &r);
            match self
                .conns
                .iter_mut()
                .find(|c| c.id == route.conn_id && !c.dead)
            {
                Some(conn) => conn.send(&Frame::Result(wire)),
                None => self.results_dropped += 1,
            }
        }
        while let Some(r) = self.coord.try_next_graph_result() {
            let Some(route) = self.graph_routes.remove(&r.id) else {
                self.results_dropped += 1;
                continue;
            };
            let wire = WireGraphResult::from_result(route.client_id, &r);
            match self
                .conns
                .iter_mut()
                .find(|c| c.id == route.conn_id && !c.dead)
            {
                Some(conn) => conn.send(&Frame::GraphResult(wire)),
                None => self.results_dropped += 1,
            }
        }
    }

    fn begin_drain(&mut self, shutdown_after: bool) {
        self.shutdown_after_drain |= shutdown_after;
        if self.state != DaemonState::Ready {
            return;
        }
        self.state = DaemonState::Draining;
        self.coord.begin_drain();
        self.logger.log("draining: admission closed");
        // Specs decoded but not yet submitted will never run: refuse
        // them now so every submitted id still gets exactly one result.
        for conn in &mut self.conns {
            while let Some(spec) = conn.pending_submits.pop_front() {
                let wire = WireResult::refused(
                    spec.id,
                    spec.gemm(),
                    "daemon draining: admission closed",
                );
                conn.send(&Frame::Result(wire));
            }
            while let Some(spec) = conn.pending_graphs.pop_front() {
                let wire = WireGraphResult::refused(
                    spec.id,
                    spec.nodes.len() as u64,
                    "daemon draining: admission closed",
                );
                conn.send(&Frame::GraphResult(wire));
            }
        }
    }

    /// Once a drain quiesces: persist the plan cache (the satellite fix
    /// — interrupts must not lose it), answer drain/stop waiters, and
    /// arm the stop deadline when a shutdown was requested.
    fn maybe_finish_drain(&mut self) {
        if self.state != DaemonState::Draining || self.drained || self.coord.pending() > 0 {
            return;
        }
        self.drained = true;
        self.coord.persist_cache();
        let stats = self.wire_stats();
        self.logger.log(&format!(
            "drained: {} completed, {} failed, cache hit rate {:.0}%",
            stats.get("jobs_completed").unwrap_or(0.0),
            stats.get("jobs_failed").unwrap_or(0.0),
            100.0 * stats.get("cache_hit_rate").unwrap_or(0.0)
        ));
        for conn in &mut self.conns {
            if conn.drain_waiter {
                conn.drain_waiter = false;
                conn.send(&Frame::Drained(stats.clone()));
            }
            if conn.stop_waiter {
                conn.stop_waiter = false;
                conn.send(&Frame::Ack);
            }
        }
        if self.shutdown_after_drain {
            self.stop_deadline = Some(Instant::now() + Duration::from_secs(1));
        }
    }

    fn flush_writes(&mut self) {
        for conn in &mut self.conns {
            if conn.dead {
                continue;
            }
            while let Some(front) = conn.outbox.front() {
                match conn.stream.write(&front[conn.out_pos..]) {
                    Ok(n) => {
                        conn.out_pos += n;
                        if conn.out_pos >= front.len() {
                            conn.outbox.pop_front();
                            conn.out_pos = 0;
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                    Err(_) => {
                        conn.dead = true; // EPIPE etc: client went away
                        break;
                    }
                }
            }
            if conn.closing && conn.outbox.is_empty() {
                conn.dead = true;
            }
        }
    }

    /// After a shutdown-drain: stop once final frames are flushed (or
    /// the grace deadline passes).
    fn maybe_stop(&mut self) {
        if !(self.drained && self.shutdown_after_drain) {
            return;
        }
        let flushed = self.conns.iter().all(|c| c.dead || c.outbox.is_empty());
        let expired = self
            .stop_deadline
            .map(|d| Instant::now() >= d)
            .unwrap_or(false);
        if flushed || expired {
            self.state = DaemonState::Stopped;
        }
    }

    fn wire_stats(&self) -> WireStats {
        let s = self.coord.stats();
        let fields: Vec<(&str, f64)> = vec![
            ("jobs_submitted", self.jobs_submitted as f64),
            ("jobs_completed", s.jobs_completed as f64),
            ("jobs_failed", s.jobs_failed as f64),
            ("jobs_pending", self.coord.pending() as f64),
            ("cache_hits", s.cache_hits as f64),
            ("cache_misses", s.cache_misses as f64),
            ("cache_hit_rate", s.cache_hit_rate),
            ("cache_evictions", s.cache_evictions as f64),
            ("coalesced_plans", s.coalesced_plans as f64),
            ("rejected_jobs", s.rejected_jobs as f64),
            ("queue_depth_peak", s.queue_depth_peak as f64),
            ("plan_p50_ms", s.plan_p50_ms),
            ("executed_jobs", s.executed_jobs as f64),
            ("executed_flops", s.executed_flops),
            ("exec_time_s", s.exec_time_s),
            ("executed_energy_j", s.executed_energy_j),
            ("executed_gflops_per_w", s.executed_gflops_per_w),
            ("cpu_gemm_flops", s.cpu_gemm_flops),
            ("cpu_gemm_time_s", s.cpu_gemm_time_s),
            ("cpu_gemm_gflops", s.cpu_gemm_gflops),
            ("simulated_energy_j", s.simulated_energy_j),
            ("reconfigs", s.reconfigs as f64),
            ("simulated_reconfig_s", s.simulated_reconfig_s),
            ("forest_compile_ms", s.forest_compile_ms),
            ("predict_rows_per_s", s.predict_rows_per_s),
            ("gate_rows_total", s.gate_rows_total as f64),
            ("gate_rows_skipped", s.gate_rows_skipped as f64),
            ("gate_skip_rate", s.gate_skip_rate),
            ("dse_pool_threads", s.dse_pool_threads as f64),
            ("retries_total", s.retries_total as f64),
            ("timeouts_total", s.timeouts_total as f64),
            ("failovers_total", s.failovers_total as f64),
            ("faults_injected", s.faults_injected as f64),
            ("breaker_state", s.breaker_state as f64),
            ("graph_jobs", s.graph_jobs as f64),
            ("graph_nodes_executed", s.graph_nodes_executed as f64),
            ("plans_shared", s.plans_shared as f64),
            ("resident_bytes_peak", s.resident_bytes_peak as f64),
            ("results_dropped", self.results_dropped as f64),
            ("connections", self.conns.iter().filter(|c| !c.dead).count() as f64),
        ];
        let backend = match self.coord.kernel_profile() {
            Some(p) => format!("{} (profile {p})", self.coord.backend_name()),
            None => self.coord.backend_name().to_string(),
        };
        WireStats {
            state: self.state.label().to_string(),
            backend,
            uptime_s: self.started.elapsed().as_secs_f64(),
            fields: fields
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        std::env::temp_dir().join(format!("versal-gemm-daemon-{}-{name}", std::process::id()))
    }

    #[test]
    fn logger_rotates_at_threshold() {
        let dir = tmp("logrot");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("daemon.log");
        let logger = Logger::new(path.clone(), 128);
        for i in 0..40 {
            logger.log(&format!("line {i} padding padding padding"));
        }
        let rotated = path.with_extension("log.1");
        assert!(rotated.exists(), "no rotated log at {}", rotated.display());
        assert!(path.exists());
        // The live file restarted from (near) zero after rotation.
        assert!(std::fs::metadata(&path).unwrap().len() < 256 + 128);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn options_paths_derive_from_state_dir() {
        let opts = DaemonOptions::new(Endpoint::parse("/tmp/x.sock"), PathBuf::from("/tmp/sd"));
        assert_eq!(opts.state_file_path(), PathBuf::from("/tmp/sd/daemon.json"));
        assert_eq!(opts.log_path(), PathBuf::from("/tmp/sd/daemon.log"));
        assert_eq!(DaemonState::Draining.label(), "draining");
    }
}
