//! END-TO-END driver (DESIGN.md §8): serve the GEMM working set of a
//! real small-transformer inference trace through the full stack.
//!
//! All three layers compose here:
//! * L1/L2 — the AOT-compiled Pallas tiled-GEMM artifacts (`make
//!   artifacts`) execute every job's actual numerics via PJRT (the
//!   coordinator's `auto` backend falls back to the blocked CPU GEMM
//!   when no artifacts exist, so the driver runs in every checkout);
//! * L3 — the coordinator plans each job with the ML-driven DSE (cached
//!   per shape/objective), batches execution, validates results against
//!   the Rust reference, and accounts per-job executed energy plus
//!   simulated-VCK190 energy for the selected mappings.
//!
//! The trace is Qwen2.5-0.5B-shaped (hidden 896, FFN 4864): one prefill
//! pass (batched sequence) and a run of decode steps — exactly the
//! workloads the paper's G1/G4/G9 come from. Results are recorded in
//! EXPERIMENTS.md.
//!
//! Run with: `make artifacts && cargo run --release --example serve_llm`

use std::time::Instant;

use versal_gemm::config::Config;
use versal_gemm::coordinator::{Coordinator, GemmJob};
use versal_gemm::dse::Objective;
use versal_gemm::report::Lab;
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::Gemm;

/// The per-layer GEMMs of a Qwen2.5-0.5B-like transformer block.
fn block_gemms(seq: usize) -> Vec<(&'static str, Gemm)> {
    let hidden = 896;
    let ffn = 4864;
    vec![
        ("qkv_proj", Gemm::new(seq, 3 * hidden / 2, hidden)), // fused qkv (GQA)
        ("attn_out", Gemm::new(seq, hidden, hidden)),
        ("ffn_gate_up", Gemm::new(seq, 2 * ffn / 2, hidden)),
        ("ffn_down", Gemm::new(seq, hidden, ffn / 2)),
    ]
}

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;
    let mut coord = Coordinator::start(&cfg, lab.engine(), Some("artifacts".into()), 2);

    let mut rng = Rng::new(0x57EE1);
    let mut jobs = Vec::new();
    let mut id = 0u64;
    let mut push = |name: &str, g: Gemm, objective: Objective, jobs: &mut Vec<(String, GemmJob)>, rng: &mut Rng| {
        let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32 * 0.1).collect();
        let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32 * 0.1).collect();
        let mut job = GemmJob::with_data(id, g, objective, a, b);
        job.validate = true;
        jobs.push((name.to_string(), job));
        id += 1;
    };

    // Prefill (seq = 64, throughput objective) + 8 decode steps
    // (seq = 32 batch of token positions, energy objective: the paper's
    // edge scenario).
    for (name, g) in block_gemms(64) {
        push(&format!("prefill/{name}"), g, Objective::Throughput, &mut jobs, &mut rng);
    }
    for step in 0..8 {
        for (name, g) in block_gemms(32) {
            push(
                &format!("decode{step}/{name}"),
                g,
                Objective::EnergyEfficiency,
                &mut jobs,
                &mut rng,
            );
        }
    }

    println!("== serve_llm: {} GEMM jobs (Qwen2.5-0.5B-shaped) ==", jobs.len());
    let names: Vec<String> = jobs.iter().map(|(n, _)| n.clone()).collect();
    let started = Instant::now();
    let results = coord.run_batch(jobs.into_iter().map(|(_, j)| j).collect());
    let wall = started.elapsed();

    let mut total_flops = 0.0;
    let mut validated = 0usize;
    println!(
        "{:<22} {:>16} {:>10} {:>10} {:>12} {:>10}",
        "job", "gemm", "plan ms", "exec ms", "GFLOP/s", "max err"
    );
    for r in &results {
        anyhow::ensure!(r.error.is_none(), "job {} failed: {:?}", names[r.id as usize], r.error);
        let exec = r.exec_time.expect("executed");
        let err = r.validation_err.expect("validated");
        anyhow::ensure!(err < 1e-2, "numerics drift on {}: {err}", names[r.id as usize]);
        validated += 1;
        total_flops += r.gemm.flops();
        println!(
            "{:<22} {:>16} {:>10.2} {:>10.2} {:>12.2} {:>10.2e}",
            names[r.id as usize],
            r.gemm.label(),
            r.plan_time.as_secs_f64() * 1e3,
            exec.as_secs_f64() * 1e3,
            r.executed_gflops().unwrap(),
            err
        );
    }

    let stats = coord.stats();
    println!("\n== summary ==");
    println!("jobs served:            {} ({} validated against reference)", results.len(), validated);
    println!("wall clock:             {:.2} s", wall.as_secs_f64());
    println!("aggregate exec rate:    {:.2} GFLOP/s (PJRT CPU, interpret-mode Pallas)", total_flops / stats.exec_time_s / 1e9);
    println!("DSE cache:              {} hits / {} misses", stats.cache_hits, stats.cache_misses);
    println!("simulated VCK190 cost:  {:.3} J across selected mappings", stats.simulated_energy_j);
    let per_tok = stats.simulated_energy_j / 8.0;
    println!("  -> {:.3} J per decode step (energy-optimal mappings)", per_tok);
    Ok(())
}
