"""L2 — JAX compute graphs around the Pallas micro-kernel.

The paper's "model" is the tiled GEMM itself: the AIE array + PL dataflow
computes ``C = A @ B`` one tile at a time.  This module defines the
AOT-lowered GEMM *tile executables* the Rust coordinator composes at run
time (mirroring how the PL composes AIE micro-kernel invocations), plus
shape-variant metadata for the artifact manifest.

Every function here is lowered ONCE by ``aot.py``; Python never runs on
the request path.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.tiled_gemm import (
    MICRO_K,
    MICRO_M,
    MICRO_N,
    tiled_gemm,
)


@dataclasses.dataclass(frozen=True)
class GemmVariant:
    """One AOT artifact: a fixed-shape tiled GEMM executable.

    The Rust runtime picks, per workload dimension, the largest variant
    tile that fits, pads the operands to tile multiples, and accumulates
    partial C tiles — the same role the PL plays for the AIE array.
    """

    name: str
    m: int
    n: int
    k: int
    block_m: int = MICRO_M
    block_n: int = MICRO_N
    block_k: int = MICRO_K

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k

    def arg_specs(self) -> Tuple[jax.ShapeDtypeStruct, jax.ShapeDtypeStruct]:
        return (
            jax.ShapeDtypeStruct((self.m, self.k), jnp.float32),
            jax.ShapeDtypeStruct((self.k, self.n), jnp.float32),
        )

    def fn(self) -> Callable:
        bm, bn, bk = self.block_m, self.block_n, self.block_k

        def gemm(a, b):
            # 1-tuple return: lowered with return_tuple=True and unwrapped
            # with to_tuple1() on the Rust side (see aot_recipe gotchas).
            return (tiled_gemm(a, b, block_m=bm, block_n=bn, block_k=bk),)

        return gemm


# Artifact set.  The micro tile is the paper's fixed 32x32x32 AIE
# workload; the larger square/skinny tiles let the Rust executor amortize
# PJRT invocation overhead on bigger workloads (decode-shaped GEMMs have
# tiny M, hence the 32xN and 64xN variants).
ARTIFACT_VARIANTS: List[GemmVariant] = [
    GemmVariant("micro_32", 32, 32, 32),
    GemmVariant("tile_64", 64, 64, 64),
    GemmVariant("tile_128", 128, 128, 128),
    GemmVariant("tile_32x128x128", 32, 128, 128),
    GemmVariant("tile_64x128x128", 64, 128, 128),
    # Perf-pass variants: MXU-edge fused blocks (a single grid step per
    # invocation) — the L1 block-shape iteration showed the blocked 32^3
    # grid pays ~10us of interpret-mode loop overhead per step, so fused
    # tiles run ~9x faster on the CPU substrate while staying inside a
    # TPU VMEM budget (3*512^2*4 B = 3.1 MB; see DESIGN.md section 8).
    GemmVariant("tile_128_fused", 128, 128, 128, block_m=128, block_n=128, block_k=128),
    GemmVariant("tile_256_fused", 256, 256, 256, block_m=256, block_n=256, block_k=256),
    GemmVariant("tile_512_fused", 512, 512, 512, block_m=512, block_n=512, block_k=512),
    GemmVariant(
        "tile_32x512x512_fused", 32, 512, 512, block_m=32, block_n=512, block_k=512
    ),
]

VARIANTS_BY_NAME: Dict[str, GemmVariant] = {v.name: v for v in ARTIFACT_VARIANTS}


def lower_variant(variant: GemmVariant) -> jax.stages.Lowered:
    """Lower one variant with fixed example shapes (AOT contract)."""
    return jax.jit(variant.fn()).lower(*variant.arg_specs())
