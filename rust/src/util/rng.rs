//! Deterministic PRNG stack: SplitMix64 seeding + xoshiro256** core.
//!
//! Every stochastic component in the framework (dataset sampling, GBDT
//! row/column subsampling, hyper-parameter search, simulated measurement
//! noise) draws from this module, keyed by a single `u64` seed from the
//! config, so dataset generation and every figure are bit-reproducible.

/// SplitMix64: used to expand a single seed into xoshiro state and to
/// derive independent per-component streams.
#[derive(Debug, Clone, Copy)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the workhorse generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive an independent child stream (e.g. per tree, per workload).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, bound)` (Lemire-style rejection-free for
    /// our purposes: modulo bias is negligible at u64 width, but we use
    /// the multiply-shift reduction anyway).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo + 1)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64() < p_true
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Lognormal with median 1.0 and log-sigma `sigma` — the shape of
    /// on-board measurement jitter.
    pub fn lognormal(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len())]
    }
}

/// Stable 64-bit FNV-1a hash — used to key deterministic per-config
/// measurement noise so the "board" returns the same number every time a
/// given design is re-measured.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01B3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // First outputs for seed 0 (known-good reference sequence).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220_A839_7B1D_CDAF);
        assert_eq!(sm.next_u64(), 0x6E78_9E6A_A1B9_65F4);
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn forks_are_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let x: Vec<u64> = (0..8).map(|_| c1.next_u64()).collect();
        let y: Vec<u64> = (0..8).map(|_| c2.next_u64()).collect();
        assert_ne!(x, y);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(1);
        for _ in 0..10_000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_respects_bound() {
        let mut rng = Rng::new(3);
        for bound in [1usize, 2, 7, 100] {
            for _ in 0..1000 {
                assert!(rng.below(bound) < bound);
            }
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
        assert!((var - 1.0).abs() < 0.02, "var {var}");
    }

    #[test]
    fn lognormal_median_near_one() {
        let mut rng = Rng::new(11);
        let mut xs: Vec<f64> = (0..50_001).map(|_| rng.lognormal(0.05)).collect();
        xs.sort_by(|a, b| a.total_cmp(b));
        let median = xs[xs.len() / 2];
        assert!((median - 1.0).abs() < 0.01, "median {median}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(6);
        let idx = rng.sample_indices(100, 30);
        assert_eq!(idx.len(), 30);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 30);
    }

    #[test]
    fn fnv_stable() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), fnv1a(b"a"));
        assert_ne!(fnv1a(b"a"), fnv1a(b"b"));
    }
}
