//! The project-specific rule set.
//!
//! Every rule matches over the lexed token stream of [`SourceFile`]s —
//! no regexes over raw text, so string literals and comments can never
//! produce findings. Rules are registered in [`all_rules`]; ids are
//! stable (waivers and the baseline reference them).
//!
//! | id                  | invariant                                           |
//! |---------------------|-----------------------------------------------------|
//! | nan-ordering        | float orderings go through `total_cmp`              |
//! | panic-freedom       | no panics on serve-critical paths                   |
//! | lock-hygiene        | `lock_unpoisoned` only, and no lock-order cycles    |
//! | wire-exhaustiveness | protocol frame kinds encode, decode, and round-trip |
//! | stats-parity        | every coordinator stat reaches the wire             |
//! | bounded-sleep       | serving loops sleep only via `util::backoff`        |

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::TokKind;
use super::{Finding, Repo, Rule, SourceFile};

pub fn all_rules() -> Vec<Box<dyn Rule>> {
    vec![
        Box::new(NanOrdering),
        Box::new(PanicFreedom),
        Box::new(LockHygiene),
        Box::new(WireExhaustiveness),
        Box::new(StatsParity),
        Box::new(BoundedSleep),
    ]
}

fn push(out: &mut Vec<Finding>, rule: &'static str, sf: &SourceFile, line: u32, message: String) {
    out.push(Finding {
        rule,
        file: sf.rel.clone(),
        line,
        message,
        waived: false,
        baselined: false,
    });
}

// ---------------------------------------------------------------------------
// nan-ordering
// ---------------------------------------------------------------------------

/// The PR 2/3/5 bug class: `partial_cmp(..).unwrap()` panics on NaN, and
/// a `partial_cmp`-based comparator handed to `sort_by`/`max_by`/`min_by`
/// is not a total order (NaN can win or panic). `f64::total_cmp` is the
/// project-wide ordering. Applies everywhere, tests included — a test
/// that panics on NaN data hides the regression the rule exists to catch.
struct NanOrdering;

const SORTERS: [&str; 4] = ["sort_by", "sort_unstable_by", "max_by", "min_by"];

impl Rule for NanOrdering {
    fn id(&self) -> &'static str {
        "nan-ordering"
    }

    fn describe(&self) -> &'static str {
        "float orderings must use total_cmp (no partial_cmp().unwrap(), \
         no partial_cmp comparators in sort_by/max_by/min_by)"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        for sf in &repo.files {
            let n = sf.n_code();
            for ci in 0..n {
                // partial_cmp( .. ).unwrap( / .expect(
                if sf.is_ident(ci, "partial_cmp") && ci + 1 < n && sf.ctok(ci + 1).is_punct(b'(')
                {
                    if let Some(close) = sf.matching(ci + 1) {
                        if close + 3 < n
                            && sf.ctok(close + 1).is_punct(b'.')
                            && (sf.is_ident(close + 2, "unwrap")
                                || sf.is_ident(close + 2, "expect"))
                            && sf.ctok(close + 3).is_punct(b'(')
                        {
                            push(
                                out,
                                self.id(),
                                sf,
                                sf.ctok(ci).line,
                                "NaN-unsafe `partial_cmp(..).unwrap()` — use `total_cmp`"
                                    .to_string(),
                            );
                        }
                    }
                }
                // .sort_by(|a, b| .. partial_cmp ..) and friends
                if sf.ctok(ci).kind == TokKind::Ident
                    && SORTERS.contains(&sf.ctext(ci))
                    && ci > 0
                    && sf.ctok(ci - 1).is_punct(b'.')
                    && ci + 1 < n
                    && sf.ctok(ci + 1).is_punct(b'(')
                {
                    if let Some(close) = sf.matching(ci + 1) {
                        let uses_partial =
                            (ci + 2..close).any(|j| sf.is_ident(j, "partial_cmp"));
                        if uses_partial {
                            push(
                                out,
                                self.id(),
                                sf,
                                sf.ctok(ci).line,
                                format!(
                                    "float comparator in `{}` uses partial_cmp — \
                                     use `total_cmp`",
                                    sf.ctext(ci)
                                ),
                            );
                        }
                    }
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// panic-freedom
// ---------------------------------------------------------------------------

/// Serve-critical modules must not panic: a planner/executor/daemon
/// thread that unwinds poisons locks and wedges the serving loop. Typed
/// errors, `let .. else`, or `util::lock_unpoisoned` instead. Test code
/// (`#[cfg(test)]`, `#[test]`) is exempt.
struct PanicFreedom;

const SERVE_DIRS: [&str; 4] = [
    "rust/src/server/",
    "rust/src/coordinator/",
    "rust/src/runtime/",
    "rust/src/dse/",
];

const PANIC_MACROS: [&str; 4] = ["panic", "unreachable", "todo", "unimplemented"];

impl Rule for PanicFreedom {
    fn id(&self) -> &'static str {
        "panic-freedom"
    }

    fn describe(&self) -> &'static str {
        "no unwrap()/expect()/panic!/unreachable! in server/, coordinator/, \
         runtime/, dse/ outside #[cfg(test)]"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        for sf in &repo.files {
            if !SERVE_DIRS.iter().any(|d| sf.rel.starts_with(d)) {
                continue;
            }
            let n = sf.n_code();
            for ci in 0..n {
                let tok = sf.ctok(ci);
                if tok.kind != TokKind::Ident || sf.in_test(tok.start) {
                    continue;
                }
                let word = sf.ctext(ci);
                let after_dot = ci > 0 && sf.ctok(ci - 1).is_punct(b'.');
                if word == "unwrap"
                    && after_dot
                    && ci + 2 < n
                    && sf.ctok(ci + 1).is_punct(b'(')
                    && sf.ctok(ci + 2).is_punct(b')')
                {
                    push(
                        out,
                        self.id(),
                        sf,
                        tok.line,
                        "`.unwrap()` on a serve-critical path — return a typed \
                         error, use `let .. else`, or `util::lock_unpoisoned`"
                            .to_string(),
                    );
                } else if word == "expect" && after_dot && ci + 1 < n
                    && sf.ctok(ci + 1).is_punct(b'(')
                {
                    push(
                        out,
                        self.id(),
                        sf,
                        tok.line,
                        "`.expect(..)` on a serve-critical path — return a typed error"
                            .to_string(),
                    );
                } else if PANIC_MACROS.contains(&word)
                    && ci + 1 < n
                    && sf.ctok(ci + 1).is_punct(b'!')
                {
                    push(
                        out,
                        self.id(),
                        sf,
                        tok.line,
                        format!("`{word}!` on a serve-critical path — return a typed error"),
                    );
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// lock-hygiene
// ---------------------------------------------------------------------------

/// Two checks. (a) Raw `.lock().unwrap()` / `.lock().expect(..)` must
/// route through `util::lock_unpoisoned` so a panicking holder cannot
/// cascade `PoisonError` panics. (b) A static lock-acquisition-order
/// graph over the named mutexes each function body acquires via
/// `lock_unpoisoned`: an edge A→B means B was acquired while A's guard
/// was plausibly live; a cycle across the repo flags a potential
/// deadlock (coordinator stats vs flight table vs cache shard vs pool
/// latch). Guard liveness is approximated from tokens — let-bound
/// guards live to the end of their block, temporaries to the end of
/// their statement (or the `{` of an `if`/`while` body) — which
/// under-approximates `match` scrutinee lifetimes and ignores early
/// `drop()`, both erring toward fewer false cycles.
struct LockHygiene;

/// One `lock_unpoisoned(..)` call site inside a function body.
struct Acq {
    /// Module-qualified lock name, e.g. `coordinator::stats`.
    name: String,
    /// Code index of the `lock_unpoisoned` identifier.
    ci: usize,
    line: u32,
    /// Code index bounding the guard's plausible live range (inclusive).
    end_ci: usize,
}

impl Rule for LockHygiene {
    fn id(&self) -> &'static str {
        "lock-hygiene"
    }

    fn describe(&self) -> &'static str {
        "mutex access via util::lock_unpoisoned only, and the static \
         lock-acquisition-order graph must be acyclic"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        self.check_raw_locks(repo, out);
        self.check_lock_order(repo, out);
    }
}

impl LockHygiene {
    fn check_raw_locks(&self, repo: &Repo, out: &mut Vec<Finding>) {
        for sf in &repo.files {
            let n = sf.n_code();
            for ci in 0..n {
                // . lock ( ) . unwrap|expect (
                if !sf.is_ident(ci, "lock") || ci == 0 || !sf.ctok(ci - 1).is_punct(b'.') {
                    continue;
                }
                if sf.in_test(sf.ctok(ci).start) {
                    continue;
                }
                if ci + 5 < n
                    && sf.ctok(ci + 1).is_punct(b'(')
                    && sf.ctok(ci + 2).is_punct(b')')
                    && sf.ctok(ci + 3).is_punct(b'.')
                    && (sf.is_ident(ci + 4, "unwrap") || sf.is_ident(ci + 4, "expect"))
                    && sf.ctok(ci + 5).is_punct(b'(')
                {
                    push(
                        out,
                        self.id(),
                        sf,
                        sf.ctok(ci).line,
                        format!(
                            "raw `.lock().{}(..)` — route through `util::lock_unpoisoned`",
                            sf.ctext(ci + 4)
                        ),
                    );
                }
            }
        }
    }

    fn check_lock_order(&self, repo: &Repo, out: &mut Vec<Finding>) {
        // Edge (held, acquired) -> first site proving it.
        let mut edges: BTreeMap<(String, String), (String, u32)> = BTreeMap::new();
        for sf in &repo.files {
            for (open, close) in fn_bodies(sf) {
                let acqs = acquisitions(sf, open, close);
                for a in &acqs {
                    for b in &acqs {
                        if b.ci <= a.ci || b.ci > a.end_ci {
                            continue;
                        }
                        if a.name == b.name {
                            if !sf.in_test(sf.ctok(b.ci).start) {
                                push(
                                    out,
                                    self.id(),
                                    sf,
                                    b.line,
                                    format!(
                                        "lock `{}` re-acquired while its guard is \
                                         still held (self-deadlock)",
                                        b.name
                                    ),
                                );
                            }
                        } else {
                            edges
                                .entry((a.name.clone(), b.name.clone()))
                                .or_insert_with(|| (sf.rel.clone(), b.line));
                        }
                    }
                }
            }
        }

        let mut adj: BTreeMap<&str, BTreeSet<&str>> = BTreeMap::new();
        for (held, acquired) in edges.keys() {
            adj.entry(held.as_str()).or_default().insert(acquired.as_str());
        }
        let mut color: BTreeMap<&str, u8> = BTreeMap::new();
        let mut stack: Vec<&str> = Vec::new();
        let mut cycles: Vec<Vec<String>> = Vec::new();
        let nodes: Vec<&str> = adj.keys().copied().collect();
        for node in nodes {
            if color.get(node).copied().unwrap_or(0) == 0 {
                dfs_cycles(node, &adj, &mut color, &mut stack, &mut cycles);
            }
        }
        // Dedupe rotations of the same cycle.
        let mut seen: BTreeSet<Vec<String>> = BTreeSet::new();
        for cycle in cycles {
            let mut key = cycle.clone();
            key.sort();
            if !seen.insert(key) {
                continue;
            }
            let closing = (
                cycle.last().cloned().unwrap_or_default(),
                cycle.first().cloned().unwrap_or_default(),
            );
            let (file, line) = match edges.get(&closing) {
                Some((f, l)) => (f.clone(), *l),
                None => (String::new(), 0),
            };
            let path = {
                let mut p = cycle.join(" -> ");
                p.push_str(" -> ");
                p.push_str(&cycle[0]);
                p
            };
            out.push(Finding {
                rule: self.id(),
                file,
                line,
                message: format!("lock-order cycle (potential deadlock): {path}"),
                waived: false,
                baselined: false,
            });
        }
    }
}

fn dfs_cycles<'a>(
    node: &'a str,
    adj: &BTreeMap<&'a str, BTreeSet<&'a str>>,
    color: &mut BTreeMap<&'a str, u8>,
    stack: &mut Vec<&'a str>,
    cycles: &mut Vec<Vec<String>>,
) {
    color.insert(node, 1);
    stack.push(node);
    if let Some(next) = adj.get(node) {
        for &nb in next {
            match color.get(nb).copied().unwrap_or(0) {
                0 => dfs_cycles(nb, adj, color, stack, cycles),
                1 => {
                    if let Some(pos) = stack.iter().position(|s| *s == nb) {
                        cycles.push(stack[pos..].iter().map(|s| s.to_string()).collect());
                    }
                }
                _ => {}
            }
        }
    }
    stack.pop();
    color.insert(node, 2);
}

/// `rust/src/coordinator/mod.rs` -> `coordinator`,
/// `rust/src/coordinator/cache.rs` -> `coordinator/cache`,
/// `rust/benches/dse_latency.rs` -> `rust/benches/dse_latency`.
fn module_key(rel: &str) -> String {
    let s = rel.strip_prefix("rust/src/").unwrap_or(rel);
    let s = s.strip_suffix(".rs").unwrap_or(s);
    let s = s.strip_suffix("/mod").unwrap_or(s);
    s.to_string()
}

/// Every `fn` body in the file as `(open_brace_ci, close_brace_ci)`.
/// Nested fns yield their own (overlapping) entries; the duplicate
/// edges that produces are deduped by the engine.
fn fn_bodies(sf: &SourceFile) -> Vec<(usize, usize)> {
    let n = sf.n_code();
    let mut out = Vec::new();
    let mut ci = 0usize;
    while ci < n {
        if sf.is_ident(ci, "fn")
            && ci + 1 < n
            && sf.ctok(ci + 1).kind == TokKind::Ident
        {
            let mut depth = 0i64;
            let mut j = ci + 2;
            while j < n {
                match sf.ctok(j).kind {
                    TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
                    TokKind::Punct(b')') | TokKind::Punct(b']') => depth -= 1,
                    TokKind::Punct(b';') if depth <= 0 => break, // trait method, no body
                    TokKind::Punct(b'{') if depth <= 0 => {
                        if let Some(close) = sf.matching(j) {
                            out.push((j, close));
                        }
                        break;
                    }
                    _ => {}
                }
                j += 1;
            }
        }
        ci += 1;
    }
    out
}

/// Collect `lock_unpoisoned(..)` acquisitions inside one body with their
/// approximated guard live ranges.
fn acquisitions(sf: &SourceFile, open: usize, close: usize) -> Vec<Acq> {
    // Brace nesting level per code index across the body (body interior
    // is level >= 1; a `}` carries the level it returns to).
    let mut level = vec![0i64; close + 1 - open];
    let mut d = 0i64;
    for (k, ci) in (open..=close).enumerate() {
        match sf.ctok(ci).kind {
            TokKind::Punct(b'{') => {
                level[k] = d;
                d += 1;
            }
            TokKind::Punct(b'}') => {
                d -= 1;
                level[k] = d;
            }
            _ => level[k] = d,
        }
    }
    let lvl = |ci: usize| level[ci - open];

    let mut out = Vec::new();
    for ci in open + 1..close {
        if !sf.is_ident(ci, "lock_unpoisoned")
            || ci + 1 >= close
            || !sf.ctok(ci + 1).is_punct(b'(')
        {
            continue;
        }
        let Some(close_p) = sf.matching(ci + 1) else {
            continue;
        };
        let Some(name) = lock_name(sf, ci + 2, close_p) else {
            continue;
        };
        let name = format!("{}::{}", module_key(&sf.rel), name);
        let end_ci = if is_let_bound(sf, open, ci, close_p) {
            // Guard lives to the end of its enclosing block.
            let d0 = lvl(ci);
            let mut j = close_p + 1;
            while j < close && lvl(j) >= d0 {
                j += 1;
            }
            j
        } else {
            // Temporary: lives to the end of the statement; an `if`/
            // `while` body brace at statement depth ends it early
            // (conservative for `match` scrutinees — see rule docs).
            let mut paren = 0i64;
            let mut brace = 0i64;
            let mut j = close_p + 1;
            while j < close {
                match sf.ctok(j).kind {
                    TokKind::Punct(b'(') | TokKind::Punct(b'[') => paren += 1,
                    TokKind::Punct(b')') | TokKind::Punct(b']') => {
                        paren -= 1;
                        if paren < 0 {
                            break; // closes an enclosing call/index
                        }
                    }
                    TokKind::Punct(b'{') => {
                        if paren == 0 && brace == 0 {
                            break;
                        }
                        brace += 1;
                    }
                    TokKind::Punct(b'}') => {
                        brace -= 1;
                        if brace < 0 {
                            break; // tail expression; block closed
                        }
                    }
                    TokKind::Punct(b';') if paren == 0 && brace == 0 => break,
                    _ => {}
                }
                j += 1;
            }
            j
        };
        out.push(Acq {
            name,
            ci,
            line: sf.ctok(ci).line,
            end_ci,
        });
    }
    out
}

/// The mutex being locked, from the call's argument tokens: the last
/// field access (`&self.exec_stats` -> `exec_stats`, `self.shard(k)` ->
/// `shard`), else the first plain identifier (`&job_rx` -> `job_rx`).
fn lock_name(sf: &SourceFile, from: usize, to: usize) -> Option<String> {
    let mut field: Option<&str> = None;
    for ci in from..to {
        if sf.ctok(ci).kind == TokKind::Ident
            && ci > from
            && sf.ctok(ci - 1).is_punct(b'.')
        {
            field = Some(sf.ctext(ci));
        }
    }
    if let Some(f) = field {
        return Some(f.to_string());
    }
    for ci in from..to {
        if sf.ctok(ci).kind == TokKind::Ident {
            let w = sf.ctext(ci);
            if w != "self" && w != "mut" {
                return Some(w.to_string());
            }
        }
    }
    None
}

/// `let [mut] name = [path::]lock_unpoisoned(..);` — the guard is bound
/// and the call is the entire initializer (a trailing `;` right after
/// the close paren, no `*`/method chain in between).
fn is_let_bound(sf: &SourceFile, body_open: usize, ci: usize, close_p: usize) -> bool {
    if close_p + 1 >= sf.n_code() || !sf.ctok(close_p + 1).is_punct(b';') {
        return false;
    }
    // Walk back over a `util::`-style path prefix: consume `:` freely,
    // and an identifier only when the token to its right (already
    // consumed) is a `:` — i.e. it is a path segment, not the binding.
    let mut k = ci;
    while k > body_open + 1 {
        let prev = sf.ctok(k - 1);
        if prev.is_punct(b':') {
            k -= 1;
        } else if prev.kind == TokKind::Ident && sf.ctok(k).is_punct(b':') {
            k -= 1;
        } else {
            break;
        }
    }
    // Expect `= <ident> [mut] let` walking back from k.
    if k <= body_open + 3 || !sf.ctok(k - 1).is_punct(b'=') {
        return false;
    }
    if sf.ctok(k - 2).kind != TokKind::Ident {
        return false;
    }
    sf.is_ident(k - 3, "let")
        || (sf.is_ident(k - 3, "mut") && k >= body_open + 4 && sf.is_ident(k - 4, "let"))
}

// ---------------------------------------------------------------------------
// wire-exhaustiveness
// ---------------------------------------------------------------------------

/// Every `K_*` frame-kind constant in `server/protocol.rs` must appear
/// in both `encode_frame` and `decode_frame`, and every `Frame` variant
/// must be exercised by a test (the round-trip suite) — a frame kind
/// that encodes but silently fails to decode is a wire-protocol bug the
/// type system cannot see.
struct WireExhaustiveness;

impl Rule for WireExhaustiveness {
    fn id(&self) -> &'static str {
        "wire-exhaustiveness"
    }

    fn describe(&self) -> &'static str {
        "every frame-kind constant appears in encode_frame, decode_frame, \
         and a round-trip test"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        let Some(sf) = repo.file_ending("server/protocol.rs") else {
            return;
        };
        let n = sf.n_code();

        // pub const K_XXX: .. = ..;
        let mut kinds: Vec<(String, u32)> = Vec::new();
        for ci in 0..n {
            if sf.is_ident(ci, "const")
                && ci + 1 < n
                && sf.ctok(ci + 1).kind == TokKind::Ident
                && sf.ctext(ci + 1).starts_with("K_")
            {
                kinds.push((sf.ctext(ci + 1).to_string(), sf.ctok(ci + 1).line));
            }
        }

        for (fn_name, what) in [("encode_frame", "encoded"), ("decode_frame", "decoded")] {
            let Some((open, close)) = fn_body(sf, fn_name) else {
                push(
                    out,
                    self.id(),
                    sf,
                    1,
                    format!("protocol is missing `fn {fn_name}`"),
                );
                continue;
            };
            let body: BTreeSet<&str> = (open..close)
                .filter(|&ci| sf.ctok(ci).kind == TokKind::Ident)
                .map(|ci| sf.ctext(ci))
                .collect();
            for (k, line) in &kinds {
                if !body.contains(k.as_str()) {
                    push(
                        out,
                        self.id(),
                        sf,
                        *line,
                        format!("frame kind `{k}` is never {what} ({fn_name})"),
                    );
                }
            }
        }

        // Every Frame variant must appear as `Frame::Variant` inside a
        // test span (the round-trip suite).
        for (variant, line) in enum_variants(sf, "Frame") {
            let covered = (0..n).any(|ci| {
                sf.is_ident(ci, &variant)
                    && ci >= 3
                    && sf.ctok(ci - 1).is_punct(b':')
                    && sf.ctok(ci - 2).is_punct(b':')
                    && sf.is_ident(ci - 3, "Frame")
                    && sf.in_test(sf.ctok(ci).start)
            });
            if !covered {
                push(
                    out,
                    self.id(),
                    sf,
                    line,
                    format!("`Frame::{variant}` is not exercised by a round-trip test"),
                );
            }
        }
    }
}

/// Body range of `fn name` as code indices `(open_brace, close_brace)`.
fn fn_body(sf: &SourceFile, name: &str) -> Option<(usize, usize)> {
    let n = sf.n_code();
    for ci in 0..n {
        if sf.is_ident(ci, "fn") && ci + 1 < n && sf.is_ident(ci + 1, name) {
            let mut depth = 0i64;
            let mut j = ci + 2;
            while j < n {
                match sf.ctok(j).kind {
                    TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
                    TokKind::Punct(b')') | TokKind::Punct(b']') => depth -= 1,
                    TokKind::Punct(b';') if depth <= 0 => return None,
                    TokKind::Punct(b'{') if depth <= 0 => {
                        return sf.matching(j).map(|close| (j, close));
                    }
                    _ => {}
                }
                j += 1;
            }
        }
    }
    None
}

/// Variant names (and lines) of `enum name { .. }`: identifiers at the
/// top nesting level of the enum body, skipping payload fields.
fn enum_variants(sf: &SourceFile, name: &str) -> Vec<(String, u32)> {
    let n = sf.n_code();
    let mut head = None;
    for ci in 0..n {
        if sf.is_ident(ci, "enum") && ci + 1 < n && sf.is_ident(ci + 1, name) {
            head = Some(ci);
            break;
        }
    }
    let Some(head) = head else {
        return Vec::new();
    };
    let mut open = None;
    for ci in head..n {
        if sf.ctok(ci).is_punct(b'{') {
            open = Some(ci);
            break;
        }
    }
    let Some(open) = open else {
        return Vec::new();
    };
    let Some(close) = sf.matching(open) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut depth = 0i64;
    for ci in open + 1..close {
        match sf.ctok(ci).kind {
            TokKind::Punct(b'{') | TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
            TokKind::Punct(b'}') | TokKind::Punct(b')') | TokKind::Punct(b']') => depth -= 1,
            TokKind::Ident if depth == 0 => {
                let prev = sf.ctok(ci - 1);
                if prev.is_punct(b'{') || prev.is_punct(b',') {
                    out.push((sf.ctext(ci).to_string(), sf.ctok(ci).line));
                }
            }
            _ => {}
        }
    }
    out
}

// ---------------------------------------------------------------------------
// stats-parity
// ---------------------------------------------------------------------------

/// Every field of `CoordinatorStats` must be surfaced to daemon clients
/// (named in `server/daemon.rs` outside tests — in practice the
/// `wire_stats` field list) or carry an explicit waiver. Serving metrics
/// that exist but never reach the wire rot silently.
struct StatsParity;

impl Rule for StatsParity {
    fn id(&self) -> &'static str {
        "stats-parity"
    }

    fn describe(&self) -> &'static str {
        "every CoordinatorStats field is surfaced in WireStats (server/daemon.rs) \
         or explicitly waived"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        let Some(coord) = repo.file_ending("coordinator/mod.rs") else {
            return;
        };
        let Some(daemon) = repo.file_ending("server/daemon.rs") else {
            return;
        };
        let fields = struct_fields(coord, "CoordinatorStats");
        if fields.is_empty() {
            return;
        }
        let mut surfaced: BTreeSet<String> = BTreeSet::new();
        for t in &daemon.toks {
            if daemon.in_test(t.start) {
                continue;
            }
            match t.kind {
                TokKind::Ident => {
                    surfaced.insert(t.text(&daemon.text).to_string());
                }
                TokKind::Str => {
                    if let Some(inner) = str_inner(t.text(&daemon.text)) {
                        surfaced.insert(inner.to_string());
                    }
                }
                _ => {}
            }
        }
        for (field, line) in fields {
            if !surfaced.contains(&field) {
                push(
                    out,
                    self.id(),
                    coord,
                    line,
                    format!(
                        "CoordinatorStats.{field} is not surfaced in WireStats \
                         (server/daemon.rs) — add it to wire_stats or waive"
                    ),
                );
            }
        }
    }
}

/// Field names (and lines) of `struct name { .. }`: identifiers at the
/// top nesting level followed by `:` and preceded by `pub`, `{`, or `,`.
fn struct_fields(sf: &SourceFile, name: &str) -> Vec<(String, u32)> {
    let n = sf.n_code();
    let mut head = None;
    for ci in 0..n {
        if sf.is_ident(ci, "struct") && ci + 1 < n && sf.is_ident(ci + 1, name) {
            head = Some(ci);
            break;
        }
    }
    let Some(head) = head else {
        return Vec::new();
    };
    let mut open = None;
    for ci in head..n {
        if sf.ctok(ci).is_punct(b'{') {
            open = Some(ci);
            break;
        }
        if sf.ctok(ci).is_punct(b';') {
            return Vec::new(); // unit or tuple struct
        }
    }
    let Some(open) = open else {
        return Vec::new();
    };
    let Some(close) = sf.matching(open) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let mut depth = 0i64;
    for ci in open + 1..close {
        match sf.ctok(ci).kind {
            TokKind::Punct(b'{') | TokKind::Punct(b'(') | TokKind::Punct(b'[')
            | TokKind::Punct(b'<') => depth += 1,
            TokKind::Punct(b'}') | TokKind::Punct(b')') | TokKind::Punct(b']')
            | TokKind::Punct(b'>') => depth -= 1,
            TokKind::Ident if depth == 0 => {
                let word = sf.ctext(ci);
                let prev = sf.ctok(ci - 1);
                let prev_ok = prev.is_punct(b'{')
                    || prev.is_punct(b',')
                    || (prev.kind == TokKind::Ident && sf.ctext(ci - 1) == "pub");
                let next_is_colon = ci + 1 < n
                    && sf.ctok(ci + 1).is_punct(b':')
                    && !(ci + 2 < n && sf.ctok(ci + 2).is_punct(b':'));
                if word != "pub" && prev_ok && next_is_colon {
                    out.push((word.to_string(), sf.ctok(ci).line));
                }
            }
            _ => {}
        }
    }
    out
}

/// Contents of a string-literal token (`"x"`, `r#"x"#`, `b"x"`).
fn str_inner(text: &str) -> Option<&str> {
    let first = text.find('"')?;
    let last = text.rfind('"')?;
    if last > first {
        Some(&text[first + 1..last])
    } else {
        None
    }
}

// ---------------------------------------------------------------------------
// bounded-sleep
// ---------------------------------------------------------------------------

/// Serving-path code must not call a literal `sleep`: a raw
/// `thread::sleep` ignores shutdown cancellation and turns every wait
/// into a fixed stall the drain state machine cannot interrupt. Waits
/// route through `util::backoff::pause` (plain bounded waits) or
/// `util::backoff::cancellable_sleep` (shutdown-aware); `util/` itself
/// is out of scope, so `backoff.rs` is the single sanctioned call site.
/// Test code is exempt — tests may pace themselves however they like.
struct BoundedSleep;

const SLEEP_DIRS: [&str; 3] = [
    "rust/src/server/",
    "rust/src/coordinator/",
    "rust/src/runtime/",
];

impl Rule for BoundedSleep {
    fn id(&self) -> &'static str {
        "bounded-sleep"
    }

    fn describe(&self) -> &'static str {
        "no raw `sleep` in server/, coordinator/, runtime/ outside tests — \
         route waits through util::backoff::pause or cancellable_sleep"
    }

    fn check(&self, repo: &Repo, out: &mut Vec<Finding>) {
        for sf in &repo.files {
            if !SLEEP_DIRS.iter().any(|d| sf.rel.starts_with(d)) {
                continue;
            }
            let n = sf.n_code();
            for ci in 0..n {
                let tok = sf.ctok(ci);
                if tok.kind != TokKind::Ident || sf.in_test(tok.start) {
                    continue;
                }
                if sf.ctext(ci) == "sleep" {
                    push(
                        out,
                        self.id(),
                        sf,
                        tok.line,
                        "raw `sleep` on a serving path — use `util::backoff::pause` \
                         (or `cancellable_sleep` where shutdown must interrupt)"
                            .to_string(),
                    );
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::{run, Baseline, Finding, Repo};

    /// `(file, line)` anchors of every unwaived finding for `rule`.
    fn anchors(repo: &Repo, rule: &str) -> Vec<(String, u32)> {
        run(repo, &Baseline::empty())
            .findings
            .into_iter()
            .filter(|f| f.rule == rule && !f.waived)
            .map(|f| (f.file, f.line))
            .collect()
    }

    fn waived(repo: &Repo, rule: &str) -> Vec<Finding> {
        run(repo, &Baseline::empty())
            .findings
            .into_iter()
            .filter(|f| f.rule == rule && f.waived)
            .collect()
    }

    #[test]
    fn nan_ordering_fires_on_known_bad() {
        let src = "\
pub fn worst(xs: &mut Vec<f64>) -> Option<f64> {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let safe = xs.to_vec();
    let mut ok = safe.clone();
    ok.sort_by(|a, b| a.total_cmp(b));
    xs.iter()
        .cloned()
        .max_by(|a, b| a.partial_cmp(b).expect(\"cmp\"))
}
";
        // Bench path: the directories PRs 2-5 never swept are in scope.
        let repo = Repo::from_sources(&[("rust/benches/fx.rs", src)]);
        assert_eq!(
            anchors(&repo, "nan-ordering"),
            vec![
                ("rust/benches/fx.rs".to_string(), 2),
                ("rust/benches/fx.rs".to_string(), 8),
            ]
        );
        // Nothing else fires: benches are not serve-critical dirs.
        assert_eq!(run(&repo, &Baseline::empty()).count_unwaived(), 2);
    }

    #[test]
    fn panic_freedom_fires_outside_tests_in_serve_dirs_only() {
        let bad = "\
pub fn f(x: Option<u32>) -> u32 {
    x.unwrap()
}
pub fn g(x: Option<u32>) -> u32 {
    x.expect(\"present\")
}
pub fn h(kind: u8) -> u8 {
    match kind {
        1 => 1,
        _ => unreachable!(\"bad kind\"),
    }
}
#[cfg(test)]
mod tests {
    #[test]
    fn fine_in_tests() {
        Some(1u32).unwrap();
        panic!(\"also fine\");
    }
}
";
        let repo = Repo::from_sources(&[
            ("rust/src/server/fx.rs", bad),
            // Same code outside the serve-critical dirs: no findings.
            ("rust/src/report/fx.rs", bad),
        ]);
        assert_eq!(
            anchors(&repo, "panic-freedom"),
            vec![
                ("rust/src/server/fx.rs".to_string(), 2),
                ("rust/src/server/fx.rs".to_string(), 5),
                ("rust/src/server/fx.rs".to_string(), 10),
            ]
        );
    }

    #[test]
    fn panic_freedom_respects_waiver_on_line_above() {
        let src = "\
pub fn f(x: Option<u32>) -> u32 {
    // lint:allow(panic-freedom) invariant: caller checked is_some
    x.unwrap()
}
";
        let repo = Repo::from_sources(&[("rust/src/dse/fx.rs", src)]);
        assert!(anchors(&repo, "panic-freedom").is_empty());
        let w = waived(&repo, "panic-freedom");
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].line, 3);
    }

    #[test]
    fn lock_hygiene_flags_raw_locks_not_helpers() {
        let src = "\
use std::sync::Mutex;
pub struct S { m: Mutex<u32> }
impl S {
    pub fn bad(&self) -> u32 {
        *self.m.lock().unwrap()
    }
    pub fn also_bad(&self) -> u32 {
        *self.m.lock().expect(\"poisoned\")
    }
    fn lock(&self) -> u32 {
        self.locked_helper()
    }
    pub fn fine(&self) -> u32 {
        self.lock()
    }
}
";
        let repo = Repo::from_sources(&[("examples/fx.rs", src)]);
        assert_eq!(
            anchors(&repo, "lock-hygiene"),
            vec![
                ("examples/fx.rs".to_string(), 5),
                ("examples/fx.rs".to_string(), 8),
            ]
        );
    }

    #[test]
    fn lock_order_cycle_detected() {
        let src = "\
use std::sync::Mutex;
use crate::util::lock_unpoisoned;
pub struct S { a: Mutex<u32>, b: Mutex<u32> }
impl S {
    pub fn ab(&self) -> u32 {
        let ga = lock_unpoisoned(&self.a);
        let gb = lock_unpoisoned(&self.b);
        *ga + *gb
    }
    pub fn ba(&self) -> u32 {
        let gb = lock_unpoisoned(&self.b);
        let ga = lock_unpoisoned(&self.a);
        *ga + *gb
    }
}
";
        let repo = Repo::from_sources(&[("rust/src/coordinator/fx.rs", src)]);
        let found = anchors(&repo, "lock-hygiene");
        assert_eq!(found.len(), 1, "exactly one cycle: {found:?}");
        let report = run(&repo, &Baseline::empty());
        let msg = &report
            .findings
            .iter()
            .find(|f| f.rule == "lock-hygiene")
            .expect("cycle finding")
            .message;
        assert!(msg.contains("cycle"), "{msg}");
        assert!(msg.contains("coordinator/fx::a") && msg.contains("coordinator/fx::b"));
    }

    #[test]
    fn lock_order_disjoint_scopes_are_clean() {
        // The plan_and_flush shape: guards in sibling blocks never overlap.
        let src = "\
use std::sync::Mutex;
use crate::util::lock_unpoisoned;
pub struct S { a: Mutex<u32>, b: Mutex<u32> }
impl S {
    pub fn f(&self) -> u32 {
        let x = {
            let ga = lock_unpoisoned(&self.a);
            *ga
        };
        let gb = lock_unpoisoned(&self.b);
        x + *gb
    }
    pub fn g(&self) -> u32 {
        lock_unpoisoned(&self.b).wrapping_add(1);
        let ga = lock_unpoisoned(&self.a);
        *ga
    }
}
";
        let repo = Repo::from_sources(&[("rust/src/coordinator/fx.rs", src)]);
        assert!(anchors(&repo, "lock-hygiene").is_empty());
    }

    #[test]
    fn lock_order_self_reacquire_detected() {
        let src = "\
use std::sync::Mutex;
use crate::util::lock_unpoisoned;
pub struct S { a: Mutex<u32> }
impl S {
    pub fn f(&self) -> u32 {
        let g1 = lock_unpoisoned(&self.a);
        let g2 = lock_unpoisoned(&self.a);
        *g1 + *g2
    }
}
";
        let repo = Repo::from_sources(&[("rust/src/dse/fx.rs", src)]);
        assert_eq!(
            anchors(&repo, "lock-hygiene"),
            vec![("rust/src/dse/fx.rs".to_string(), 7)]
        );
    }

    #[test]
    fn wire_exhaustiveness_fires_on_gaps() {
        let src = "\
pub const K_A: u8 = 1;
pub const K_B: u8 = 2;
pub enum Frame { A, B(u32) }
pub fn encode_frame(f: &Frame) -> u8 {
    match f {
        Frame::A => K_A,
        Frame::B(_) => 0,
    }
}
pub fn decode_frame(k: u8) -> Option<Frame> {
    match k {
        K_A => Some(Frame::A),
        K_B => Some(Frame::B(0)),
        _ => None,
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn roundtrip_a() {
        let f = Frame::A;
        assert!(decode_frame(encode_frame(&f)).is_some());
    }
}
";
        let repo = Repo::from_sources(&[("rust/src/server/protocol.rs", src)]);
        let found = anchors(&repo, "wire-exhaustiveness");
        // K_B never encoded (line 2); Frame::B never round-tripped (line 3).
        assert_eq!(
            found,
            vec![
                ("rust/src/server/protocol.rs".to_string(), 2),
                ("rust/src/server/protocol.rs".to_string(), 3),
            ]
        );
    }

    /// Known-bad graph-protocol fixture: `SUBMIT_GRAPH` frames encode
    /// and round-trip in a test, but `decode_frame` is missing the
    /// `K_SUBMIT_GRAPH` arm — exactly the one-sided wire bug the v4
    /// graph kinds could reintroduce. The rule must anchor it on the
    /// constant's declaration line.
    #[test]
    fn wire_exhaustiveness_catches_missing_submit_graph_decode() {
        let src = "\
pub const K_SUBMIT_GRAPH: u8 = 10;
pub const K_GRAPH_RESULT: u8 = 11;
pub enum Frame { SubmitGraph(u32), GraphResult(u32) }
pub fn encode_frame(f: &Frame) -> u8 {
    match f {
        Frame::SubmitGraph(_) => K_SUBMIT_GRAPH,
        Frame::GraphResult(_) => K_GRAPH_RESULT,
    }
}
pub fn decode_frame(k: u8) -> Option<Frame> {
    match k {
        K_GRAPH_RESULT => Some(Frame::GraphResult(0)),
        _ => None,
    }
}
#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn roundtrip_graph_kinds() {
        let s = Frame::SubmitGraph(1);
        assert!(decode_frame(encode_frame(&s)).is_none());
        let r = Frame::GraphResult(1);
        assert!(decode_frame(encode_frame(&r)).is_some());
    }
}
";
        let repo = Repo::from_sources(&[("rust/src/server/protocol.rs", src)]);
        // Exactly one finding: K_SUBMIT_GRAPH never decoded (line 1).
        // Both variants are exercised by the test span, and both kinds
        // are encoded, so nothing else may fire.
        assert_eq!(
            anchors(&repo, "wire-exhaustiveness"),
            vec![("rust/src/server/protocol.rs".to_string(), 1)]
        );
    }

    #[test]
    fn stats_parity_fires_and_respects_waiver() {
        let coord = "\
pub struct CoordinatorStats {
    pub jobs_completed: u64,
    pub hidden_metric: f64,
    // lint:allow(stats-parity) derived at read time from the others
    pub derived_metric: f64,
}
";
        let daemon = "\
pub fn wire_stats() -> Vec<(String, f64)> {
    vec![(\"jobs_completed\".to_string(), 1.0)]
}
";
        let repo = Repo::from_sources(&[
            ("rust/src/coordinator/mod.rs", coord),
            ("rust/src/server/daemon.rs", daemon),
        ]);
        assert_eq!(
            anchors(&repo, "stats-parity"),
            vec![("rust/src/coordinator/mod.rs".to_string(), 3)]
        );
        let w = waived(&repo, "stats-parity");
        assert_eq!(w.len(), 1);
        assert!(w[0].message.contains("derived_metric"));
    }

    #[test]
    fn stats_parity_catches_missing_resilience_counter() {
        let coord = "\
pub struct CoordinatorStats {
    pub jobs_completed: u64,
    pub retries_total: u64,
    pub failovers_total: u64,
}
";
        let daemon = "\
pub fn wire_stats() -> Vec<(String, f64)> {
    vec![
        (\"jobs_completed\".to_string(), 1.0),
        (\"retries_total\".to_string(), 2.0),
    ]
}
";
        let repo = Repo::from_sources(&[
            ("rust/src/coordinator/mod.rs", coord),
            ("rust/src/server/daemon.rs", daemon),
        ]);
        assert_eq!(
            anchors(&repo, "stats-parity"),
            vec![("rust/src/coordinator/mod.rs".to_string(), 4)]
        );
    }

    #[test]
    fn bounded_sleep_fires_in_serve_dirs_outside_tests() {
        let src = "\
use crate::util::backoff;
pub fn tick(stop: &std::sync::atomic::AtomicBool) {
    std::thread::sleep(std::time::Duration::from_millis(2));
    backoff::pause(std::time::Duration::from_millis(2));
    backoff::cancellable_sleep(std::time::Duration::from_millis(2), stop);
}
#[cfg(test)]
mod tests {
    #[test]
    fn pacing_in_tests_is_fine() {
        std::thread::sleep(std::time::Duration::from_millis(1));
    }
}
";
        let repo = Repo::from_sources(&[
            ("rust/src/server/fx.rs", src),
            // Same code outside the serving dirs (report/, util/): clean.
            ("rust/src/report/fx.rs", src),
            ("rust/src/util/fx.rs", src),
        ]);
        // Only the literal `sleep` ident fires — `backoff::pause` and
        // `cancellable_sleep` are different identifiers.
        assert_eq!(
            anchors(&repo, "bounded-sleep"),
            vec![("rust/src/server/fx.rs".to_string(), 3)]
        );
    }

    #[test]
    fn bounded_sleep_respects_waiver() {
        let src = "\
pub fn settle() {
    // lint:allow(bounded-sleep) startup settle before the first tick
    std::thread::sleep(std::time::Duration::from_millis(50));
}
";
        let repo = Repo::from_sources(&[("rust/src/runtime/fx.rs", src)]);
        assert!(anchors(&repo, "bounded-sleep").is_empty());
        let w = waived(&repo, "bounded-sleep");
        assert_eq!(w.len(), 1);
        assert_eq!(w[0].line, 3);
    }
}
