//! `versal-gemm` CLI — leader entrypoint for the framework.
//!
//! Subcommands mirror the paper's workflow:
//! * `dataset`  — offline phase: generate the ~6000-design dataset;
//! * `train`    — fit the L/P/R GBDT models (optionally with search);
//! * `dse`      — online phase: Pareto-optimal mapping for one GEMM;
//! * `report`   — regenerate any paper figure/table (see DESIGN.md §6);
//! * `serve`    — boot the coordinator and stream GEMM jobs through the
//!   selected execution backend (PJRT over the AOT Pallas kernels when
//!   artifacts exist, the blocked CPU GEMM otherwise, or the VCK190
//!   simulator via `--backend sim`);
//! * `validate` — numerics check of the PJRT runtime vs the reference.

use std::path::PathBuf;

use versal_gemm::config::Config;
use versal_gemm::coordinator::{Admission, BackendChoice, Coordinator, CoordinatorOptions, GemmJob};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::Objective;
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::report::{render, Lab};
use versal_gemm::runtime::{matmul_ref, max_abs_diff, GemmEngine};
use versal_gemm::util::cli::Args;
use versal_gemm::util::rng::Rng;
use versal_gemm::versal::{BufferPlacement, VersalSim};
use versal_gemm::workloads::{eval_workloads, training_workloads, Gemm};

const USAGE: &str = "\
versal-gemm — energy/performance-optimal GEMM mapping for Versal ACAP

USAGE:
  versal-gemm <subcommand> [options]

SUBCOMMANDS:
  dataset   --out data/dataset.csv             generate the offline-phase dataset
  train     --data-dir data [--search N]       train the L/P/R predictors
  dse       --gemm MxNxK [--objective throughput|energy] [--data-dir data]
  report    <fig1|fig3|fig4|fig6|fig7|fig8|fig9|fig10|table2|table3|model-quality|all>
            [--data-dir data] [--out file]
  serve     [--jobs N] [--artifacts artifacts] [--data-dir data]
            [--planners N] [--cache-shards N] [--cache-capacity N]
            [--plan-cache file.json]   persist/warm the plan cache across restarts
            [--max-queue N]            bound on queued + coalesced-parked jobs
            [--admission block|reject] full-queue policy (default: block)
            [--dse-threads N]          width of the process-wide DSE worker pool
                                       (default: PALLAS_DSE_THREADS, else cores)
            [--backend pjrt|cpu|sim|auto] execution backend (default: auto =
                                       PJRT if the artifacts load, else CPU)
  validate  [--artifacts artifacts]            PJRT runtime vs reference GEMM
  sweep     --model qwen|llama|deit [--seqs 32,64,..] per-layer mapping sweep
  info                                         board + workload summary

COMMON OPTIONS:
  --config path.toml     override defaults (board/sim/train/dataset sections)
  --data-dir DIR         dataset + model cache directory (default: data)
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::from_args(args)?;
    let data_dir = PathBuf::from(args.opt_or("data-dir", "data"));
    match args.subcommand.as_deref() {
        Some("dataset") => cmd_dataset(args, &cfg),
        Some("train") => cmd_train(args, &cfg, data_dir),
        Some("dse") => cmd_dse(args, &cfg, data_dir),
        Some("report") => cmd_report(args, cfg, data_dir),
        Some("serve") => cmd_serve(args, cfg, data_dir),
        Some("validate") => cmd_validate(args),
        Some("sweep") => cmd_sweep(args, cfg, data_dir),
        Some("info") => cmd_info(&cfg),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_dataset(args: &Args, cfg: &Config) -> anyhow::Result<()> {
    let out = PathBuf::from(args.opt_or("out", "data/dataset.csv"));
    eprintln!("generating offline-phase dataset (18 workloads)...");
    let started = std::time::Instant::now();
    let ds = Dataset::generate(cfg, &training_workloads());
    ds.save(cfg, &out)?;
    println!(
        "wrote {} designs across {} workloads to {} in {:.1}s",
        ds.len(),
        ds.workload_ids().len(),
        out.display(),
        started.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_train(args: &Args, cfg: &Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let ds_path = data_dir.join("dataset.csv");
    let ds = if ds_path.exists() {
        Dataset::load(cfg, &ds_path)?
    } else {
        eprintln!("no dataset at {}; generating...", ds_path.display());
        let ds = Dataset::generate(cfg, &training_workloads());
        ds.save(cfg, &ds_path)?;
        ds
    };
    let mut cfg = cfg.clone();
    cfg.train.search_trials = args.opt_usize("search", cfg.train.search_trials)?;
    if cfg.train.search_trials > 0 {
        eprintln!(
            "hyper-parameter search: {} trials (5-fold CV)...",
            cfg.train.search_trials
        );
        let x = ds.feature_matrix(cfg.board.micro_tile, FeatureSet::SetIAndII);
        let y = ds.targets(&cfg).latency_s;
        let (best, score) = versal_gemm::gbdt::cv::search_hyperparams(&x, &y, &cfg.train, true);
        println!(
            "best hyper-params: trees={} depth={} lr={:.3} (CV MAPE {:.2}%, R2 {:.4})",
            best.n_trees, best.max_depth, best.learning_rate, score.mean_mape, score.mean_r2
        );
        cfg.train = best;
    }
    let started = std::time::Instant::now();
    let model = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
    let out = data_dir.join("predictors.json");
    model.save(&out)?;
    println!(
        "trained L/P/R models on {} designs in {:.1}s -> {}",
        ds.len(),
        started.elapsed().as_secs_f64(),
        out.display()
    );
    Ok(())
}

fn cmd_dse(args: &Args, cfg: &Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let (m, n, k) = args
        .opt_gemm_dims("gemm")?
        .ok_or_else(|| anyhow::anyhow!("--gemm MxNxK is required"))?;
    let g = Gemm::new(m, n, k);
    let objective = Objective::parse(args.opt_or("objective", "throughput"))?;
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let engine = lab.engine();
    let r = engine.explore(&g)?;
    let sel = r.select(objective);
    println!(
        "GEMM {} — {} candidates, {} feasible, Pareto front of {} ({} ms)",
        g.label(),
        r.n_candidates,
        r.n_feasible,
        r.pareto.len(),
        r.elapsed.as_millis()
    );
    println!(
        "selected ({}): {}  #AIE={}",
        objective.label(),
        sel.tiling.label(),
        sel.tiling.n_aie()
    );
    println!(
        "predicted: {:.1} GFLOP/s, {:.1} W, {:.2} GFLOP/s/W",
        sel.gflops, sel.prediction.power_w, sel.energy_eff
    );
    let sim = VersalSim::new(cfg);
    match sim.evaluate(&g, &sel.tiling, BufferPlacement::UramFirst) {
        Ok(mea) => println!(
            "simulated: {:.1} GFLOP/s, {:.1} W, {:.2} GFLOP/s/W (latency {:.3} ms)",
            mea.gflops,
            mea.power_w,
            mea.energy_eff,
            mea.latency_s * 1e3
        ),
        Err(e) => println!("simulated: design failed ({e})"),
    }
    println!("\nPareto front (predicted):");
    for c in &r.pareto {
        println!(
            "  {:<28} #AIE={:<4} {:.1} GFLOP/s  {:.2} GFLOP/s/W",
            c.tiling.label(),
            c.tiling.n_aie(),
            c.gflops,
            c.energy_eff
        );
    }
    Ok(())
}

fn cmd_report(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let lab = Lab::prepare(cfg, data_dir)?;
    let text = render(&lab, id)?;
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!("wrote report to {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let n_jobs = args.opt_usize("jobs", 24)?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let n_planners = args.opt_usize("planners", 2)?;
    let defaults = CoordinatorOptions::default();
    let options = CoordinatorOptions {
        n_shards: args.opt_usize("cache-shards", defaults.n_shards)?,
        cache_capacity: args.opt_usize("cache-capacity", defaults.cache_capacity)?,
        cache_path: args.opt("plan-cache").map(PathBuf::from),
        max_queue_depth: args.opt_usize("max-queue", defaults.max_queue_depth)?,
        admission: match args.opt("admission") {
            Some(text) => Admission::parse(text)?,
            None => defaults.admission,
        },
        dse_threads: match args.opt_usize("dse-threads", 0)? {
            0 => None,
            n => Some(n),
        },
        backend: BackendChoice::parse(args.opt_or("backend", "auto"))?,
    };
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let engine = lab.engine();
    let mut coord = Coordinator::start_with(&cfg, engine, Some(artifacts), n_planners, options);

    // A small LLM-inference-like job stream over the eval workloads.
    let wl = eval_workloads();
    let mut rng = Rng::new(2025);
    let mut jobs = Vec::new();
    for i in 0..n_jobs {
        let w = &wl[rng.below(6)]; // small/medium layers for quick serving
        let g = w.gemm;
        let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
        let mut job = GemmJob::with_data(
            i as u64,
            g,
            if i % 2 == 0 {
                Objective::Throughput
            } else {
                Objective::EnergyEfficiency
            },
            a,
            b,
        );
        job.validate = i % 5 == 0;
        jobs.push(job);
    }
    let started = std::time::Instant::now();
    let results = coord.run_batch(jobs);
    let wall = started.elapsed();
    let mut ok = 0usize;
    for r in &results {
        if r.error.is_none() {
            ok += 1;
        } else {
            eprintln!("job {} failed: {:?}", r.id, r.error);
        }
        if let Some(err) = r.validation_err {
            anyhow::ensure!(err < 1e-2, "validation failed on job {}: {err}", r.id);
        }
    }
    let stats = coord.stats();
    println!(
        "served {ok}/{} jobs in {:.2}s via backend `{}` — exec throughput \
         {:.2} GFLOP/s, executed energy {:.2} J ({:.2} GFLOPS/W aggregate), \
         cache {} hits / {} misses / {} evictions ({:.0}% hit rate), \
         {} coalesced plans / {} rejected jobs / queue peak {}, \
         p50 plan latency {:.3} ms, dse pool {} threads / stage-2 gate \
         skipped {:.0}% of candidate rows, forest compile {:.1} ms / \
         predict {:.0} rows/s, simulated VCK190 energy {:.1} J",
        results.len(),
        wall.as_secs_f64(),
        coord.backend_name(),
        stats.executed_gflops(),
        stats.executed_energy_j,
        stats.executed_gflops_per_w,
        stats.cache_hits,
        stats.cache_misses,
        stats.cache_evictions,
        100.0 * stats.cache_hit_rate,
        stats.coalesced_plans,
        stats.rejected_jobs,
        stats.queue_depth_peak,
        stats.plan_p50_ms,
        stats.dse_pool_threads,
        100.0 * stats.gate_skip_rate,
        stats.forest_compile_ms,
        stats.predict_rows_per_s,
        stats.simulated_energy_j
    );
    coord.shutdown();
    Ok(())
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let engine = GemmEngine::load(&artifacts)?;
    println!("platform: {}", engine.platform());
    let mut rng = Rng::new(7);
    for (m, n, k) in [
        (32, 32, 32),
        (64, 64, 64),
        (128, 128, 128),
        (100, 200, 96),
        (32, 896, 896),
    ] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = engine.gemm(&a, &b, m, n, k)?;
        let want = matmul_ref(&a, &b, m, n, k);
        let err = max_abs_diff(&got, &want);
        println!("gemm {m}x{n}x{k}: max abs err {err:.2e}");
        anyhow::ensure!(err < 1e-2, "numerics check failed for {m}x{n}x{k}");
    }
    println!(
        "runtime validation OK ({} kernel invocations)",
        engine.invocations.get()
    );
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    use versal_gemm::workloads::models::{deit_base, llama3_1b, qwen25_05b};
    let spec = match args.opt_or("model", "qwen") {
        "qwen" => qwen25_05b(),
        "llama" => llama3_1b(),
        "deit" => deit_base(),
        other => anyhow::bail!("unknown model `{other}` (qwen|llama|deit)"),
    };
    let seqs: Vec<usize> = args
        .opt_or("seqs", "32,64,128,512")
        .split(',')
        .map(|v| v.parse().map_err(|_| anyhow::anyhow!("bad seq `{v}`")))
        .collect::<anyhow::Result<_>>()?;
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let engine = lab.engine();
    let sim = VersalSim::new(&cfg);
    println!(
        "== {}: per-layer mappings across sequence lengths ==",
        spec.name
    );
    println!(
        "{:<14} {:>5} {:>18} {:>26} {:>10} {:>9} {:>9}",
        "layer", "seq", "gemm", "mapping", "GFLOP/s", "W", "GF/s/W"
    );
    for &seq in &seqs {
        for (name, g) in spec.working_set(seq, false) {
            let r = engine.explore(&g)?;
            let Some((sel, m)) =
                versal_gemm::dse::best_buildable(&r, &sim, &g, Objective::EnergyEfficiency)
            else {
                println!("{name:<14} {seq:>5} {:>18} (no buildable design)", g.label());
                continue;
            };
            println!(
                "{:<14} {:>5} {:>18} {:>26} {:>10.1} {:>9.1} {:>9.2}",
                name,
                seq,
                g.label(),
                sel.tiling.label(),
                m.gflops,
                m.power_w,
                m.energy_eff
            );
        }
    }
    Ok(())
}

fn cmd_info(cfg: &Config) -> anyhow::Result<()> {
    println!(
        "board: {} — {} AIEs @ {:.2} GHz ({} GFLOP/s peak), DDR {:.1} GB/s",
        cfg.board.name,
        cfg.board.aie_total,
        cfg.board.aie_clock_hz / 1e9,
        cfg.board.peak_gflops(),
        cfg.board.ddr_peak_bps / 1e9
    );
    println!("\ntraining workloads (offline phase):");
    for w in training_workloads() {
        println!("  {:<14} {:<12} {}", w.id, w.source, w.gemm.label());
    }
    println!("\nevaluation workloads G1..G13 (by increasing FLOPs):");
    for w in eval_workloads() {
        println!(
            "  {:<4} {:<22} {:<18} {:.2} GFLOP, AI {:.1}",
            w.id,
            w.source,
            w.gemm.label(),
            w.gemm.flops() / 1e9,
            w.gemm.arithmetic_intensity()
        );
    }
    Ok(())
}
