//! Deterministic fault injection for the execution path (DESIGN.md
//! §10): the harness that lets the chaos suite *prove* the resilient
//! executor's guarantees instead of asserting them by inspection.
//!
//! A [`FaultPlan`] is parsed from `--faults <spec>` (or the
//! `PALLAS_FAULTS` env var) with a tiny semicolon grammar, e.g.
//!
//! ```text
//! err:p=0.2;hang:p=0.05,ms=500;slow:p=0.1,x=8;seed:7
//! ```
//!
//! and compiled into a [`FaultInjector`] holding one seeded RNG. Each
//! backend call draws once *per clause* — whether or not the clause
//! triggers or even applies to the executing backend — so the
//! injection schedule is a pure function of `(spec, seed, call index)`
//! and replays bit-identically across runs, backends, and retry
//! interleavings. [`FaultyBackend`] is a decorator over any
//! [`ExecBackend`]: production code paths never check a "chaos mode"
//! flag; with no plan configured the decorator is simply absent.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::runtime::backend::ExecBackend;
use crate::tiling::Tiling;
use crate::util::backoff;
use crate::util::lock_unpoisoned;
use crate::util::rng::Rng;
use crate::versal::Measurement;
use crate::workloads::Gemm;

/// Marker embedded in injected transient errors; the resilient
/// executor's classifier treats anything non-permanent as retryable.
pub const TRANSIENT_MARKER: &str = "injected transient fault";

/// Marker embedded in injected permanent errors; classified permanent,
/// so it trips the backend's circuit breaker immediately.
pub const PERMANENT_MARKER: &str = "injected permanent fault";

/// Hard ceiling on an injected hang: the harness exists to exercise
/// deadlines, not to wedge CI forever when a spec typo says `ms=1e9`.
const HANG_CAP_MS: u64 = 10_000;

/// Hang duration when a `hang` clause omits `ms=`.
const DEFAULT_HANG_MS: u64 = 200;

/// Injector seed when the spec has no `seed:` clause. A constant (not
/// entropy) so that omitting the clause still replays bit-identically.
const DEFAULT_SEED: u64 = 0xFA_0175;

/// What a triggered clause does to the backend call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    /// Fail with [`TRANSIENT_MARKER`] (retryable).
    Err,
    /// Fail with [`PERMANENT_MARKER`] (trips the breaker).
    Perm,
    /// Sleep `ms` before executing — a bounded hang, for deadline tests.
    Hang,
    /// Stretch the call's latency by `x` (execute, then idle `(x-1)·t`).
    Slow,
}

impl FaultKind {
    fn parse(text: &str) -> Result<FaultKind> {
        match text {
            "err" => Ok(FaultKind::Err),
            "perm" => Ok(FaultKind::Perm),
            "hang" => Ok(FaultKind::Hang),
            "slow" => Ok(FaultKind::Slow),
            other => bail!("unknown fault kind `{other}` (err|perm|hang|slow|seed)"),
        }
    }

    fn label(&self) -> &'static str {
        match self {
            FaultKind::Err => "err",
            FaultKind::Perm => "perm",
            FaultKind::Hang => "hang",
            FaultKind::Slow => "slow",
        }
    }
}

/// One `kind:p=..,..` clause of a fault spec.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultClause {
    pub kind: FaultKind,
    /// Trigger probability per backend call, in `[0, 1]`.
    pub p: f64,
    /// Hang duration (ms); meaningful for [`FaultKind::Hang`].
    pub ms: u64,
    /// Latency multiplier (≥ 1); meaningful for [`FaultKind::Slow`].
    pub x: f64,
    /// Restrict the clause to one backend tier (`backend=cpu`); `None`
    /// applies to every tier. The RNG draw happens either way, so the
    /// filter never perturbs the schedule of later clauses.
    pub backend: Option<String>,
}

/// A parsed `--faults` spec: an ordered clause list plus the RNG seed.
/// The first triggered clause that applies to the executing backend
/// wins; clause order in the spec is therefore a priority order.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPlan {
    pub clauses: Vec<FaultClause>,
    pub seed: u64,
}

impl FaultPlan {
    /// Parse a spec like `err:p=0.2;hang:p=0.05,ms=500;slow:p=0.1,x=8`.
    /// An optional `seed:N` clause pins the injector seed (default: a
    /// fixed constant, so replays are deterministic either way).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut clauses = Vec::new();
        let mut seed = DEFAULT_SEED;
        for raw in spec.split(';') {
            let clause = raw.trim();
            if clause.is_empty() {
                continue;
            }
            let (head, params) = match clause.split_once(':') {
                Some((h, p)) => (h.trim(), p.trim()),
                None => bail!("fault clause `{clause}` missing `:` (want kind:p=..)"),
            };
            if head == "seed" {
                seed = params
                    .parse::<u64>()
                    .map_err(|_| anyhow::anyhow!("bad fault seed `{params}`"))?;
                continue;
            }
            let kind = FaultKind::parse(head)?;
            let mut p = None;
            let mut ms = DEFAULT_HANG_MS;
            let mut x = 1.0f64;
            let mut backend = None;
            for param in params.split(',') {
                let param = param.trim();
                if param.is_empty() {
                    continue;
                }
                let (key, value) = match param.split_once('=') {
                    Some((k, v)) => (k.trim(), v.trim()),
                    None => bail!("fault param `{param}` in `{clause}` missing `=`"),
                };
                match key {
                    "p" => {
                        let v: f64 = value
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad probability `{value}`"))?;
                        if !(0.0..=1.0).contains(&v) {
                            bail!("fault probability {v} outside [0, 1] in `{clause}`");
                        }
                        p = Some(v);
                    }
                    "ms" => {
                        let v: u64 = value
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad hang duration `{value}`"))?;
                        ms = v.min(HANG_CAP_MS);
                    }
                    "x" => {
                        let v: f64 = value
                            .parse()
                            .map_err(|_| anyhow::anyhow!("bad slow factor `{value}`"))?;
                        if v < 1.0 {
                            bail!("slow factor {v} < 1 in `{clause}`");
                        }
                        x = v;
                    }
                    "backend" => backend = Some(value.to_string()),
                    other => bail!("unknown fault param `{other}` in `{clause}`"),
                }
            }
            let p = match p {
                Some(p) => p,
                None => bail!("fault clause `{clause}` missing p=<probability>"),
            };
            clauses.push(FaultClause {
                kind,
                p,
                ms,
                x,
                backend,
            });
        }
        if clauses.is_empty() {
            bail!("fault spec `{spec}` has no clauses");
        }
        Ok(FaultPlan { clauses, seed })
    }

    /// Plan from the `PALLAS_FAULTS` environment variable, if set and
    /// non-empty. A malformed spec is an error, not a silent no-op —
    /// chaos the operator asked for must not quietly disable itself.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var("PALLAS_FAULTS") {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec).map(Some),
            _ => Ok(None),
        }
    }

    /// Canonical re-rendering of the spec, for the serve summary.
    pub fn label(&self) -> String {
        let mut parts: Vec<String> = self
            .clauses
            .iter()
            .map(|c| {
                let mut s = format!("{}:p={}", c.kind.label(), c.p);
                if c.kind == FaultKind::Hang {
                    s.push_str(&format!(",ms={}", c.ms));
                }
                if c.kind == FaultKind::Slow {
                    s.push_str(&format!(",x={}", c.x));
                }
                if let Some(b) = &c.backend {
                    s.push_str(&format!(",backend={b}"));
                }
                s
            })
            .collect();
        parts.push(format!("seed:{}", self.seed));
        parts.join(";")
    }
}

/// What the injector decided for one backend call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultDecision {
    Fail { permanent: bool },
    Hang { ms: u64 },
    Slow { x: f64 },
}

/// The seeded decision engine. `Send + Sync` (one mutex-guarded RNG)
/// so a single injector can be shared between the executor thread and
/// the watchdog worker — one global call counter, one schedule.
pub struct FaultInjector {
    plan: FaultPlan,
    rng: Mutex<Rng>,
    injected: AtomicU64,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        let rng = Mutex::new(Rng::new(plan.seed));
        FaultInjector {
            plan,
            rng,
            injected: AtomicU64::new(0),
        }
    }

    /// Decide the fate of one backend call. Every clause consumes
    /// exactly one RNG draw regardless of whether it triggers or
    /// applies to `backend`, so the schedule depends only on the call
    /// index — never on which tier happens to be executing.
    pub fn decide(&self, backend: &str) -> Option<FaultDecision> {
        let mut rng = lock_unpoisoned(&self.rng);
        let mut chosen = None;
        for clause in &self.plan.clauses {
            let hit = rng.bool(clause.p);
            let applies = match clause.backend.as_deref() {
                Some(b) => b == backend,
                None => true,
            };
            if hit && applies && chosen.is_none() {
                chosen = Some(match clause.kind {
                    FaultKind::Err => FaultDecision::Fail { permanent: false },
                    FaultKind::Perm => FaultDecision::Fail { permanent: true },
                    FaultKind::Hang => FaultDecision::Hang { ms: clause.ms },
                    FaultKind::Slow => FaultDecision::Slow { x: clause.x },
                });
            }
        }
        if chosen.is_some() {
            self.injected.fetch_add(1, Ordering::SeqCst);
        }
        chosen
    }

    /// Total faults actually injected (triggered *and* applicable).
    pub fn injected(&self) -> u64 {
        self.injected.load(Ordering::SeqCst)
    }

    pub fn plan(&self) -> &FaultPlan {
        &self.plan
    }
}

/// Decorator injecting the plan's faults around any [`ExecBackend`].
/// Identity (`name`, hints, profiles, measurements) passes through
/// untouched — stats stay honest about which tier really executed.
pub struct FaultyBackend {
    inner: Box<dyn ExecBackend>,
    injector: Arc<FaultInjector>,
}

impl FaultyBackend {
    pub fn wrap(inner: Box<dyn ExecBackend>, injector: Arc<FaultInjector>) -> FaultyBackend {
        FaultyBackend { inner, injector }
    }
}

impl ExecBackend for FaultyBackend {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn supports(&self, g: &Gemm) -> bool {
        self.inner.supports(g)
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        match self.injector.decide(self.inner.name()) {
            Some(FaultDecision::Fail { permanent: false }) => {
                bail!("{TRANSIENT_MARKER}: {m}x{n}x{k} on `{}`", self.inner.name())
            }
            Some(FaultDecision::Fail { permanent: true }) => {
                bail!("{PERMANENT_MARKER}: {m}x{n}x{k} on `{}`", self.inner.name())
            }
            Some(FaultDecision::Hang { ms }) => {
                backoff::pause(Duration::from_millis(ms));
                self.inner.gemm(a, b, m, n, k)
            }
            Some(FaultDecision::Slow { x }) => {
                let started = Instant::now();
                let c = self.inner.gemm(a, b, m, n, k)?;
                backoff::pause(started.elapsed().mul_f64((x - 1.0).max(0.0)));
                Ok(c)
            }
            None => self.inner.gemm(a, b, m, n, k),
        }
    }

    fn variant_hint(&self, m: usize, n: usize, k: usize) -> Option<usize> {
        self.inner.variant_hint(m, n, k)
    }

    fn kernel_profile(&self) -> Option<&'static str> {
        self.inner.kernel_profile()
    }

    fn board_measurement(&self, g: &Gemm, t: &Tiling) -> Option<Measurement> {
        self.inner.board_measurement(g, t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::backend::CpuBackend;
    use crate::runtime::{matmul_ref, max_abs_diff};

    #[test]
    fn parses_the_issue_example_spec() {
        let plan = FaultPlan::parse("err:p=0.2;hang:p=0.05,ms=500;slow:p=0.1,x=8").unwrap();
        assert_eq!(plan.clauses.len(), 3);
        assert_eq!(plan.seed, DEFAULT_SEED);
        assert_eq!(
            plan.clauses[0],
            FaultClause {
                kind: FaultKind::Err,
                p: 0.2,
                ms: DEFAULT_HANG_MS,
                x: 1.0,
                backend: None,
            }
        );
        assert_eq!(plan.clauses[1].kind, FaultKind::Hang);
        assert_eq!(plan.clauses[1].ms, 500);
        assert_eq!(plan.clauses[2].kind, FaultKind::Slow);
        assert_eq!(plan.clauses[2].x, 8.0);
    }

    #[test]
    fn parses_seed_backend_filter_and_perm() {
        let plan = FaultPlan::parse("perm:p=1,backend=cpu;seed:42").unwrap();
        assert_eq!(plan.seed, 42);
        assert_eq!(plan.clauses[0].kind, FaultKind::Perm);
        assert_eq!(plan.clauses[0].backend.as_deref(), Some("cpu"));
        assert!(plan.label().contains("seed:42"));
        assert!(plan.label().contains("perm:p=1,backend=cpu"));
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "",
            "nope:p=0.5",
            "err",
            "err:p=1.5",
            "err:q=0.5",
            "slow:p=0.5,x=0.5",
            "seed:banana",
            "err:p=abc",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "`{bad}` should not parse");
        }
    }

    #[test]
    fn hang_durations_are_capped() {
        let plan = FaultPlan::parse("hang:p=1,ms=999999999").unwrap();
        assert_eq!(plan.clauses[0].ms, HANG_CAP_MS);
    }

    #[test]
    fn same_seed_replays_identical_schedule() {
        let spec = "err:p=0.3;hang:p=0.1,ms=50;slow:p=0.2,x=4;seed:9";
        let a = FaultInjector::new(FaultPlan::parse(spec).unwrap());
        let b = FaultInjector::new(FaultPlan::parse(spec).unwrap());
        let schedule_a: Vec<_> = (0..300).map(|_| a.decide("cpu")).collect();
        let schedule_b: Vec<_> = (0..300).map(|_| b.decide("cpu")).collect();
        assert_eq!(schedule_a, schedule_b);
        assert_eq!(a.injected(), b.injected());
        assert!(a.injected() > 0, "p=0.3 over 300 calls must trigger");
        // A different seed produces a different schedule.
        let c = FaultInjector::new(
            FaultPlan::parse("err:p=0.3;hang:p=0.1,ms=50;slow:p=0.2,x=4;seed:10").unwrap(),
        );
        let schedule_c: Vec<_> = (0..300).map(|_| c.decide("cpu")).collect();
        assert_ne!(schedule_a, schedule_c);
    }

    #[test]
    fn backend_filter_gates_the_decision_not_the_draw() {
        // Same seed: the cpu-only clause fires for cpu calls but never
        // for sim calls, and the draw sequence is identical either way.
        let spec = "err:p=0.5,backend=cpu;seed:3";
        let on_cpu = FaultInjector::new(FaultPlan::parse(spec).unwrap());
        let on_sim = FaultInjector::new(FaultPlan::parse(spec).unwrap());
        let cpu_hits = (0..200).filter(|_| on_cpu.decide("cpu").is_some()).count();
        let sim_hits = (0..200).filter(|_| on_sim.decide("sim").is_some()).count();
        assert!(cpu_hits > 0);
        assert_eq!(sim_hits, 0);
    }

    #[test]
    fn faulty_backend_injects_and_passes_through() {
        let (m, n, k) = (16, 16, 16);
        let a = vec![1.0f32; m * k];
        let b = vec![2.0f32; k * n];
        // p=1 transient: every call fails with the transient marker.
        let always = FaultyBackend::wrap(
            Box::new(CpuBackend::new()),
            Arc::new(FaultInjector::new(FaultPlan::parse("err:p=1").unwrap())),
        );
        let err = always.gemm(&a, &b, m, n, k).unwrap_err().to_string();
        assert!(err.contains(TRANSIENT_MARKER), "{err}");
        assert_eq!(always.name(), "cpu", "identity passes through");
        // p=0: numerics are untouched.
        let never = FaultyBackend::wrap(
            Box::new(CpuBackend::new()),
            Arc::new(FaultInjector::new(FaultPlan::parse("err:p=0").unwrap())),
        );
        let got = never.gemm(&a, &b, m, n, k).unwrap();
        assert!(max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k)) == 0.0);
        assert_eq!(never.injector.injected(), 0);
    }

    #[test]
    fn permanent_faults_carry_the_permanent_marker() {
        let faulty = FaultyBackend::wrap(
            Box::new(CpuBackend::new()),
            Arc::new(FaultInjector::new(FaultPlan::parse("perm:p=1").unwrap())),
        );
        let err = faulty
            .gemm(&[0.0; 4], &[0.0; 4], 2, 2, 2)
            .unwrap_err()
            .to_string();
        assert!(err.contains(PERMANENT_MARKER), "{err}");
    }

    #[test]
    fn from_env_rejects_malformed_and_accepts_absent() {
        // No env var set in the test process: absent is Ok(None).
        std::env::remove_var("PALLAS_FAULTS");
        assert!(FaultPlan::from_env().unwrap().is_none());
    }
}
