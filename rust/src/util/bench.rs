//! Minimal benchmark harness (criterion is not in the offline crate
//! set). Used by every `benches/*.rs` target via `harness = false`.
//!
//! Reports min / median / mean / p95 over timed iterations after a
//! warm-up phase, plus derived throughput when the caller supplies a
//! work unit.

use std::time::{Duration, Instant};

/// One benchmark's timing summary.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: usize,
    pub min: Duration,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
}

impl BenchStats {
    pub fn per_sec(&self) -> f64 {
        1.0 / self.mean.as_secs_f64()
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> BenchStats {
    assert!(iters > 0);
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort();
    let total: Duration = samples.iter().sum();
    BenchStats {
        iters,
        min: samples[0],
        median: samples[samples.len() / 2],
        mean: total / iters as u32,
        p95: samples[((samples.len() as f64 * 0.95) as usize).min(samples.len() - 1)],
    }
}

/// Pretty-print a named result row.
pub fn report(name: &str, stats: &BenchStats) {
    println!(
        "{name:<44} {:>10.3?} min  {:>10.3?} med  {:>10.3?} mean  {:>10.3?} p95  ({} iters)",
        stats.min, stats.median, stats.mean, stats.p95, stats.iters
    );
}

/// Pretty-print with a throughput figure (`units` processed per call).
pub fn report_throughput(name: &str, stats: &BenchStats, units: f64, unit_name: &str) {
    println!(
        "{name:<44} {:>10.3?} med   {:>12.1} {unit_name}/s",
        stats.median,
        units / stats.median.as_secs_f64()
    );
}

/// Wall-clock one closure once (for end-to-end phases).
pub fn once<T>(name: &str, f: impl FnOnce() -> T) -> T {
    let t = Instant::now();
    let out = f();
    println!("{name:<44} {:>10.3?} (single run)", t.elapsed());
    out
}
