//! Bench: L3 execution hot path — the pluggable backends behind
//! `runtime::backend`.
//!
//! Section 1 exercises the always-available CPU backend (blocked tiled
//! GEMM, row panels on the shared DSE pool) against the reference
//! GEMM. Section 2 serves data jobs through a coordinator with
//! `--backend cpu` and asserts the per-job energy accounting
//! (`energy_j` / `avg_power_w` / `gflops_per_w`) is present, finite,
//! and mutually consistent. Section 3 is the original PJRT tiled
//! executor over the AOT Pallas artifacts (requires `make artifacts`).
//!
//! `--smoke` (CI on every PR) runs sections 1–2 only with reduced
//! shapes and a tiny in-memory model, so the execution path and the
//! energy fields are covered even where no artifacts exist.
use versal_gemm::config::Config;
use versal_gemm::coordinator::{BackendChoice, Coordinator, CoordinatorOptions, GemmJob};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::{DseEngine, Objective};
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::runtime::backend::{CpuBackend, ExecBackend};
use versal_gemm::runtime::{matmul_ref, max_abs_diff, GemmEngine};
use versal_gemm::util::bench::{bench, once, report, report_throughput};
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::{training_workloads, Gemm};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");

    // ---- 1. CPU backend: blocked tiled GEMM on the shared pool ---------
    println!("== bench: cpu execution backend (blocked tiled GEMM, DsePool row panels) ==");
    let cpu = CpuBackend::new();
    let mut rng = Rng::new(3);
    let shapes: &[(usize, usize, usize)] = if smoke {
        &[(128, 128, 128), (70, 50, 90)]
    } else {
        &[(128, 128, 128), (256, 256, 256), (32, 896, 896), (512, 512, 512)]
    };
    for &(m, n, k) in shapes {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let flops = 2.0 * (m * n * k) as f64;
        let got = cpu.gemm(&a, &b, m, n, k)?;
        let err = max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k));
        assert!(err < 1e-2, "cpu backend numerics {m}x{n}x{k}: err {err}");
        let (warmup, iters) = if smoke { (1, 3) } else { (2, 8) };
        let stats = bench(warmup, iters, || {
            std::hint::black_box(cpu.gemm(&a, &b, m, n, k).unwrap());
        });
        report(&format!("cpu gemm {m}x{n}x{k}"), &stats);
        report_throughput("  throughput", &stats, flops / 1e9, "GFLOP");
    }

    // ---- 2. serving energy accounting over the CPU backend -------------
    println!("== bench: coordinator data jobs + per-job energy accounting (backend cpu) ==");
    let mut cfg = Config::default();
    cfg.dataset.top_k = 10;
    cfg.dataset.bottom_k = 6;
    cfg.dataset.random_k = 30;
    cfg.train.n_trees = 60;
    cfg.train.learning_rate = 0.2;
    let engine = once("offline phase (reduced dataset + train)", || {
        let wl: Vec<_> = training_workloads().into_iter().take(4).collect();
        let ds = Dataset::generate(&cfg, &wl);
        DseEngine::new(Predictors::train(&ds, &cfg, FeatureSet::SetIAndII), &cfg.board)
    });
    let options = CoordinatorOptions {
        backend: BackendChoice::Cpu,
        ..CoordinatorOptions::default()
    };
    let mut coord = Coordinator::start_with(&cfg, engine, None, 2, options);
    let n_jobs = if smoke { 4u64 } else { 12 };
    let jobs: Vec<GemmJob> = (0..n_jobs)
        .map(|i| {
            let g = Gemm::new(64 * (1 + i as usize % 3), 256, 128);
            let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
            let mut j = GemmJob::with_data(i, g, Objective::Throughput, a, b);
            j.validate = i % 2 == 0;
            j
        })
        .collect();
    let results = once(&format!("run_batch ({n_jobs} data jobs)"), || {
        coord.run_batch(jobs)
    });
    assert_eq!(results.len(), n_jobs as usize);
    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
        let exec = r.exec_time.expect("executed").as_secs_f64();
        let energy = r.energy_j.expect("energy accounted");
        let avg_w = r.avg_power_w.expect("avg power");
        let gpw = r.gflops_per_w.expect("gflops/W");
        assert!(energy.is_finite() && energy > 0.0, "job {}: energy {energy}", r.id);
        assert!(avg_w.is_finite() && avg_w > 0.0);
        assert!(gpw.is_finite() && gpw > 0.0);
        let drift = (energy - avg_w * exec).abs() / energy;
        assert!(drift < 1e-9, "job {}: energy/power inconsistent ({drift})", r.id);
        if let Some(err) = r.validation_err {
            assert!(err < 1e-2, "job {} numerics {err}", r.id);
        }
    }
    let stats = coord.stats();
    assert_eq!(coord.backend_name(), "cpu");
    assert_eq!(stats.executed_jobs, n_jobs);
    assert!(stats.executed_energy_j > 0.0);
    assert!(stats.executed_gflops_per_w > 0.0);
    println!(
        "backend `{}`: {} jobs, {:.2} GFLOP/s, {:.3} J total, {:.2} GFLOPS/W aggregate",
        coord.backend_name(),
        stats.executed_jobs,
        stats.executed_gflops(),
        stats.executed_energy_j,
        stats.executed_gflops_per_w
    );
    coord.shutdown();
    if smoke {
        println!("smoke OK: cpu backend numerics + energy accounting");
        return Ok(());
    }

    // ---- 3. PJRT tiled executor over the AOT artifacts -----------------
    let engine = GemmEngine::load(std::path::Path::new("artifacts"))?;
    println!("== bench: PJRT tiled GEMM executor (platform {}) ==", engine.platform());
    let mut rng = Rng::new(3);
    for &(m, n, k) in &[(128usize, 128usize, 128usize), (256, 256, 256), (32, 896, 896), (512, 512, 512)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let flops = 2.0 * (m * n * k) as f64;
        let stats = bench(2, 8, || {
            std::hint::black_box(engine.gemm(&a, &b, m, n, k).unwrap());
        });
        report(&format!("pjrt gemm {m}x{n}x{k}"), &stats);
        report_throughput("  throughput", &stats, flops / 1e9, "GFLOP");
        let ref_stats = bench(1, 3, || {
            std::hint::black_box(matmul_ref(&a, &b, m, n, k));
        });
        report(&format!("rust ref  {m}x{n}x{k}"), &ref_stats);
    }
    println!("total kernel invocations: {}", engine.invocations.get());
    Ok(())
}
