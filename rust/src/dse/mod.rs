//! Online phase: ML-driven design space exploration (paper §IV-B).
//!
//! Given a GEMM and an objective, the engine *streams* the tiling
//! candidate space ([`crate::tiling::candidate_iter`]), featurizes and
//! batch-predicts `{𝓛, 𝓟, 𝓡}` in fixed-size chunks through the
//! pretrained models, filters configurations that do not fit the PL,
//! folds survivors into an incremental Pareto front on the
//! (throughput, energy-efficiency) plane, and returns the best mapping
//! for the requested objective. Paper: "less than 2 sec. per workload".
//!
//! The streaming path never materializes the candidate set: cooperative
//! tasks on the process-wide [`DsePool`] pull [`PREDICT_CHUNK`]-sized
//! batches off a shared lazy iterator, so peak memory is O(front +
//! feasible) rather than O(|C(G)|), and the Pareto front is maintained
//! insert-by-insert instead of by a full post-hoc sweep. Ties are broken
//! by the tiling tuple so results are deterministic regardless of worker
//! interleaving and pool width (`streaming_matches_materialized_path`
//! and `explore_is_identical_across_pool_sizes` check it).
//!
//! Prediction is **two-stage and resource-gated** by default: stage 1
//! runs only the 5 𝓡 outputs and applies `fits(resource_margin_pct)`;
//! stage 2 pays the (heavier) 𝓛/𝓟 ensembles only for the survivors.
//! Selections are bit-identical with gating on or off — the gate merely
//! skips tree walks whose outputs the resource filter was about to
//! discard (see `Predictors::predict_rows_gated`).
//!
//! [`ExhaustiveExplorer`] is the ground-truth twin used for Fig. 4 / 10:
//! it measures every candidate on the simulator instead of predicting.

pub mod compare;
pub mod pool;

pub use pool::DsePool;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::metrics::{hypervolume_2d, pareto_front_max};
use crate::models::{Prediction, Predictors};
use crate::tiling::{candidate_iter, enumerate_candidates, CandidateIter, Tiling, TilingLimits};
use crate::util::lock_unpoisoned;
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::Gemm;

/// Candidates per featurize+predict batch on the streaming hot path.
pub const PREDICT_CHUNK: usize = 256;

/// Chunks one cooperative pool turn processes before yielding its
/// worker, so concurrent explorations sharing [`DsePool`] interleave at
/// ~millisecond granularity instead of serializing behind whole
/// explorations.
const TURN_CHUNKS: usize = 4;

/// Optimization objective of the online phase.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Objective {
    Throughput,
    EnergyEfficiency,
}

impl Objective {
    pub fn label(&self) -> &'static str {
        match self {
            Objective::Throughput => "throughput",
            Objective::EnergyEfficiency => "energy-eff",
        }
    }

    pub fn parse(text: &str) -> anyhow::Result<Objective> {
        match text {
            "throughput" | "thr" | "perf" => Ok(Objective::Throughput),
            "energy" | "energy-eff" | "eff" => Ok(Objective::EnergyEfficiency),
            other => anyhow::bail!("unknown objective `{other}` (throughput|energy)"),
        }
    }
}

/// One candidate with its predicted metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CandidateEval {
    pub tiling: Tiling,
    pub prediction: Prediction,
    pub gflops: f64,
    pub energy_eff: f64,
}

/// Total-order tie-break key: ensures every selection is deterministic
/// even when two candidates predict identical metrics and when worker
/// threads process chunks in different orders.
fn tiling_key(t: &Tiling) -> (usize, usize, usize, usize, usize, usize) {
    (t.p_m, t.p_n, t.p_k, t.b_m, t.b_n, t.b_k)
}

/// `true` iff the new candidate strictly beats the incumbent on the
/// metric, or ties it with a smaller tiling key. NaN metrics never win.
fn improves(metric_new: f64, new: &Tiling, metric_cur: f64, cur: &Tiling) -> bool {
    match metric_new.total_cmp(&metric_cur) {
        std::cmp::Ordering::Greater => true,
        std::cmp::Ordering::Less => false,
        std::cmp::Ordering::Equal => tiling_key(new) < tiling_key(cur),
    }
}

fn dominates(a: &CandidateEval, b: &CandidateEval) -> bool {
    a.gflops >= b.gflops
        && a.energy_eff >= b.energy_eff
        && (a.gflops > b.gflops || a.energy_eff > b.energy_eff)
}

/// Incrementally maintained 2-D maximization Pareto front.
///
/// Inserts are O(front size), which stays in the tens for this design
/// space — far cheaper than re-sweeping every feasible candidate, and
/// insertion-order independent (exact-coordinate duplicates resolve to
/// the smallest tiling key).
#[derive(Debug, Clone, Default)]
pub struct ParetoFront {
    points: Vec<CandidateEval>,
}

impl ParetoFront {
    pub fn insert(&mut self, c: CandidateEval) {
        if !(c.gflops.is_finite() && c.energy_eff.is_finite()) {
            return;
        }
        if let Some(i) = self
            .points
            .iter()
            .position(|p| p.gflops == c.gflops && p.energy_eff == c.energy_eff)
        {
            if tiling_key(&c.tiling) < tiling_key(&self.points[i].tiling) {
                self.points[i] = c;
            }
            return;
        }
        if self.points.iter().any(|p| dominates(p, &c)) {
            return;
        }
        self.points.retain(|p| !dominates(&c, p));
        self.points.push(c);
    }

    pub fn merge(&mut self, other: ParetoFront) {
        for c in other.points {
            self.insert(c);
        }
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// The front, throughput-descending (cosmetic parity with the old
    /// sweep-based extraction).
    pub fn into_sorted(mut self) -> Vec<CandidateEval> {
        self.points.sort_by(|a, b| {
            b.gflops
                .total_cmp(&a.gflops)
                .then_with(|| tiling_key(&a.tiling).cmp(&tiling_key(&b.tiling)))
        });
        self.points
    }
}

/// Result of one DSE run.
#[derive(Debug, Clone)]
pub struct DseResult {
    pub gemm: Gemm,
    /// Number of enumerated candidates (|C(G)|).
    pub n_candidates: usize,
    /// Candidates surviving the resource filter.
    pub n_feasible: usize,
    /// Candidates the stage-1 resource gate rejected, skipping their
    /// latency/power tree walks entirely (0 with gating disabled).
    pub n_gated: usize,
    /// Predicted Pareto front (throughput x energy-eff, maximization).
    pub pareto: Vec<CandidateEval>,
    /// Every feasible candidate (resource-filtered), unordered.
    pub feasible: Vec<CandidateEval>,
    pub best_throughput: CandidateEval,
    pub best_energy: CandidateEval,
    pub elapsed: std::time::Duration,
}

impl DseResult {
    pub fn select(&self, objective: Objective) -> &CandidateEval {
        match objective {
            Objective::Throughput => &self.best_throughput,
            Objective::EnergyEfficiency => &self.best_energy,
        }
    }

    /// All feasible candidates, best-first by the objective — the retry
    /// order when a selected design fails to build. Deterministic: ties
    /// on the metric resolve by the tiling tuple.
    pub fn ranked(&self, objective: Objective) -> Vec<CandidateEval> {
        let mut out = self.feasible.clone();
        out.sort_by(rank_cmp(objective));
        out
    }

    /// The best `k` feasible candidates by the objective — what the
    /// build-retry walk actually consumes (`best_buildable` and the
    /// coordinator try at most 64). Partial selection: the ~25k feasible
    /// candidates are partitioned around the k-th best in O(n) and only
    /// the survivors sorted, instead of the full O(n log n) sort
    /// [`DseResult::ranked`] pays. The comparator is a total order
    /// (metric, then tiling tuple), so the result equals the first `k`
    /// entries of `ranked` exactly.
    pub fn ranked_top(&self, objective: Objective, k: usize) -> Vec<CandidateEval> {
        if k == 0 {
            return Vec::new();
        }
        let cmp = rank_cmp(objective);
        let mut out = self.feasible.clone();
        if k < out.len() {
            let _ = out.select_nth_unstable_by(k - 1, &cmp);
            out.truncate(k);
        }
        out.sort_by(&cmp);
        out
    }
}

/// Total-order ranking comparator for one objective: metric descending,
/// ties broken by the tiling tuple.
fn rank_cmp(objective: Objective) -> impl Fn(&CandidateEval, &CandidateEval) -> std::cmp::Ordering {
    move |a, b| {
        let (ka, kb) = match objective {
            Objective::Throughput => (a.gflops, b.gflops),
            Objective::EnergyEfficiency => (a.energy_eff, b.energy_eff),
        };
        kb.total_cmp(&ka)
            .then_with(|| tiling_key(&a.tiling).cmp(&tiling_key(&b.tiling)))
    }
}

/// Per-task accumulator for one streaming pass. A task owns its
/// accumulator across cooperative pool turns; accumulators merge with
/// total-order tie-breaks after the scope completes, so the merge is
/// independent of which task saw which chunk.
#[derive(Debug, Default)]
struct StreamAcc {
    n_candidates: usize,
    /// Candidates the stage-1 resource gate rejected.
    n_gated: usize,
    feasible: Vec<CandidateEval>,
    front: ParetoFront,
    best_thr: Option<CandidateEval>,
    best_eff: Option<CandidateEval>,
}

impl StreamAcc {
    fn fold(&mut self, c: CandidateEval) {
        if self
            .best_thr
            .map_or(true, |b| improves(c.gflops, &c.tiling, b.gflops, &b.tiling))
        {
            self.best_thr = Some(c);
        }
        if self.best_eff.map_or(true, |b| {
            improves(c.energy_eff, &c.tiling, b.energy_eff, &b.tiling)
        }) {
            self.best_eff = Some(c);
        }
        self.front.insert(c);
        self.feasible.push(c);
    }
}

/// Per-pool-worker scratch reused across chunks, turns, and entire
/// explorations — pool workers are process-lifetime threads, so these
/// buffers are allocated once per worker and stay warm.
#[derive(Debug, Default)]
struct WorkerScratch {
    batch: Vec<Tiling>,
    rows: Vec<f64>,
    preds: Vec<Prediction>,
    /// Stage-1 survivor indices (compaction index of the gated path).
    surv: Vec<u32>,
}

thread_local! {
    static SCRATCH: std::cell::RefCell<WorkerScratch> =
        std::cell::RefCell::new(WorkerScratch::default());
}

/// Process-wide gauge of threads currently executing DSE streaming work,
/// counted at stream-turn granularity on *whatever* thread runs the turn.
/// Unlike the pool's own active counter (bounded by construction), this
/// would catch a regression back to per-exploration thread spawning —
/// the concurrency bench asserts its peak never exceeds the pool width.
static DSE_ACTIVE: AtomicUsize = AtomicUsize::new(0);
static DSE_ACTIVE_PEAK: AtomicUsize = AtomicUsize::new(0);

/// High-water mark of threads concurrently executing DSE streaming work
/// since process start.
pub fn active_dse_workers_peak() -> usize {
    DSE_ACTIVE_PEAK.load(Ordering::SeqCst)
}

/// RAII guard around one stream turn: decrements the gauge even if the
/// turn panics (the pool catches the unwind; the gauge must not leak).
struct DseActiveGuard;

impl DseActiveGuard {
    fn enter() -> DseActiveGuard {
        let now = DSE_ACTIVE.fetch_add(1, Ordering::SeqCst) + 1;
        DSE_ACTIVE_PEAK.fetch_max(now, Ordering::SeqCst);
        DseActiveGuard
    }
}

impl Drop for DseActiveGuard {
    fn drop(&mut self) {
        DSE_ACTIVE.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The ML-driven DSE engine.
#[derive(Debug, Clone)]
pub struct DseEngine {
    pub predictors: Predictors,
    pub limits: TilingLimits,
    pub micro: usize,
    /// Safety margin (percent) on predicted resource utilization —
    /// absorbs 𝓡-model error so selected designs actually build.
    pub resource_margin_pct: f64,
    /// Two-stage resource-gated prediction: stage 1 predicts only the 5
    /// 𝓡 outputs and applies `fits(resource_margin_pct)`; stage 2 runs
    /// the 𝓛/𝓟 trees on the survivors only. Selections are
    /// bit-identical with gating on or off (property-tested); `false`
    /// is the full-prediction baseline the benches compare against.
    pub gate: bool,
    /// Worker-pool override (determinism tests, benches); `None` routes
    /// explorations through the shared process-wide [`DsePool::global`].
    pool: Option<Arc<DsePool>>,
}

impl DseEngine {
    pub fn new(predictors: Predictors, board: &crate::config::BoardConfig) -> DseEngine {
        // Compile the forest-inference arena up front so the one-time
        // flatten cost lands here instead of inside the first explore's
        // latency budget (the OnceLock would otherwise fire lazily on
        // the first worker's first chunk).
        predictors.forest();
        DseEngine {
            predictors,
            limits: TilingLimits::from_board(board),
            micro: board.micro_tile,
            resource_margin_pct: 4.0,
            gate: true,
            pool: None,
        }
    }

    /// Route this engine's explorations through a dedicated pool instead
    /// of the process-global one (pool-width determinism tests, benches).
    pub fn with_pool(mut self, pool: Arc<DsePool>) -> DseEngine {
        self.pool = Some(pool);
        self
    }

    fn pool(&self) -> &DsePool {
        match &self.pool {
            Some(p) => p,
            None => DsePool::global(),
        }
    }

    /// Width of the worker pool explorations run on (0 when the shared
    /// global pool has not spun up yet).
    pub fn pool_threads(&self) -> usize {
        match &self.pool {
            Some(p) => p.n_threads(),
            None => DsePool::get_global().map_or(0, DsePool::n_threads),
        }
    }

    /// Evaluate one already-predicted candidate against the filters;
    /// returns `None` for designs that do not fit or whose predictions
    /// degenerate (NaN/non-positive — never propagated downstream).
    fn admit(&self, g: &Gemm, t: &Tiling, prediction: &Prediction) -> Option<CandidateEval> {
        if !prediction.fits(self.resource_margin_pct) {
            return None;
        }
        let gflops = prediction.gflops(g);
        let energy_eff = prediction.energy_eff(g);
        if !(gflops.is_finite() && gflops > 0.0 && energy_eff.is_finite() && energy_eff > 0.0) {
            return None;
        }
        Some(CandidateEval {
            tiling: *t,
            prediction: *prediction,
            gflops,
            energy_eff,
        })
    }

    /// One cooperative turn of one streaming task: pull up to
    /// [`TURN_CHUNKS`] fixed-size chunks off the shared lazy iterator,
    /// featurize into per-worker scratch, predict (two-stage gated when
    /// [`DseEngine::gate`] is set), and fold survivors into the task's
    /// accumulator. Returns `true` while the iterator may hold more work
    /// (the pool re-enqueues the task behind other explorations' turns),
    /// `false` once drained or cancelled.
    fn stream_turn(
        &self,
        g: &Gemm,
        shared: &Mutex<CandidateIter>,
        cancel: &AtomicBool,
        acc: &mut StreamAcc,
    ) -> bool {
        let n_feat = self.predictors.feature_set.len();
        let _active = DseActiveGuard::enter();
        SCRATCH.with(|cell| {
            let scratch = &mut *cell.borrow_mut();
            let WorkerScratch {
                batch,
                rows,
                preds,
                surv,
            } = scratch;
            for _ in 0..TURN_CHUNKS {
                // Cancellation hook (coordinator shutdown while plan
                // waiters park on this exploration): stop pulling chunks;
                // the partial result is discarded by `explore_with_cancel`.
                if cancel.load(Ordering::Relaxed) {
                    return false;
                }
                batch.clear();
                {
                    let mut it = lock_unpoisoned(shared);
                    while batch.len() < PREDICT_CHUNK {
                        match it.next() {
                            Some(t) => batch.push(t),
                            None => break,
                        }
                    }
                }
                if batch.is_empty() {
                    return false;
                }
                acc.n_candidates += batch.len();
                rows.clear();
                for t in batch.iter() {
                    let full = crate::features::featurize(g, t, self.micro);
                    rows.extend_from_slice(&full[..n_feat]);
                }
                if self.gate {
                    // Stage 1 predicts only the 5 resource outputs; rows
                    // the fits() filter rejects never pay the 𝓛/𝓟 trees
                    // (stage 2 runs on the in-place-compacted survivors).
                    let n_rows = self.predictors.predict_rows_gated(
                        rows,
                        n_feat,
                        self.resource_margin_pct,
                        surv,
                        preds,
                    );
                    acc.n_gated += n_rows - surv.len();
                    for (&ri, p) in surv.iter().zip(preds.iter()) {
                        if let Some(c) = self.admit(g, &batch[ri as usize], p) {
                            acc.fold(c);
                        }
                    }
                } else {
                    self.predictors.predict_rows(rows, n_feat, preds);
                    for (t, p) in batch.iter().zip(preds.iter()) {
                        if let Some(c) = self.admit(g, t, p) {
                            acc.fold(c);
                        }
                    }
                }
            }
            true
        })
    }

    /// Run the full online phase for one workload, streaming the
    /// candidate space across the shared DSE worker pool.
    pub fn explore(&self, g: &Gemm) -> anyhow::Result<DseResult> {
        self.explore_with_cancel(g, &AtomicBool::new(false))
    }

    /// [`DseEngine::explore`] with a cooperative cancellation hook: when
    /// `cancel` becomes true, tasks stop pulling candidate chunks and
    /// the exploration returns an error instead of a (partial) result.
    /// The coordinator raises the flag at shutdown so an in-flight cold
    /// plan — possibly with a queue of coalesced waiters parked on it —
    /// aborts promptly instead of finishing a doomed sweep.
    ///
    /// Execution model: `n_threads` cooperative tasks are submitted to
    /// the shared [`DsePool`] (no per-exploration thread spawning — K
    /// concurrent explorations share pool-size workers, not K x 8).
    /// Each task folds into its own accumulator; a panicking task turn
    /// degrades to a recoverable error here, exactly like the old
    /// scoped-thread join did. Selection is deterministic regardless of
    /// pool width or interleaving: accumulator merging uses the same
    /// total-order tiling-tuple tie-breaks as the fold itself.
    pub fn explore_with_cancel(&self, g: &Gemm, cancel: &AtomicBool) -> anyhow::Result<DseResult> {
        let start = std::time::Instant::now();
        let shared = Mutex::new(candidate_iter(g, self.micro, &self.limits));
        let pool = self.pool();
        let n_tasks = pool.n_threads();
        let states: Vec<Mutex<StreamAcc>> = (0..n_tasks)
            .map(|_| Mutex::new(StreamAcc::default()))
            .collect();
        // The per-task mutex is uncontended by construction (at most one
        // turn of a task runs at a time); it exists to hand `&mut` state
        // through the `Sync` closure the pool requires.
        let panics = pool.run_scoped(n_tasks, |i| {
            let mut acc = lock_unpoisoned(&states[i]);
            self.stream_turn(g, &shared, cancel, &mut acc)
        });
        if panics > 0 {
            anyhow::bail!("dse worker panicked for {}", g.label());
        }

        if cancel.load(Ordering::Relaxed) {
            anyhow::bail!("dse cancelled for {}", g.label());
        }

        let mut n_candidates = 0usize;
        let mut n_gated = 0usize;
        let mut feasible = Vec::new();
        let mut front = ParetoFront::default();
        let mut best_thr: Option<CandidateEval> = None;
        let mut best_eff: Option<CandidateEval> = None;
        for state in states {
            let acc = state.into_inner().unwrap_or_else(|e| e.into_inner());
            n_candidates += acc.n_candidates;
            n_gated += acc.n_gated;
            feasible.extend(acc.feasible);
            front.merge(acc.front);
            if let Some(c) = acc.best_thr {
                if best_thr.map_or(true, |b| improves(c.gflops, &c.tiling, b.gflops, &b.tiling)) {
                    best_thr = Some(c);
                }
            }
            if let Some(c) = acc.best_eff {
                if best_eff.map_or(true, |b| {
                    improves(c.energy_eff, &c.tiling, b.energy_eff, &b.tiling)
                }) {
                    best_eff = Some(c);
                }
            }
        }

        if n_candidates == 0 {
            anyhow::bail!("no tiling candidates for {}", g.label());
        }
        let (Some(best_throughput), Some(best_energy)) = (best_thr, best_eff) else {
            anyhow::bail!("no feasible design for {}", g.label());
        };

        Ok(DseResult {
            gemm: *g,
            n_candidates,
            n_feasible: feasible.len(),
            n_gated,
            pareto: front.into_sorted(),
            feasible,
            best_throughput,
            best_energy,
            elapsed: start.elapsed(),
        })
    }
}

/// The best design that actually builds on the simulator, walking the
/// ranked list (absorbs resource-model error — the real flow re-runs
/// codegen on the next candidate after a failed bitstream).
pub fn best_buildable(
    r: &DseResult,
    sim: &VersalSim,
    g: &Gemm,
    objective: Objective,
) -> Option<(CandidateEval, Measurement)> {
    r.ranked_top(objective, 64).into_iter().find_map(|c| {
        sim.evaluate(g, &c.tiling, BufferPlacement::UramFirst)
            .ok()
            .map(|m| (c, m))
    })
}

/// Epsilon-relaxed Pareto front: keeps every candidate not dominated by
/// a strict-front member with margin `eps` on BOTH axes. Prediction
/// error collapses many truly-Pareto designs onto near-misses; the
/// relaxed front (paper's "set with candidate GEMM mappings") recovers
/// them for Fig. 10-style frontier construction.
///
/// Hardened: empty input or `cap == 0` yields an empty front, NaN
/// metrics are skipped, and exact-duplicate tilings are collapsed.
pub fn epsilon_pareto(cands: &[CandidateEval], eps: f64, cap: usize) -> Vec<CandidateEval> {
    if cands.is_empty() || cap == 0 || !eps.is_finite() {
        return Vec::new();
    }
    let front = pareto_candidates(cands);
    let mut out: Vec<CandidateEval> = cands
        .iter()
        .filter(|c| c.gflops.is_finite() && c.energy_eff.is_finite())
        .filter(|c| {
            !front.iter().any(|f| {
                f.gflops >= c.gflops * (1.0 + eps) && f.energy_eff >= c.energy_eff * (1.0 + eps)
            })
        })
        .copied()
        .collect();
    out.sort_by(|a, b| {
        b.gflops
            .total_cmp(&a.gflops)
            .then_with(|| tiling_key(&a.tiling).cmp(&tiling_key(&b.tiling)))
    });
    out.dedup_by(|a, b| a.tiling == b.tiling);
    out.truncate(cap);
    out
}

/// Extract the Pareto-optimal subset of candidate evaluations.
/// NaN metrics are skipped rather than panicking the comparison sort.
pub fn pareto_candidates(cands: &[CandidateEval]) -> Vec<CandidateEval> {
    let mut front = ParetoFront::default();
    for c in cands {
        front.insert(*c);
    }
    front.into_sorted()
}

/// Ground-truth exploration: measure every candidate on the simulator
/// (the paper's "actual Pareto front from exhaustive experiments").
#[derive(Debug, Clone)]
pub struct ExhaustiveExplorer {
    pub sim: VersalSim,
    pub limits: TilingLimits,
    pub placement: BufferPlacement,
}

impl ExhaustiveExplorer {
    pub fn new(sim: VersalSim) -> ExhaustiveExplorer {
        let limits = TilingLimits::from_board(&sim.board);
        ExhaustiveExplorer {
            sim,
            limits,
            placement: BufferPlacement::UramFirst,
        }
    }

    /// All buildable designs with their measurements.
    pub fn explore(&self, g: &Gemm) -> Vec<(Tiling, Measurement)> {
        enumerate_candidates(g, self.sim.board.micro_tile, &self.limits)
            .into_iter()
            .filter_map(|t| self.sim.evaluate(g, &t, self.placement).ok().map(|m| (t, m)))
            .collect()
    }

    pub fn best_by(&self, g: &Gemm, objective: Objective) -> Option<(Tiling, Measurement)> {
        self.explore(g).into_iter().max_by(|a, b| {
            let ka = match objective {
                Objective::Throughput => a.1.gflops,
                Objective::EnergyEfficiency => a.1.energy_eff,
            };
            let kb = match objective {
                Objective::Throughput => b.1.gflops,
                Objective::EnergyEfficiency => b.1.energy_eff,
            };
            ka.total_cmp(&kb)
        })
    }

    /// The true Pareto front as (throughput, energy-eff) points.
    pub fn true_front(&self, g: &Gemm) -> Vec<(f64, f64)> {
        let pts: Vec<(f64, f64)> = self
            .explore(g)
            .iter()
            .map(|(_, m)| (m.gflops, m.energy_eff))
            .collect();
        pareto_front_max(&pts)
    }
}

/// Hypervolume of a set of measured designs against a reference scale
/// (Fig. 10's quality metric).
pub fn measured_hypervolume(points: &[(f64, f64)], scale: (f64, f64)) -> f64 {
    hypervolume_2d(points, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::dataset::Dataset;
    use crate::features::FeatureSet;
    use crate::workloads::training_workloads;

    fn quick_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 12;
        cfg.dataset.bottom_k = 8;
        cfg.dataset.random_k = 60;
        cfg.train.n_trees = 100;
        cfg.train.learning_rate = 0.15;
        cfg
    }

    fn engine(cfg: &Config) -> DseEngine {
        let wl: Vec<_> = training_workloads().into_iter().take(6).collect();
        let ds = Dataset::generate(cfg, &wl);
        let predictors = Predictors::train(&ds, cfg, FeatureSet::SetIAndII);
        DseEngine::new(predictors, &cfg.board)
    }

    #[test]
    fn explore_returns_consistent_result() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(512, 1024, 768);
        let r = eng.explore(&g).unwrap();
        assert!(r.n_candidates > 100);
        assert!(r.n_feasible > 0 && r.n_feasible <= r.n_candidates);
        assert!(!r.pareto.is_empty());
        // Objective winners lie on the Pareto front extremes.
        assert!(r.best_throughput.gflops >= r.pareto.iter().map(|c| c.gflops).fold(0.0, f64::max) - 1e-9);
        assert!(
            r.best_energy.energy_eff
                >= r.pareto.iter().map(|c| c.energy_eff).fold(0.0, f64::max) - 1e-9
        );
        assert_eq!(r.select(Objective::Throughput).tiling, r.best_throughput.tiling);
    }

    #[test]
    fn dse_under_two_seconds() {
        // Paper §V-A: DSE with the ML model takes < 2 s per workload.
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(1024, 4864, 896); // large candidate space
        let r = eng.explore(&g).unwrap();
        assert!(
            r.elapsed.as_secs_f64() < 2.0,
            "DSE took {:?} for {} candidates",
            r.elapsed,
            r.n_candidates
        );
    }

    #[test]
    fn pareto_front_is_nondominated_and_sorted() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let r = eng.explore(&Gemm::new(256, 2048, 512)).unwrap();
        let front = &r.pareto;
        for i in 0..front.len() {
            for j in 0..front.len() {
                if i == j {
                    continue;
                }
                let dominates = front[j].gflops >= front[i].gflops
                    && front[j].energy_eff >= front[i].energy_eff
                    && (front[j].gflops > front[i].gflops
                        || front[j].energy_eff > front[i].energy_eff);
                assert!(!dominates, "front member {i} dominated by {j}");
            }
        }
        // into_sorted order: throughput-descending.
        for w in front.windows(2) {
            assert!(w[0].gflops >= w[1].gflops);
        }
    }

    #[test]
    fn streaming_matches_materialized_path() {
        // The streaming/batched/incremental path must select exactly the
        // mappings the old materialize-everything path selected.
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        for g in [
            Gemm::new(512, 1024, 768),
            Gemm::new(224, 3072, 768),
            Gemm::new(128, 256, 128),
            Gemm::new(32, 896, 896),
        ] {
            let r = eng.explore(&g).unwrap();

            // Reference: eager enumeration, per-candidate prediction.
            let cands = enumerate_candidates(&g, eng.micro, &eng.limits);
            let n_feat = eng.predictors.feature_set.len();
            let mut feasible: Vec<CandidateEval> = Vec::new();
            for t in &cands {
                let full = crate::features::featurize(&g, t, eng.micro);
                let p = eng.predictors.predict_row(&full[..n_feat]);
                if let Some(c) = eng.admit(&g, t, &p) {
                    feasible.push(c);
                }
            }
            assert_eq!(r.n_candidates, cands.len(), "{}", g.label());
            assert_eq!(r.n_feasible, feasible.len(), "{}", g.label());

            let best_thr = feasible
                .iter()
                .copied()
                .reduce(|a, b| {
                    if improves(b.gflops, &b.tiling, a.gflops, &a.tiling) {
                        b
                    } else {
                        a
                    }
                })
                .unwrap();
            let best_eff = feasible
                .iter()
                .copied()
                .reduce(|a, b| {
                    if improves(b.energy_eff, &b.tiling, a.energy_eff, &a.tiling) {
                        b
                    } else {
                        a
                    }
                })
                .unwrap();
            assert_eq!(r.best_throughput.tiling, best_thr.tiling, "{}", g.label());
            assert_eq!(r.best_energy.tiling, best_eff.tiling, "{}", g.label());

            // Same Pareto set (as a set of tilings).
            let mut want: Vec<_> = pareto_candidates(&feasible)
                .iter()
                .map(|c| c.tiling)
                .collect();
            let mut got: Vec<_> = r.pareto.iter().map(|c| c.tiling).collect();
            want.sort_by_key(tiling_key);
            got.sort_by_key(tiling_key);
            assert_eq!(got, want, "{}", g.label());
        }
    }

    /// Tilings of a result's Pareto front, sorted (set comparison).
    fn pareto_tilings(r: &DseResult) -> Vec<Tiling> {
        let mut out: Vec<Tiling> = r.pareto.iter().map(|c| c.tiling).collect();
        out.sort_by_key(tiling_key);
        out
    }

    #[test]
    fn explore_is_identical_across_pool_sizes() {
        // The acceptance property behind `PALLAS_DSE_THREADS`: the env
        // var only sizes the process-global pool, so pinning dedicated
        // pools of 1 / 2 / 8 workers exercises exactly the same widths.
        // Selection, Pareto set, and counts must not depend on width or
        // interleaving.
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(224, 3072, 768);
        let base = eng.explore(&g).unwrap();
        for n in [1usize, 2, 8] {
            let eng_n = eng.clone().with_pool(std::sync::Arc::new(DsePool::new(n)));
            let r = eng_n.explore(&g).unwrap();
            assert_eq!(r.n_candidates, base.n_candidates, "{n} threads");
            assert_eq!(r.n_feasible, base.n_feasible, "{n} threads");
            assert_eq!(r.n_gated, base.n_gated, "{n} threads");
            assert_eq!(r.best_throughput.tiling, base.best_throughput.tiling, "{n} threads");
            assert_eq!(r.best_energy.tiling, base.best_energy.tiling, "{n} threads");
            assert_eq!(pareto_tilings(&r), pareto_tilings(&base), "{n} threads");
        }
    }

    #[test]
    fn gated_explore_matches_ungated() {
        // The tentpole equivalence: two-stage resource gating must not
        // change any selection — it only skips latency/power tree walks
        // for candidates the fits() filter rejects anyway.
        let cfg = quick_cfg();
        let gated = engine(&cfg);
        let mut ungated = gated.clone();
        ungated.gate = false;
        for g in [
            Gemm::new(512, 1024, 768),
            Gemm::new(224, 3072, 768),
            Gemm::new(32, 896, 896),
        ] {
            let a = gated.explore(&g).unwrap();
            let b = ungated.explore(&g).unwrap();
            assert_eq!(a.n_candidates, b.n_candidates, "{}", g.label());
            assert_eq!(a.n_feasible, b.n_feasible, "{}", g.label());
            assert_eq!(a.best_throughput.tiling, b.best_throughput.tiling, "{}", g.label());
            assert_eq!(a.best_energy.tiling, b.best_energy.tiling, "{}", g.label());
            assert_eq!(pareto_tilings(&a), pareto_tilings(&b), "{}", g.label());
            // Gate accounting: the ungated path skips nothing; the gated
            // path skips exactly the candidates that fail fits(), all of
            // which are infeasible.
            assert_eq!(b.n_gated, 0, "{}", g.label());
            assert!(a.n_gated <= a.n_candidates - a.n_feasible, "{}", g.label());
        }
    }

    #[test]
    fn ranked_top_equals_ranked_prefix() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let r = eng.explore(&Gemm::new(512, 1024, 768)).unwrap();
        for objective in [Objective::Throughput, Objective::EnergyEfficiency] {
            let full = r.ranked(objective);
            for k in [0usize, 1, 7, 64, full.len(), full.len() + 100] {
                let top = r.ranked_top(objective, k);
                assert_eq!(top.len(), k.min(full.len()), "k={k}");
                for (a, b) in top.iter().zip(&full) {
                    assert_eq!(a.tiling, b.tiling, "k={k}");
                }
            }
        }
    }

    #[test]
    fn explore_is_deterministic_across_runs() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(224, 3072, 768);
        let a = eng.explore(&g).unwrap();
        let b = eng.explore(&g).unwrap();
        assert_eq!(a.best_throughput.tiling, b.best_throughput.tiling);
        assert_eq!(a.best_energy.tiling, b.best_energy.tiling);
        assert_eq!(a.pareto.len(), b.pareto.len());
        for (x, y) in a.pareto.iter().zip(&b.pareto) {
            assert_eq!(x.tiling, y.tiling);
        }
    }

    #[test]
    fn pareto_helpers_survive_degenerate_inputs() {
        // Empty input.
        assert!(pareto_candidates(&[]).is_empty());
        assert!(epsilon_pareto(&[], 0.05, 10).is_empty());
        let mk = |gf: f64, ee: f64, p_m: usize| CandidateEval {
            tiling: Tiling::new((p_m, 1, 1), (1, 1, 1)),
            prediction: Prediction {
                latency_s: 1.0,
                power_w: 1.0,
                resources_pct: [0.0; 5],
            },
            gflops: gf,
            energy_eff: ee,
        };
        // NaN points are skipped, not propagated.
        let cands = [mk(f64::NAN, 1.0, 1), mk(2.0, f64::NAN, 2), mk(1.0, 1.0, 3)];
        let front = pareto_candidates(&cands);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].tiling.p_m, 3);
        // Duplicate points collapse deterministically (smallest key wins).
        let dups = [mk(1.0, 1.0, 5), mk(1.0, 1.0, 2), mk(1.0, 1.0, 9)];
        let front = pareto_candidates(&dups);
        assert_eq!(front.len(), 1);
        assert_eq!(front[0].tiling.p_m, 2);
        // epsilon_pareto with cap 0 and duplicate tilings.
        assert!(epsilon_pareto(&dups, 0.05, 0).is_empty());
        let eps = epsilon_pareto(&[mk(1.0, 1.0, 2), mk(1.0, 1.0, 2)], 0.05, 10);
        assert_eq!(eps.len(), 1);
    }

    #[test]
    fn cancelled_explore_errors_instead_of_returning_partial_result() {
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let g = Gemm::new(512, 1024, 768);
        // Pre-set flag: workers pull nothing, the call must surface the
        // cancellation (not "no candidates", not a partial front).
        let cancel = AtomicBool::new(true);
        let err = eng.explore_with_cancel(&g, &cancel).unwrap_err();
        assert!(err.to_string().contains("cancelled"), "got: {err}");
        // The same engine still explores normally afterwards.
        cancel.store(false, Ordering::Relaxed);
        assert!(eng.explore_with_cancel(&g, &cancel).is_ok());
    }

    #[test]
    fn exhaustive_best_matches_objective() {
        let cfg = quick_cfg();
        let ex = ExhaustiveExplorer::new(VersalSim::new(&cfg));
        let g = Gemm::new(224, 768, 768);
        let all = ex.explore(&g);
        assert!(all.len() > 50);
        let (_, thr) = ex.best_by(&g, Objective::Throughput).unwrap();
        let (_, eff) = ex.best_by(&g, Objective::EnergyEfficiency).unwrap();
        for (_, m) in &all {
            assert!(m.gflops <= thr.gflops + 1e-9);
            assert!(m.energy_eff <= eff.energy_eff + 1e-9);
        }
    }

    #[test]
    fn ml_selection_close_to_true_optimum() {
        // The point of the paper: ML-selected designs land near the true
        // best (analytical selections often do not).
        let cfg = quick_cfg();
        let eng = engine(&cfg);
        let ex = ExhaustiveExplorer::new(VersalSim::new(&cfg));
        let g = Gemm::new(512, 768, 768); // near training distribution
        let r = eng.explore(&g).unwrap();
        let sim = VersalSim::new(&cfg);
        let measured = sim
            .evaluate(&g, &r.best_throughput.tiling, BufferPlacement::UramFirst)
            .unwrap();
        let (_, true_best) = ex.best_by(&g, Objective::Throughput).unwrap();
        let ratio = measured.gflops / true_best.gflops;
        assert!(ratio > 0.7, "ML pick at {ratio:.2} of true optimum");
    }
}
