//! Analytical performance models and the prior-work selection policies
//! (the paper's comparison baselines, reimplemented from their published
//! cost models — see DESIGN.md §1).
//!
//! * [`AnalyticalModel`] — ARIES-style closed-form latency/throughput
//!   estimate: ideal MAC pipeline + fixed-efficiency DDR roofline. This
//!   is also what guides the offline-phase *sampling* (§IV-A.1).
//! * [`AriesPolicy`] — full tiling space, analytical throughput
//!   objective, conservative resource constraints.
//! * [`CharmPolicy`] — a fixed family of pre-designed monolithic
//!   accelerators; workloads are padded up to the accelerator tile
//!   (CHARM's one-size design: efficient for large GEMMs, wasteful for
//!   small ones — visible in Table III where CHARM holds 112–256 AIEs
//!   even on G1).
//!
//! What these models deliberately ignore — cascade sync, placement
//! congestion, burst-length-dependent DDR efficiency, row-buffer
//! effects, broadcast serialization, per-iteration overheads — is what
//! the simulator includes; the mismatch is the documented ~27% MAPE of
//! Fig. 7.

use crate::config::BoardConfig;
use crate::tiling::{enumerate_candidates, Tiling, TilingLimits};
use crate::versal::pl::{self, BufferPlacement};
use crate::workloads::Gemm;

/// ARIES-style analytical model [19]: latency = max(compute, ddr) with
/// ideal compute and a fixed DDR efficiency.
#[derive(Debug, Clone)]
pub struct AnalyticalModel {
    pub board: BoardConfig,
    /// Assumed flat DDR efficiency (prior works calibrate one constant).
    pub ddr_efficiency: f64,
    /// Assumed kernel efficiency (prior works quote ~95% pipelined).
    pub kernel_efficiency: f64,
}

impl AnalyticalModel {
    pub fn new(board: &BoardConfig) -> AnalyticalModel {
        AnalyticalModel {
            board: board.clone(),
            ddr_efficiency: 0.72,
            kernel_efficiency: 0.95,
        }
    }

    /// Estimated latency (s); `None` if the tiling does not partition.
    pub fn latency(&self, g: &Gemm, t: &Tiling) -> Option<f64> {
        let micro = self.board.micro_tile;
        let (t_m, t_n, t_k) = t.l3_iters(g, micro)?;
        let iters = (t_m * t_n * t_k) as f64;
        // Ideal compute: each AIE runs B micro-kernels per iteration at
        // `kernel_efficiency` of the 8 MAC/cycle pipeline.
        let micro_cycles =
            (micro * micro * micro) as f64 / self.board.macs_per_cycle / self.kernel_efficiency;
        let compute = iters * (t.b_m * t.b_n * t.b_k) as f64 * micro_cycles
            / self.board.aie_clock_hz;
        // DDR: total traffic at a flat efficiency.
        let (l2m, l2n, l2k) = t.l2_tile(micro);
        let bytes = iters * (4 * (l2m * l2k + l2k * l2n)) as f64
            + (t_m * t_n) as f64 * (4 * l2m * l2n) as f64;
        let ddr = bytes / (self.board.ddr_peak_bps * self.ddr_efficiency);
        Some(compute.max(ddr))
    }

    /// Estimated throughput (GFLOP/s) on the unpadded workload.
    pub fn throughput(&self, g: &Gemm, t: &Tiling) -> Option<f64> {
        self.latency(g, t).map(|l| g.flops() / l / 1e9)
    }

    /// Resource estimate: prior works get the buffer arithmetic right
    /// (it is deterministic) — reuse the exact allocator.
    pub fn resources(&self, t: &Tiling, placement: BufferPlacement) -> pl::Resources {
        pl::resources(t, &self.board, placement)
    }
}

/// A design selected by a baseline policy: the tiling plus the workload
/// the hardware actually computes (CHARM pads; ARIES/ours do not beyond
/// the 32-alignment the mapper always applies).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SelectedDesign {
    pub tiling: Tiling,
    /// Effective (padded) workload the accelerator executes.
    pub effective: Gemm,
    pub placement: BufferPlacement,
}

/// ARIES [19]: enumerate the full space, filter by (conservative)
/// resources, pick the analytically-best throughput.
#[derive(Debug, Clone)]
pub struct AriesPolicy {
    pub model: AnalyticalModel,
    /// Conservative utilization cap applied during selection.
    pub util_cap: f64,
}

impl AriesPolicy {
    pub fn new(board: &BoardConfig) -> AriesPolicy {
        AriesPolicy {
            model: AnalyticalModel::new(board),
            util_cap: 0.85,
        }
    }

    pub fn select(&self, g: &Gemm) -> Option<SelectedDesign> {
        let limits = TilingLimits::from_board(&self.model.board);
        let cands = enumerate_candidates(g, self.model.board.micro_tile, &limits);
        let placement = BufferPlacement::UramFirst;
        let mut best: Option<(f64, Tiling)> = None;
        for t in cands {
            let res = self.model.resources(&t, placement);
            if res.max_utilization(&self.model.board) > self.util_cap {
                continue;
            }
            if let Some(thr) = self.model.throughput(g, &t) {
                if thr > best.map(|(b, _)| b).unwrap_or(0.0) {
                    best = Some((thr, t));
                }
            }
        }
        best.map(|(_, tiling)| SelectedDesign {
            tiling,
            effective: g.padded(self.model.board.micro_tile),
            placement,
        })
    }
}

/// One pre-designed CHARM accelerator: fixed AIE array and buffer tile.
#[derive(Debug, Clone, Copy)]
pub struct CharmAccel {
    pub name: &'static str,
    pub tiling: Tiling,
}

/// CHARM [14]: a small family of monolithic accelerators designed for
/// large square GEMMs; a workload is padded up to the accelerator's
/// level-2 tile and run on the analytically best family member.
#[derive(Debug, Clone)]
pub struct CharmPolicy {
    pub model: AnalyticalModel,
    pub family: Vec<CharmAccel>,
}

impl CharmPolicy {
    pub fn new(board: &BoardConfig) -> CharmPolicy {
        // Family mirrors the published CHARM design points (Table III
        // shows CHARM at 112/128/224/256 AIEs with large BRAM reuse).
        let family = vec![
            CharmAccel {
                name: "charm_256",
                tiling: Tiling::new((8, 8, 4), (2, 2, 1)),
            },
            CharmAccel {
                name: "charm_224",
                tiling: Tiling::new((8, 7, 4), (2, 2, 1)),
            },
            CharmAccel {
                name: "charm_128",
                tiling: Tiling::new((4, 4, 8), (2, 2, 1)),
            },
            CharmAccel {
                name: "charm_112",
                tiling: Tiling::new((4, 7, 4), (2, 2, 1)),
            },
        ];
        CharmPolicy {
            model: AnalyticalModel::new(board),
            family,
        }
    }

    /// Pad `g` up so the accelerator's level-2 tile partitions it.
    pub fn padded_workload(&self, g: &Gemm, accel: &CharmAccel) -> Gemm {
        let micro = self.model.board.micro_tile;
        let (l2m, l2n, l2k) = accel.tiling.l2_tile(micro);
        let pad = |d: usize, step: usize| d.div_ceil(step) * step;
        Gemm::new(pad(g.m, l2m), pad(g.n, l2n), pad(g.k, l2k))
    }

    pub fn select(&self, g: &Gemm) -> Option<SelectedDesign> {
        let placement = BufferPlacement::BramOnly;
        let mut best: Option<(f64, SelectedDesign)> = None;
        for accel in &self.family {
            let eff = self.padded_workload(g, accel);
            let res = self.model.resources(&accel.tiling, placement);
            if !res.fits(&self.model.board) {
                continue;
            }
            // Analytical throughput w.r.t. the ORIGINAL workload: padding
            // waste shows up as lost throughput.
            let lat = match self.model.latency(&eff, &accel.tiling) {
                Some(l) => l,
                None => continue,
            };
            let thr = g.flops() / lat / 1e9;
            if thr > best.as_ref().map(|(b, _)| *b).unwrap_or(0.0) {
                best = Some((
                    thr,
                    SelectedDesign {
                        tiling: accel.tiling,
                        effective: eff,
                        placement,
                    },
                ));
            }
        }
        best.map(|(_, d)| d)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::versal::{BufferPlacement, VersalSim};
    use crate::workloads::eval_workloads;

    fn board() -> BoardConfig {
        BoardConfig::default()
    }

    #[test]
    fn analytical_latency_positive_and_ordered() {
        let m = AnalyticalModel::new(&board());
        let g = Gemm::new(1024, 1024, 1024);
        let small = m.latency(&g, &Tiling::new((2, 2, 1), (1, 1, 1))).unwrap();
        let big = m.latency(&g, &Tiling::new((8, 8, 4), (2, 2, 2))).unwrap();
        assert!(big < small, "more AIEs should be analytically faster");
        assert!(m.latency(&Gemm::new(96, 96, 96), &Tiling::new((2, 1, 1), (1, 1, 1))).is_none());
    }

    #[test]
    fn analytical_underestimates_simulator_latency() {
        // The analytical model is optimistic: it ignores congestion,
        // cascade, burst effects and overheads.
        let cfg = Config::default();
        let sim = VersalSim::new(&cfg);
        let m = AnalyticalModel::new(&cfg.board);
        let g = Gemm::new(2048, 2048, 2048);
        let t = Tiling::new((8, 8, 4), (2, 2, 2));
        let est = m.latency(&g, &t).unwrap();
        let truth = sim
            .evaluate_noiseless(&g, &t, BufferPlacement::UramFirst)
            .unwrap()
            .latency_s;
        assert!(est < truth, "est {est} truth {truth}");
        assert!(est > truth * 0.3, "not absurdly optimistic");
    }

    #[test]
    fn aries_selects_valid_design_for_all_eval_workloads() {
        let policy = AriesPolicy::new(&board());
        for w in eval_workloads() {
            let d = policy.select(&w.gemm).unwrap_or_else(|| panic!("{} no design", w.id));
            assert!(d.tiling.l3_iters(&w.gemm, 32).is_some());
            let res = policy.model.resources(&d.tiling, d.placement);
            assert!(res.fits(&board()));
        }
    }

    #[test]
    fn charm_family_fits_and_pads() {
        let policy = CharmPolicy::new(&board());
        for accel in &policy.family {
            let res = policy
                .model
                .resources(&accel.tiling, BufferPlacement::BramOnly);
            assert!(res.fits(&board()), "{} does not fit", accel.name);
        }
        let g = Gemm::new(32, 896, 896);
        let d = policy.select(&g).unwrap();
        // CHARM keeps a big array even for a tiny workload...
        assert!(d.tiling.n_aie() >= 112, "n_aie {}", d.tiling.n_aie());
        // ...and pads the workload up to its own tile.
        assert!(d.effective.m >= g.m && d.effective.flops() > g.flops());
        assert_eq!(d.effective.m % d.tiling.l2_tile(32).0, 0);
    }

    #[test]
    fn charm_wastes_flops_on_small_workloads() {
        let policy = CharmPolicy::new(&board());
        let small = Gemm::new(32, 896, 896);
        let d = policy.select(&small).unwrap();
        let waste = d.effective.flops() / small.flops();
        assert!(waste > 2.0, "padding waste only {waste}x");
        let big = Gemm::new(2048, 8192, 2048);
        let d2 = policy.select(&big).unwrap();
        let waste2 = d2.effective.flops() / big.flops();
        assert!(waste2 < 1.3, "big workloads should pad little: {waste2}");
    }

    #[test]
    fn aries_beats_charm_analytically_on_small_workloads() {
        let aries = AriesPolicy::new(&board());
        let charm = CharmPolicy::new(&board());
        let g = Gemm::new(32, 896, 896);
        let da = aries.select(&g).unwrap();
        let dc = charm.select(&g).unwrap();
        let m = AnalyticalModel::new(&board());
        let thr_a = g.flops() / m.latency(&g, &da.tiling).unwrap();
        let thr_c = g.flops() / m.latency(&dc.effective, &dc.tiling).unwrap();
        assert!(thr_a > thr_c);
    }
}
