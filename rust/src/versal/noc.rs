//! NoC / PL→AIE stream feed model.
//!
//! Operand tiles leave the PL reuse buffers and enter the AIE array over
//! AXI streams (PLIO). Each AIE consumes two operand streams and emits
//! one result stream (or forwards partial sums along the cascade). Feed
//! time overlaps compute via double buffering, but it becomes the
//! binding constraint for reuse-poor configs, and wide broadcast fan-out
//! (`P_N` or `P_M` large) serializes multicast stages — another effect
//! absent from analytical models.

use crate::config::{BoardConfig, SimConfig};
use crate::tiling::Tiling;

/// Bytes streamed into one AIE for one micro-kernel: an A block and a
/// B block (FP32). Output is amortized along the cascade.
pub fn bytes_per_micro_kernel(board: &BoardConfig) -> f64 {
    let t = board.micro_tile as f64;
    2.0 * 4.0 * t * t
}

/// Multicast serialization factor: hardware multicast covers a fan-out
/// of 4 streams; wider broadcast repeats stages.
pub fn broadcast_factor(t: &Tiling) -> f64 {
    let widest = t.p_m.max(t.p_n) as f64;
    if widest <= 4.0 {
        1.0
    } else {
        1.0 + 0.06 * (widest / 4.0).log2()
    }
}

/// Seconds to feed ONE AIE for one level-2 iteration
/// (`B_M·B_N·B_K` micro-kernels), including broadcast serialization.
pub fn feed_time_per_l2_iter(t: &Tiling, board: &BoardConfig, sim: &SimConfig) -> f64 {
    let micros_per_aie = (t.b_m * t.b_n * t.b_k) as f64;
    let bytes = micros_per_aie * bytes_per_micro_kernel(board);
    bytes * broadcast_factor(t) / sim.plio_bps_per_stream
}

/// Aggregate PL↔AIE traffic (bytes) for the whole GEMM — feeds the NoC
/// power term. Every micro-kernel consumes its operand blocks from the
/// PL, regardless of DDR-level reuse.
pub fn array_traffic_bytes(total_micro_kernels: f64, board: &BoardConfig) -> f64 {
    total_micro_kernels * bytes_per_micro_kernel(board)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> (BoardConfig, SimConfig) {
        (BoardConfig::default(), SimConfig::default())
    }

    #[test]
    fn micro_kernel_operand_bytes() {
        let (b, _) = defaults();
        assert_eq!(bytes_per_micro_kernel(&b), 8192.0);
    }

    #[test]
    fn broadcast_grows_with_fanout() {
        let narrow = Tiling::new((2, 4, 1), (1, 1, 1));
        let wide = Tiling::new((2, 32, 1), (1, 1, 1));
        assert_eq!(broadcast_factor(&narrow), 1.0);
        assert!(broadcast_factor(&wide) > 1.0);
        let wider = Tiling::new((50, 8, 1), (1, 1, 1));
        assert!(broadcast_factor(&wider) > broadcast_factor(&wide) * 0.99);
    }

    #[test]
    fn feed_overlaps_compute_for_default_plio() {
        // With 128-bit PLIO @ 230 MHz (3.68 GB/s) the stream can feed a
        // micro-kernel faster than the AIE computes it, so well-designed
        // mappings stay compute-bound (paper: ~90% peak achievable).
        let (b, s) = defaults();
        let t = Tiling::new((2, 2, 1), (1, 1, 1));
        let feed = feed_time_per_l2_iter(&t, &b, &s);
        let compute = super::super::aie::compute_time_per_l2_iter(&t, &b, &s);
        assert!(feed < compute, "feed {feed} compute {compute}");
    }

    #[test]
    fn feed_scales_with_per_aie_work() {
        let (b, s) = defaults();
        let one = feed_time_per_l2_iter(&Tiling::new((1, 1, 1), (1, 1, 1)), &b, &s);
        let eight = feed_time_per_l2_iter(&Tiling::new((1, 1, 1), (2, 2, 2)), &b, &s);
        assert!((eight / one - 8.0).abs() < 1e-9);
    }

    #[test]
    fn array_traffic_linear() {
        let (b, _) = defaults();
        assert_eq!(
            array_traffic_bytes(10.0, &b),
            10.0 * bytes_per_micro_kernel(&b)
        );
    }
}
