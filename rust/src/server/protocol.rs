//! Wire protocol of the serving daemon — length-prefixed binary frames.
//!
//! Every message on the socket is one frame:
//!
//! ```text
//!   offset  size  field
//!   0       4     payload length N (u32 LE, excludes this 6-byte header)
//!   4       1     protocol version (PROTOCOL_VERSION)
//!   5       1     frame kind (K_SUBMIT .. K_ERROR)
//!   6       N     payload (kind-specific, little-endian scalars)
//! ```
//!
//! Design rules, in order of importance:
//!
//! * **No panic on malformed bytes.** Every decode path goes through
//!   [`Scan`], which returns [`ProtocolError`] on truncation, bad
//!   discriminants, invalid UTF-8, or trailing garbage. A daemon fed
//!   `/dev/urandom` must answer with an ERROR frame and close the
//!   connection, never abort.
//! * **Torn reads are normal.** [`FrameReader`] buffers partial frames
//!   across arbitrarily small socket reads and yields complete frames
//!   only; a frame split at any byte boundary reassembles identically.
//! * **Bounded allocation.** The declared payload length is checked
//!   against [`MAX_FRAME_LEN`] *before* any buffering commitment, and
//!   every embedded length (strings, f32 vectors, stats entries) is
//!   validated against the bytes actually present before allocating.
//! * **Versioned.** The version byte is checked before the kind, so a
//!   future incompatible revision surfaces as [`ProtocolError::
//!   BadVersion`] instead of a misparse.
//!
//! Job-id correlation: SUBMIT carries the client's job id and the
//! matching RESULT echoes it back, so a client may pipeline many
//! submits and match the result stream in any completion order.

use std::fmt;

use crate::coordinator::{GemmJob, GraphInput, GraphJob, GraphResult, JobResult};
use crate::dse::Objective;
use crate::workloads::graph::{GemmGraph, OperandSource, Slot};
use crate::workloads::Gemm;

/// Current wire-protocol revision (the version byte of every frame).
/// v2 added the `backend` descriptor string to STATS/DRAINED payloads;
/// v3 extends RESULT with the resilience triple (`retries`,
/// `timed_out`, `backend_used`); v4 adds the graph-job pair
/// SUBMIT_GRAPH/GRAPH_RESULT (a whole DAG of GEMMs as one job). Each
/// bump makes an older peer fail with `BadVersion` instead of
/// misparsing the reshaped payload.
pub const PROTOCOL_VERSION: u8 = 4;

/// Hard ceiling on one frame's payload (256 MiB) — large enough for a
/// 2048x2048 FP32 operand pair with headroom, small enough that a
/// corrupt length prefix cannot drive an unbounded allocation.
pub const MAX_FRAME_LEN: usize = 256 << 20;

/// Bytes of frame header preceding the payload.
pub const HEADER_LEN: usize = 6;

/// Sanity bound on counted collections inside payloads (stats entries).
const MAX_STATS_FIELDS: usize = 4096;

/// Sanity bound on one graph submission's node count (and, at two
/// external slots per node, half its input-buffer count).
pub const MAX_GRAPH_NODES: usize = 4096;

pub const K_SUBMIT: u8 = 1;
pub const K_RESULT: u8 = 2;
pub const K_STATS_REQ: u8 = 3;
pub const K_STATS: u8 = 4;
pub const K_DRAIN: u8 = 5;
pub const K_DRAINED: u8 = 6;
pub const K_SHUTDOWN: u8 = 7;
pub const K_ACK: u8 = 8;
pub const K_ERROR: u8 = 9;
pub const K_SUBMIT_GRAPH: u8 = 10;
pub const K_GRAPH_RESULT: u8 = 11;

/// Codec failure. Recoverable at the connection level (close + report),
/// never via panic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ProtocolError {
    /// Payload shorter than the structure it declares.
    Truncated,
    /// Declared payload length exceeds [`MAX_FRAME_LEN`].
    Oversized { len: usize },
    /// Version byte differs from [`PROTOCOL_VERSION`].
    BadVersion { version: u8 },
    /// Unknown frame kind byte.
    BadKind { kind: u8 },
    /// A field held an invalid value (bad discriminant, bad UTF-8, an
    /// embedded length larger than the payload).
    BadPayload { what: &'static str },
    /// Payload longer than the structure it declares (corruption).
    TrailingBytes { n: usize },
}

impl fmt::Display for ProtocolError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ProtocolError::Truncated => write!(f, "frame payload truncated"),
            ProtocolError::Oversized { len } => {
                write!(f, "frame payload of {len} bytes exceeds the {MAX_FRAME_LEN}-byte limit")
            }
            ProtocolError::BadVersion { version } => {
                write!(f, "unsupported protocol version {version} (expected {PROTOCOL_VERSION})")
            }
            ProtocolError::BadKind { kind } => write!(f, "unknown frame kind {kind}"),
            ProtocolError::BadPayload { what } => write!(f, "malformed frame payload: {what}"),
            ProtocolError::TrailingBytes { n } => {
                write!(f, "{n} trailing bytes after frame payload")
            }
        }
    }
}

impl std::error::Error for ProtocolError {}

/// One GEMM request as it travels the wire. The client-side analogue of
/// [`GemmJob`]: the daemon rewrites `id` to a daemon-global id before
/// submission and maps it back on the way out.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    pub id: u64,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub objective: Objective,
    /// Validate the executed result against the reference GEMM.
    pub validate: bool,
    pub a: Option<Vec<f32>>,
    pub b: Option<Vec<f32>>,
}

impl JobSpec {
    pub fn plan_only(id: u64, m: usize, n: usize, k: usize, objective: Objective) -> JobSpec {
        JobSpec {
            id,
            m,
            n,
            k,
            objective,
            validate: false,
            a: None,
            b: None,
        }
    }

    pub fn gemm(&self) -> Gemm {
        Gemm::new(self.m, self.n, self.k)
    }

    /// Convert into a coordinator job under a (possibly rewritten) id.
    pub fn into_job(self, id: u64) -> GemmJob {
        let gemm = self.gemm();
        GemmJob {
            id,
            gemm,
            objective: self.objective,
            a: self.a,
            b: self.b,
            validate: self.validate,
            deadline_ms: None,
        }
    }
}

/// One completed job as it travels the wire: [`JobResult`] minus the
/// output matrix (results stream back accounting + metrics; operands
/// and products stay on the daemon side).
#[derive(Debug, Clone, PartialEq)]
pub struct WireResult {
    pub id: u64,
    pub m: u64,
    pub n: u64,
    pub k: u64,
    pub cache_hit: bool,
    pub coalesced: bool,
    pub plan_time_us: u64,
    pub exec_time_us: Option<u64>,
    pub energy_j: Option<f64>,
    pub avg_power_w: Option<f64>,
    pub gflops_per_w: Option<f64>,
    pub validation_err: Option<f32>,
    /// Selected mapping's label (absent when planning failed).
    pub tiling: Option<String>,
    pub n_aie: u32,
    pub error: Option<String>,
    /// Attempts beyond the first the resilient executor spent (v3).
    pub retries: u32,
    /// Whether any attempt hit its per-job deadline (v3).
    pub timed_out: bool,
    /// Execution tier that produced the final outcome (v3).
    pub backend_used: Option<String>,
}

impl WireResult {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// Project a coordinator result onto the wire under the client's id.
    pub fn from_result(client_id: u64, r: &JobResult) -> WireResult {
        WireResult {
            id: client_id,
            m: r.gemm.m as u64,
            n: r.gemm.n as u64,
            k: r.gemm.k as u64,
            cache_hit: r.cache_hit,
            coalesced: r.coalesced,
            plan_time_us: r.plan_time.as_micros() as u64,
            exec_time_us: r.exec_time.map(|d| d.as_micros() as u64),
            energy_j: r.energy_j,
            avg_power_w: r.avg_power_w,
            gflops_per_w: r.gflops_per_w,
            validation_err: r.validation_err,
            tiling: r.plan.map(|p| p.tiling.label()),
            n_aie: r.plan.map(|p| p.tiling.n_aie() as u32).unwrap_or(0),
            error: r.error.clone(),
            retries: r.retries,
            timed_out: r.timed_out,
            backend_used: r.backend_used.map(str::to_string),
        }
    }

    /// A daemon-side refusal (admission closed while draining): the job
    /// never reached the coordinator.
    pub fn refused(id: u64, gemm: Gemm, why: &str) -> WireResult {
        WireResult {
            id,
            m: gemm.m as u64,
            n: gemm.n as u64,
            k: gemm.k as u64,
            cache_hit: false,
            coalesced: false,
            plan_time_us: 0,
            exec_time_us: None,
            energy_j: None,
            avg_power_w: None,
            gflops_per_w: None,
            validation_err: None,
            tiling: None,
            n_aie: 0,
            error: Some(why.to_string()),
            retries: 0,
            timed_out: false,
            backend_used: None,
        }
    }
}

/// One graph node as it travels the wire. `a_src`/`b_src` name the
/// upstream node whose output feeds that slot; `None` marks a
/// client-provided (external) operand.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphNodeSpec {
    pub name: String,
    pub m: usize,
    pub n: usize,
    pub k: usize,
    pub a_src: Option<String>,
    pub b_src: Option<String>,
}

/// One whole-model request as it travels the wire: a DAG of GEMMs
/// submitted as a single job (v4). The client-side analogue of
/// [`GraphJob`]; intermediates stay resident on the daemon side and
/// never appear on the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct GraphSpec {
    pub id: u64,
    pub objective: Objective,
    /// Validate every node's output against the reference GEMM.
    pub validate: bool,
    pub nodes: Vec<GraphNodeSpec>,
    /// External operand buffers, one per external slot for a data
    /// graph; empty for plan-only submissions.
    pub inputs: Vec<GraphInput>,
}

impl GraphSpec {
    /// Project a workload graph onto the wire under the client's id.
    pub fn from_graph(
        id: u64,
        graph: &GemmGraph,
        objective: Objective,
        inputs: Vec<GraphInput>,
    ) -> GraphSpec {
        let nodes = graph
            .nodes
            .iter()
            .map(|node| {
                let src = |s: &OperandSource| match s {
                    OperandSource::External => None,
                    OperandSource::Node(name) => Some(name.clone()),
                };
                GraphNodeSpec {
                    name: node.name.clone(),
                    m: node.gemm.m,
                    n: node.gemm.n,
                    k: node.gemm.k,
                    a_src: src(&node.a),
                    b_src: src(&node.b),
                }
            })
            .collect();
        GraphSpec {
            id,
            objective,
            validate: false,
            nodes,
            inputs,
        }
    }

    /// Rebuild the workload graph this spec describes.
    pub fn graph(&self) -> GemmGraph {
        let mut graph = GemmGraph::new();
        for node in &self.nodes {
            let src = |s: &Option<String>| match s {
                None => OperandSource::External,
                Some(name) => OperandSource::Node(name.clone()),
            };
            graph = graph.push(
                &node.name,
                Gemm::new(node.m, node.n, node.k),
                src(&node.a_src),
                src(&node.b_src),
            );
        }
        graph
    }

    /// Convert into a coordinator job under a (possibly rewritten) id.
    /// Outputs are never kept: the wire path streams back metrics only.
    pub fn into_job(self, id: u64) -> GraphJob {
        let graph = self.graph();
        GraphJob {
            id,
            graph,
            objective: self.objective,
            inputs: self.inputs,
            validate: self.validate,
            keep_outputs: false,
            deadline_ms: None,
        }
    }
}

/// One completed graph job as it travels the wire: [`GraphResult`]'s
/// rollups without per-node buffers — energy, efficiency, plan-sharing
/// and residency accounting stream back; intermediates never do.
#[derive(Debug, Clone, PartialEq)]
pub struct WireGraphResult {
    pub id: u64,
    pub n_nodes: u64,
    pub plan_time_us: u64,
    /// Summed node execution time.
    pub exec_sum_us: Option<u64>,
    /// Critical-path execution time through the DAG.
    pub exec_critical_us: Option<u64>,
    pub energy_j: Option<f64>,
    pub avg_power_w: Option<f64>,
    pub gflops_per_w: Option<f64>,
    /// Nodes that reused another same-shape node's plan.
    pub plans_shared: u64,
    /// High-water mark of arena-resident intermediate bytes.
    pub resident_bytes_peak: u64,
    /// Whole-DAG plan-cache hit (no per-key lookups at all).
    pub graph_cache_hit: bool,
    pub error: Option<String>,
}

impl WireGraphResult {
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// Project a coordinator graph result onto the wire under the
    /// client's id.
    pub fn from_result(client_id: u64, r: &GraphResult) -> WireGraphResult {
        WireGraphResult {
            id: client_id,
            n_nodes: r.n_nodes as u64,
            plan_time_us: r.plan_time.as_micros() as u64,
            exec_sum_us: r.exec_time_sum.map(|d| d.as_micros() as u64),
            exec_critical_us: r.exec_time_critical.map(|d| d.as_micros() as u64),
            energy_j: r.energy_j,
            avg_power_w: r.avg_power_w,
            gflops_per_w: r.gflops_per_w,
            plans_shared: r.plans_shared,
            resident_bytes_peak: r.resident_bytes_peak,
            graph_cache_hit: r.graph_cache_hit,
            error: r.error.clone(),
        }
    }

    /// A daemon-side refusal (admission closed while draining): the
    /// graph never reached the coordinator.
    pub fn refused(id: u64, n_nodes: u64, why: &str) -> WireGraphResult {
        WireGraphResult {
            id,
            n_nodes,
            plan_time_us: 0,
            exec_sum_us: None,
            exec_critical_us: None,
            energy_j: None,
            avg_power_w: None,
            gflops_per_w: None,
            plans_shared: 0,
            resident_bytes_peak: 0,
            graph_cache_hit: false,
            error: Some(why.to_string()),
        }
    }
}

/// Daemon/service counters as they travel the wire: a self-describing
/// list of named values plus the daemon's lifecycle state, so stats can
/// grow fields without a protocol revision.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WireStats {
    /// Daemon state machine position: "ready" / "draining" / "stopped".
    pub state: String,
    /// Human-readable execution-backend descriptor, e.g.
    /// `cpu (profile l2-large)` — backend name plus the selected packed-
    /// panel kernel profile when one applies ("starting" until the
    /// executor has built its backend).
    pub backend: String,
    pub uptime_s: f64,
    pub fields: Vec<(String, f64)>,
}

impl WireStats {
    pub fn get(&self, name: &str) -> Option<f64> {
        self.fields
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| *v)
    }
}

/// Every message the daemon and its clients exchange.
#[derive(Debug, Clone, PartialEq)]
pub enum Frame {
    /// Client → daemon: submit one job.
    Submit(JobSpec),
    /// Daemon → client: one completed job (streamed, any order).
    Result(WireResult),
    /// Client → daemon: request a stats snapshot.
    StatsReq,
    /// Daemon → client: stats snapshot.
    Stats(WireStats),
    /// Client → daemon: close admission, finish in-flight jobs, persist
    /// the plan cache; answered with `Drained` once quiescent.
    Drain,
    /// Daemon → client: drain completed; payload is the final stats.
    Drained(WireStats),
    /// Client → daemon: drain, then exit the process. Answered with
    /// `Ack` just before the daemon stops.
    Shutdown,
    /// Daemon → client: generic acknowledgement.
    Ack,
    /// Daemon → client: protocol-level failure. `job_id` is 0 when the
    /// error is not attributable to a specific submission.
    Error { job_id: u64, message: String },
    /// Client → daemon: submit one whole-model graph job (v4).
    SubmitGraph(GraphSpec),
    /// Daemon → client: one completed graph job (v4).
    GraphResult(WireGraphResult),
}

// ---------------------------------------------------------------------------
// encode

fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_string(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

fn put_opt_u64(out: &mut Vec<u8>, v: Option<u64>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_u64(out, x);
        }
        None => put_u8(out, 0),
    }
}

fn put_opt_f64(out: &mut Vec<u8>, v: Option<f64>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_f64(out, x);
        }
        None => put_u8(out, 0),
    }
}

fn put_opt_f32(out: &mut Vec<u8>, v: Option<f32>) {
    match v {
        Some(x) => {
            put_u8(out, 1);
            put_f32(out, x);
        }
        None => put_u8(out, 0),
    }
}

fn put_opt_string(out: &mut Vec<u8>, v: Option<&str>) {
    match v {
        Some(s) => {
            put_u8(out, 1);
            put_string(out, s);
        }
        None => put_u8(out, 0),
    }
}

fn put_f32_vec(out: &mut Vec<u8>, v: &[f32]) {
    put_u64(out, v.len() as u64);
    for x in v {
        put_f32(out, *x);
    }
}

fn objective_byte(o: Objective) -> u8 {
    match o {
        Objective::Throughput => 0,
        Objective::EnergyEfficiency => 1,
    }
}

fn slot_byte(s: Slot) -> u8 {
    match s {
        Slot::A => 0,
        Slot::B => 1,
    }
}

fn frame_bytes(kind: u8, payload: Vec<u8>) -> Vec<u8> {
    debug_assert!(payload.len() <= MAX_FRAME_LEN);
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    put_u32(&mut out, payload.len() as u32);
    put_u8(&mut out, PROTOCOL_VERSION);
    put_u8(&mut out, kind);
    out.extend_from_slice(&payload);
    out
}

fn submit_payload(spec: &JobSpec) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, spec.id);
    put_u64(&mut p, spec.m as u64);
    put_u64(&mut p, spec.n as u64);
    put_u64(&mut p, spec.k as u64);
    put_u8(&mut p, objective_byte(spec.objective));
    let mut flags = 0u8;
    if spec.validate {
        flags |= 1;
    }
    if spec.a.is_some() {
        flags |= 2;
    }
    if spec.b.is_some() {
        flags |= 4;
    }
    put_u8(&mut p, flags);
    if let Some(a) = &spec.a {
        put_f32_vec(&mut p, a);
    }
    if let Some(b) = &spec.b {
        put_f32_vec(&mut p, b);
    }
    p
}

fn result_payload(r: &WireResult) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, r.id);
    put_u64(&mut p, r.m);
    put_u64(&mut p, r.n);
    put_u64(&mut p, r.k);
    let mut flags = 0u8;
    if r.cache_hit {
        flags |= 1;
    }
    if r.coalesced {
        flags |= 2;
    }
    if r.timed_out {
        flags |= 4;
    }
    put_u8(&mut p, flags);
    put_u64(&mut p, r.plan_time_us);
    put_opt_u64(&mut p, r.exec_time_us);
    put_opt_f64(&mut p, r.energy_j);
    put_opt_f64(&mut p, r.avg_power_w);
    put_opt_f64(&mut p, r.gflops_per_w);
    put_opt_f32(&mut p, r.validation_err);
    put_opt_string(&mut p, r.tiling.as_deref());
    put_u32(&mut p, r.n_aie);
    put_opt_string(&mut p, r.error.as_deref());
    put_u32(&mut p, r.retries);
    put_opt_string(&mut p, r.backend_used.as_deref());
    p
}

fn submit_graph_payload(spec: &GraphSpec) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, spec.id);
    put_u8(&mut p, objective_byte(spec.objective));
    let mut flags = 0u8;
    if spec.validate {
        flags |= 1;
    }
    put_u8(&mut p, flags);
    put_u32(&mut p, spec.nodes.len() as u32);
    for node in &spec.nodes {
        put_string(&mut p, &node.name);
        put_u64(&mut p, node.m as u64);
        put_u64(&mut p, node.n as u64);
        put_u64(&mut p, node.k as u64);
        put_opt_string(&mut p, node.a_src.as_deref());
        put_opt_string(&mut p, node.b_src.as_deref());
    }
    put_u32(&mut p, spec.inputs.len() as u32);
    for input in &spec.inputs {
        put_string(&mut p, &input.node);
        put_u8(&mut p, slot_byte(input.slot));
        put_f32_vec(&mut p, &input.data);
    }
    p
}

fn graph_result_payload(r: &WireGraphResult) -> Vec<u8> {
    let mut p = Vec::new();
    put_u64(&mut p, r.id);
    put_u64(&mut p, r.n_nodes);
    let mut flags = 0u8;
    if r.graph_cache_hit {
        flags |= 1;
    }
    put_u8(&mut p, flags);
    put_u64(&mut p, r.plan_time_us);
    put_opt_u64(&mut p, r.exec_sum_us);
    put_opt_u64(&mut p, r.exec_critical_us);
    put_opt_f64(&mut p, r.energy_j);
    put_opt_f64(&mut p, r.avg_power_w);
    put_opt_f64(&mut p, r.gflops_per_w);
    put_u64(&mut p, r.plans_shared);
    put_u64(&mut p, r.resident_bytes_peak);
    put_opt_string(&mut p, r.error.as_deref());
    p
}

fn stats_payload(s: &WireStats) -> Vec<u8> {
    let mut p = Vec::new();
    put_string(&mut p, &s.state);
    put_string(&mut p, &s.backend);
    put_f64(&mut p, s.uptime_s);
    put_u32(&mut p, s.fields.len() as u32);
    for (name, value) in &s.fields {
        put_string(&mut p, name);
        put_f64(&mut p, *value);
    }
    p
}

/// Encode one frame to its on-wire bytes.
pub fn encode_frame(frame: &Frame) -> Vec<u8> {
    match frame {
        Frame::Submit(spec) => frame_bytes(K_SUBMIT, submit_payload(spec)),
        Frame::Result(r) => frame_bytes(K_RESULT, result_payload(r)),
        Frame::StatsReq => frame_bytes(K_STATS_REQ, Vec::new()),
        Frame::Stats(s) => frame_bytes(K_STATS, stats_payload(s)),
        Frame::Drain => frame_bytes(K_DRAIN, Vec::new()),
        Frame::Drained(s) => frame_bytes(K_DRAINED, stats_payload(s)),
        Frame::Shutdown => frame_bytes(K_SHUTDOWN, Vec::new()),
        Frame::Ack => frame_bytes(K_ACK, Vec::new()),
        Frame::Error { job_id, message } => {
            let mut p = Vec::new();
            put_u64(&mut p, *job_id);
            put_string(&mut p, message);
            frame_bytes(K_ERROR, p)
        }
        Frame::SubmitGraph(spec) => frame_bytes(K_SUBMIT_GRAPH, submit_graph_payload(spec)),
        Frame::GraphResult(r) => frame_bytes(K_GRAPH_RESULT, graph_result_payload(r)),
    }
}

/// Encode a SUBMIT frame directly from a borrowed spec (avoids cloning
/// operand buffers into a [`Frame`] first).
pub fn encode_submit(spec: &JobSpec) -> Vec<u8> {
    frame_bytes(K_SUBMIT, submit_payload(spec))
}

/// Encode a SUBMIT_GRAPH frame directly from a borrowed spec (avoids
/// cloning every input buffer into a [`Frame`] first).
pub fn encode_submit_graph(spec: &GraphSpec) -> Vec<u8> {
    frame_bytes(K_SUBMIT_GRAPH, submit_graph_payload(spec))
}

// ---------------------------------------------------------------------------
// decode

/// Bounds-checked little-endian payload reader. Every accessor returns
/// `ProtocolError` instead of panicking.
struct Scan<'a> {
    b: &'a [u8],
}

impl<'a> Scan<'a> {
    fn new(b: &'a [u8]) -> Scan<'a> {
        Scan { b }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], ProtocolError> {
        if self.b.len() < n {
            return Err(ProtocolError::Truncated);
        }
        let (head, rest) = self.b.split_at(n);
        self.b = rest;
        Ok(head)
    }

    fn u8(&mut self) -> Result<u8, ProtocolError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, ProtocolError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, ProtocolError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn f32(&mut self) -> Result<f32, ProtocolError> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64, ProtocolError> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn string(&mut self) -> Result<String, ProtocolError> {
        let n = self.u32()? as usize;
        if n > self.b.len() {
            return Err(ProtocolError::Truncated);
        }
        let raw = self.take(n)?;
        String::from_utf8(raw.to_vec()).map_err(|_| ProtocolError::BadPayload {
            what: "invalid UTF-8 in string field",
        })
    }

    fn opt_u64(&mut self) -> Result<Option<u64>, ProtocolError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.u64()?)),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid option tag",
            }),
        }
    }

    fn opt_f64(&mut self) -> Result<Option<f64>, ProtocolError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f64()?)),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid option tag",
            }),
        }
    }

    fn opt_f32(&mut self) -> Result<Option<f32>, ProtocolError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.f32()?)),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid option tag",
            }),
        }
    }

    fn opt_string(&mut self) -> Result<Option<String>, ProtocolError> {
        match self.u8()? {
            0 => Ok(None),
            1 => Ok(Some(self.string()?)),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid option tag",
            }),
        }
    }

    fn f32_vec(&mut self) -> Result<Vec<f32>, ProtocolError> {
        let n = self.u64()? as usize;
        let need = n.checked_mul(4).ok_or(ProtocolError::BadPayload {
            what: "f32 vector length overflow",
        })?;
        if need > self.b.len() {
            return Err(ProtocolError::Truncated);
        }
        let raw = self.take(need)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    fn objective(&mut self) -> Result<Objective, ProtocolError> {
        match self.u8()? {
            0 => Ok(Objective::Throughput),
            1 => Ok(Objective::EnergyEfficiency),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid objective discriminant",
            }),
        }
    }

    fn slot(&mut self) -> Result<Slot, ProtocolError> {
        match self.u8()? {
            0 => Ok(Slot::A),
            1 => Ok(Slot::B),
            _ => Err(ProtocolError::BadPayload {
                what: "invalid operand slot discriminant",
            }),
        }
    }

    /// Payloads describe their exact extent; leftovers mean corruption.
    fn finish(self) -> Result<(), ProtocolError> {
        if self.b.is_empty() {
            Ok(())
        } else {
            Err(ProtocolError::TrailingBytes { n: self.b.len() })
        }
    }
}

fn decode_submit(payload: &[u8]) -> Result<JobSpec, ProtocolError> {
    let mut s = Scan::new(payload);
    let id = s.u64()?;
    let m = s.u64()? as usize;
    let n = s.u64()? as usize;
    let k = s.u64()? as usize;
    let objective = s.objective()?;
    let flags = s.u8()?;
    if flags & !0b111 != 0 {
        return Err(ProtocolError::BadPayload {
            what: "unknown submit flag bits",
        });
    }
    let a = if flags & 2 != 0 { Some(s.f32_vec()?) } else { None };
    let b = if flags & 4 != 0 { Some(s.f32_vec()?) } else { None };
    s.finish()?;
    Ok(JobSpec {
        id,
        m,
        n,
        k,
        objective,
        validate: flags & 1 != 0,
        a,
        b,
    })
}

fn decode_result(payload: &[u8]) -> Result<WireResult, ProtocolError> {
    let mut s = Scan::new(payload);
    let id = s.u64()?;
    let m = s.u64()?;
    let n = s.u64()?;
    let k = s.u64()?;
    let flags = s.u8()?;
    if flags & !0b111 != 0 {
        return Err(ProtocolError::BadPayload {
            what: "unknown result flag bits",
        });
    }
    let plan_time_us = s.u64()?;
    let exec_time_us = s.opt_u64()?;
    let energy_j = s.opt_f64()?;
    let avg_power_w = s.opt_f64()?;
    let gflops_per_w = s.opt_f64()?;
    let validation_err = s.opt_f32()?;
    let tiling = s.opt_string()?;
    let n_aie = s.u32()?;
    let error = s.opt_string()?;
    let retries = s.u32()?;
    let backend_used = s.opt_string()?;
    s.finish()?;
    Ok(WireResult {
        id,
        m,
        n,
        k,
        cache_hit: flags & 1 != 0,
        coalesced: flags & 2 != 0,
        plan_time_us,
        exec_time_us,
        energy_j,
        avg_power_w,
        gflops_per_w,
        validation_err,
        tiling,
        n_aie,
        error,
        retries,
        timed_out: flags & 4 != 0,
        backend_used,
    })
}

fn decode_submit_graph(payload: &[u8]) -> Result<GraphSpec, ProtocolError> {
    let mut s = Scan::new(payload);
    let id = s.u64()?;
    let objective = s.objective()?;
    let flags = s.u8()?;
    if flags & !0b1 != 0 {
        return Err(ProtocolError::BadPayload {
            what: "unknown submit-graph flag bits",
        });
    }
    let n_nodes = s.u32()? as usize;
    if n_nodes > MAX_GRAPH_NODES {
        return Err(ProtocolError::BadPayload {
            what: "graph node count out of range",
        });
    }
    let mut nodes = Vec::with_capacity(n_nodes.min(256));
    for _ in 0..n_nodes {
        let name = s.string()?;
        let m = s.u64()? as usize;
        let n = s.u64()? as usize;
        let k = s.u64()? as usize;
        let a_src = s.opt_string()?;
        let b_src = s.opt_string()?;
        nodes.push(GraphNodeSpec {
            name,
            m,
            n,
            k,
            a_src,
            b_src,
        });
    }
    let n_inputs = s.u32()? as usize;
    if n_inputs > 2 * MAX_GRAPH_NODES {
        return Err(ProtocolError::BadPayload {
            what: "graph input count out of range",
        });
    }
    let mut inputs = Vec::with_capacity(n_inputs.min(256));
    for _ in 0..n_inputs {
        let node = s.string()?;
        let slot = s.slot()?;
        let data = s.f32_vec()?;
        inputs.push(GraphInput { node, slot, data });
    }
    s.finish()?;
    Ok(GraphSpec {
        id,
        objective,
        validate: flags & 1 != 0,
        nodes,
        inputs,
    })
}

fn decode_graph_result(payload: &[u8]) -> Result<WireGraphResult, ProtocolError> {
    let mut s = Scan::new(payload);
    let id = s.u64()?;
    let n_nodes = s.u64()?;
    let flags = s.u8()?;
    if flags & !0b1 != 0 {
        return Err(ProtocolError::BadPayload {
            what: "unknown graph-result flag bits",
        });
    }
    let plan_time_us = s.u64()?;
    let exec_sum_us = s.opt_u64()?;
    let exec_critical_us = s.opt_u64()?;
    let energy_j = s.opt_f64()?;
    let avg_power_w = s.opt_f64()?;
    let gflops_per_w = s.opt_f64()?;
    let plans_shared = s.u64()?;
    let resident_bytes_peak = s.u64()?;
    let error = s.opt_string()?;
    s.finish()?;
    Ok(WireGraphResult {
        id,
        n_nodes,
        plan_time_us,
        exec_sum_us,
        exec_critical_us,
        energy_j,
        avg_power_w,
        gflops_per_w,
        plans_shared,
        resident_bytes_peak,
        graph_cache_hit: flags & 1 != 0,
        error,
    })
}

fn decode_stats(payload: &[u8]) -> Result<WireStats, ProtocolError> {
    let mut s = Scan::new(payload);
    let state = s.string()?;
    let backend = s.string()?;
    let uptime_s = s.f64()?;
    let count = s.u32()? as usize;
    if count > MAX_STATS_FIELDS {
        return Err(ProtocolError::BadPayload {
            what: "stats field count out of range",
        });
    }
    let mut fields = Vec::with_capacity(count.min(256));
    for _ in 0..count {
        let name = s.string()?;
        let value = s.f64()?;
        fields.push((name, value));
    }
    s.finish()?;
    Ok(WireStats {
        state,
        backend,
        uptime_s,
        fields,
    })
}

fn decode_empty(kind: u8, payload: &[u8]) -> Result<Frame, ProtocolError> {
    Scan::new(payload).finish()?;
    Ok(match kind {
        K_STATS_REQ => Frame::StatsReq,
        K_DRAIN => Frame::Drain,
        K_SHUTDOWN => Frame::Shutdown,
        _ => Frame::Ack,
    })
}

/// Decode one frame's payload given its (already validated) kind byte.
pub fn decode_frame(kind: u8, payload: &[u8]) -> Result<Frame, ProtocolError> {
    match kind {
        K_SUBMIT => Ok(Frame::Submit(decode_submit(payload)?)),
        K_RESULT => Ok(Frame::Result(decode_result(payload)?)),
        K_SUBMIT_GRAPH => Ok(Frame::SubmitGraph(decode_submit_graph(payload)?)),
        K_GRAPH_RESULT => Ok(Frame::GraphResult(decode_graph_result(payload)?)),
        K_STATS => Ok(Frame::Stats(decode_stats(payload)?)),
        K_DRAINED => Ok(Frame::Drained(decode_stats(payload)?)),
        K_STATS_REQ | K_DRAIN | K_SHUTDOWN | K_ACK => decode_empty(kind, payload),
        K_ERROR => {
            let mut s = Scan::new(payload);
            let job_id = s.u64()?;
            let message = s.string()?;
            s.finish()?;
            Ok(Frame::Error { job_id, message })
        }
        other => Err(ProtocolError::BadKind { kind: other }),
    }
}

/// Incremental frame reassembler: push raw socket bytes in, pop complete
/// frames out. Handles torn reads (any split), rejects oversized and
/// mis-versioned frames before buffering their payload.
#[derive(Debug, Default)]
pub struct FrameReader {
    buf: Vec<u8>,
    pos: usize,
}

/// Compact the consumed prefix once it crosses this threshold.
const COMPACT_AT: usize = 64 << 10;

impl FrameReader {
    pub fn new() -> FrameReader {
        FrameReader::default()
    }

    /// Append raw bytes read from the socket.
    pub fn push(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet consumed as frames.
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Pop the next complete frame, `Ok(None)` if more bytes are needed.
    /// After an error the stream is unrecoverable: the caller should
    /// report and close the connection.
    pub fn next_frame(&mut self) -> Result<Option<Frame>, ProtocolError> {
        let avail = &self.buf[self.pos..];
        if avail.len() < HEADER_LEN {
            return Ok(None);
        }
        let len = u32::from_le_bytes([avail[0], avail[1], avail[2], avail[3]]) as usize;
        if len > MAX_FRAME_LEN {
            return Err(ProtocolError::Oversized { len });
        }
        let version = avail[4];
        if version != PROTOCOL_VERSION {
            return Err(ProtocolError::BadVersion { version });
        }
        let kind = avail[5];
        if avail.len() < HEADER_LEN + len {
            return Ok(None); // torn read: wait for the rest
        }
        let frame = decode_frame(kind, &avail[HEADER_LEN..HEADER_LEN + len])?;
        self.pos += HEADER_LEN + len;
        if self.pos >= COMPACT_AT {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        Ok(Some(frame))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_spec(id: u64, with_data: bool) -> JobSpec {
        JobSpec {
            id,
            m: 64,
            n: 96,
            k: 32,
            objective: Objective::EnergyEfficiency,
            validate: true,
            a: with_data.then(|| (0..64 * 32).map(|i| i as f32 * 0.5).collect()),
            b: with_data.then(|| (0..32 * 96).map(|i| -(i as f32)).collect()),
        }
    }

    fn sample_result(id: u64) -> WireResult {
        WireResult {
            id,
            m: 64,
            n: 96,
            k: 32,
            cache_hit: true,
            coalesced: false,
            plan_time_us: 1234,
            exec_time_us: Some(987),
            energy_j: Some(0.25),
            avg_power_w: Some(31.5),
            gflops_per_w: None,
            validation_err: Some(1e-6),
            tiling: Some("P=4x4x2 B=2x2x1".to_string()),
            n_aie: 32,
            error: None,
            retries: 2,
            timed_out: true,
            backend_used: Some("cpu".to_string()),
        }
    }

    fn sample_graph_spec(id: u64, with_data: bool) -> GraphSpec {
        let g = Gemm::new(8, 16, 16);
        let graph = GemmGraph::new()
            .push("n0", g, OperandSource::External, OperandSource::External)
            .push(
                "n1",
                g,
                OperandSource::Node("n0".to_string()),
                OperandSource::External,
            );
        let inputs = if with_data {
            vec![
                GraphInput::new("n0", Slot::A, (0..8 * 16).map(|i| i as f32).collect()),
                GraphInput::new("n0", Slot::B, vec![0.5; 16 * 16]),
                GraphInput::new("n1", Slot::B, vec![-1.0; 16 * 16]),
            ]
        } else {
            Vec::new()
        };
        let mut spec = GraphSpec::from_graph(id, &graph, Objective::Throughput, inputs);
        spec.validate = with_data;
        spec
    }

    fn sample_graph_result(id: u64) -> WireGraphResult {
        WireGraphResult {
            id,
            n_nodes: 2,
            plan_time_us: 4321,
            exec_sum_us: Some(900),
            exec_critical_us: Some(880),
            energy_j: Some(0.125),
            avg_power_w: Some(28.0),
            gflops_per_w: None,
            plans_shared: 1,
            resident_bytes_peak: 512,
            graph_cache_hit: true,
            error: None,
        }
    }

    fn sample_stats() -> WireStats {
        WireStats {
            state: "ready".to_string(),
            backend: "cpu (profile l2-large)".to_string(),
            uptime_s: 12.75,
            fields: vec![
                ("jobs_completed".to_string(), 42.0),
                ("cache_hit_rate".to_string(), 0.5),
            ],
        }
    }

    fn roundtrip(frame: &Frame) -> Frame {
        let bytes = encode_frame(frame);
        let mut rd = FrameReader::new();
        rd.push(&bytes);
        let out = rd.next_frame().expect("decode").expect("complete");
        assert_eq!(rd.buffered(), 0);
        out
    }

    #[test]
    fn all_kinds_roundtrip() {
        let frames = vec![
            Frame::Submit(sample_spec(7, true)),
            Frame::Submit(sample_spec(8, false)),
            Frame::Result(sample_result(7)),
            Frame::StatsReq,
            Frame::Stats(sample_stats()),
            Frame::Drain,
            Frame::Drained(sample_stats()),
            Frame::Shutdown,
            Frame::Ack,
            Frame::Error {
                job_id: 3,
                message: "queue full".to_string(),
            },
            Frame::SubmitGraph(sample_graph_spec(9, true)),
            Frame::SubmitGraph(sample_graph_spec(10, false)),
            Frame::GraphResult(sample_graph_result(9)),
        ];
        for f in &frames {
            assert_eq!(&roundtrip(f), f, "frame {f:?} did not round-trip");
        }
    }

    #[test]
    fn torn_reads_reassemble_byte_by_byte() {
        let frame = Frame::Submit(sample_spec(5, true));
        let bytes = encode_frame(&frame);
        let mut rd = FrameReader::new();
        for (i, byte) in bytes.iter().enumerate() {
            rd.push(std::slice::from_ref(byte));
            let got = rd.next_frame().expect("no error mid-stream");
            if i + 1 < bytes.len() {
                assert!(got.is_none(), "yielded a frame at byte {i} of {}", bytes.len());
            } else {
                assert_eq!(got, Some(frame.clone()));
            }
        }
    }

    #[test]
    fn random_split_points_reassemble() {
        // Property: a frame stream split at arbitrary boundaries decodes
        // to the same frame sequence.
        crate::util::forall(
            0xfeed,
            60,
            |rng| {
                let frames = vec![
                    Frame::Submit(sample_spec(rng.below(100) as u64, rng.below(2) == 0)),
                    Frame::Result(sample_result(rng.below(100) as u64)),
                    Frame::Stats(sample_stats()),
                    Frame::Ack,
                ];
                let chunk = 1 + rng.below(97);
                (frames, chunk)
            },
            |(frames, chunk)| {
                let mut bytes = Vec::new();
                for f in frames {
                    bytes.extend_from_slice(&encode_frame(f));
                }
                let mut rd = FrameReader::new();
                let mut got = Vec::new();
                for piece in bytes.chunks(*chunk) {
                    rd.push(piece);
                    while let Some(f) = rd.next_frame().expect("decode") {
                        got.push(f);
                    }
                }
                assert_eq!(&got, frames);
            },
        );
    }

    #[test]
    fn oversized_frame_is_rejected_before_buffering() {
        let mut rd = FrameReader::new();
        let mut header = Vec::new();
        put_u32(&mut header, (MAX_FRAME_LEN + 1) as u32);
        put_u8(&mut header, PROTOCOL_VERSION);
        put_u8(&mut header, K_SUBMIT);
        rd.push(&header);
        assert_eq!(
            rd.next_frame(),
            Err(ProtocolError::Oversized {
                len: MAX_FRAME_LEN + 1
            })
        );
    }

    #[test]
    fn unknown_version_surfaces_before_kind() {
        let mut rd = FrameReader::new();
        // Version 9 with an *invalid* kind too: version must win.
        rd.push(&[0, 0, 0, 0, 9, 0xEE]);
        assert_eq!(rd.next_frame(), Err(ProtocolError::BadVersion { version: 9 }));
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let mut rd = FrameReader::new();
        rd.push(&[0, 0, 0, 0, PROTOCOL_VERSION, 0xEE]);
        assert_eq!(rd.next_frame(), Err(ProtocolError::BadKind { kind: 0xEE }));
    }

    #[test]
    fn malformed_payloads_error_without_panic() {
        // Bad objective discriminant.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u64(&mut p, 8);
        put_u64(&mut p, 8);
        put_u64(&mut p, 8);
        put_u8(&mut p, 7); // objective: invalid
        put_u8(&mut p, 0);
        assert!(matches!(
            decode_frame(K_SUBMIT, &p),
            Err(ProtocolError::BadPayload { .. })
        ));
        // Truncated: declared string longer than payload.
        let mut p = Vec::new();
        put_u64(&mut p, 0);
        put_u32(&mut p, 1000); // error-message length with no bytes behind it
        assert_eq!(decode_frame(K_ERROR, &p), Err(ProtocolError::Truncated));
        // Trailing garbage after an empty-payload kind.
        assert_eq!(
            decode_frame(K_DRAIN, &[1, 2, 3]),
            Err(ProtocolError::TrailingBytes { n: 3 })
        );
        // f32 vector whose element count cannot fit the payload.
        let mut p = Vec::new();
        put_u64(&mut p, 2);
        put_u64(&mut p, 4);
        put_u64(&mut p, 4);
        put_u64(&mut p, 4);
        put_u8(&mut p, 0);
        put_u8(&mut p, 2 | 4); // has A and B
        put_u64(&mut p, u64::MAX / 8); // absurd element count
        assert!(matches!(
            decode_frame(K_SUBMIT, &p),
            Err(ProtocolError::Truncated) | Err(ProtocolError::BadPayload { .. })
        ));
    }

    #[test]
    fn garbage_streams_never_panic() {
        // Fuzz-lite: random byte soup must yield Ok(None)/Err, never panic.
        let mut rng = Rng::new(0xbad5eed);
        for _ in 0..200 {
            let n = rng.below(512);
            let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
            let mut rd = FrameReader::new();
            rd.push(&bytes);
            // Drain until the reader stalls or errors; both are fine.
            loop {
                match rd.next_frame() {
                    Ok(Some(_)) => continue,
                    Ok(None) | Err(_) => break,
                }
            }
        }
    }

    #[test]
    fn long_streams_compact_their_buffer() {
        let frame = Frame::Result(sample_result(1));
        let bytes = encode_frame(&frame);
        let mut rd = FrameReader::new();
        for _ in 0..2000 {
            rd.push(&bytes);
            assert_eq!(rd.next_frame().unwrap(), Some(frame.clone()));
        }
        // The consumed prefix must not grow without bound.
        assert!(rd.buf.len() < COMPACT_AT + bytes.len());
    }

    #[test]
    fn malformed_graph_payloads_error_without_panic() {
        // Node count beyond the sanity bound is refused before any
        // per-node allocation.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u8(&mut p, 0); // objective
        put_u8(&mut p, 0); // flags
        put_u32(&mut p, (MAX_GRAPH_NODES + 1) as u32);
        assert!(matches!(
            decode_frame(K_SUBMIT_GRAPH, &p),
            Err(ProtocolError::BadPayload { .. })
        ));
        // Invalid slot discriminant in an input.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u8(&mut p, 0);
        put_u8(&mut p, 0);
        put_u32(&mut p, 0); // no nodes
        put_u32(&mut p, 1); // one input
        put_string(&mut p, "n0");
        put_u8(&mut p, 7); // slot: invalid
        assert!(matches!(
            decode_frame(K_SUBMIT_GRAPH, &p),
            Err(ProtocolError::BadPayload { .. })
        ));
        // Truncated mid-node.
        let full = encode_frame(&Frame::SubmitGraph(sample_graph_spec(2, true)));
        let payload = &full[HEADER_LEN..full.len() - 5];
        assert!(decode_frame(K_SUBMIT_GRAPH, payload).is_err());
        // Unknown flag bits in a graph result.
        let mut p = Vec::new();
        put_u64(&mut p, 1);
        put_u64(&mut p, 2);
        put_u8(&mut p, 0b10);
        assert!(matches!(
            decode_frame(K_GRAPH_RESULT, &p),
            Err(ProtocolError::BadPayload { .. })
        ));
    }

    #[test]
    fn graph_spec_job_conversion_preserves_structure() {
        let spec = sample_graph_spec(3, true);
        let job = spec.clone().into_job(42);
        assert_eq!(job.id, 42);
        assert_eq!(job.graph.len(), 2);
        assert_eq!(job.graph.nodes[0].gemm, Gemm::new(8, 16, 16));
        assert_eq!(job.graph.nodes[1].a, OperandSource::Node("n0".to_string()));
        assert_eq!(job.graph.nodes[1].b, OperandSource::External);
        assert!(job.validate);
        assert!(!job.keep_outputs);
        assert_eq!(job.inputs.len(), 3);
        // The rebuilt graph validates (topo order + edge shapes intact).
        assert!(job.graph.validate().is_ok());
        // from_graph/graph() are inverses on the node structure.
        let back = GraphSpec::from_graph(3, &job.graph, spec.objective, Vec::new());
        assert_eq!(back.nodes, spec.nodes);
    }

    #[test]
    fn spec_job_conversion_preserves_fields() {
        let spec = sample_spec(3, true);
        let job = spec.clone().into_job(99);
        assert_eq!(job.id, 99);
        assert_eq!(job.gemm, Gemm::new(64, 96, 32));
        assert_eq!(job.objective, Objective::EnergyEfficiency);
        assert!(job.validate);
        assert_eq!(job.a.as_ref().map(Vec::len), Some(64 * 32));
        assert_eq!(job.b.as_ref().map(Vec::len), Some(32 * 96));
    }
}
