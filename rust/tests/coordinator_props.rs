//! Property tests on coordinator invariants (routing, batching, state):
//! randomized job streams through the planner/executor pipeline, with
//! the invariants every router must keep — exactly-once completion, id
//! preservation, cache coherence, monotonic stats.

use versal_gemm::config::Config;
use versal_gemm::coordinator::{Coordinator, GemmJob};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::{DseEngine, Objective};
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::util::forall;
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::{training_workloads, Gemm};

fn quick_cfg() -> Config {
    let mut cfg = Config::default();
    cfg.dataset.top_k = 8;
    cfg.dataset.bottom_k = 6;
    cfg.dataset.random_k = 20;
    cfg.train.n_trees = 40;
    cfg.train.learning_rate = 0.25;
    cfg
}

fn engine(cfg: &Config) -> DseEngine {
    let wl: Vec<_> = training_workloads().into_iter().take(3).collect();
    let ds = Dataset::generate(cfg, &wl);
    DseEngine::new(Predictors::train(&ds, cfg, FeatureSet::SetIAndII), &cfg.board)
}

/// Random pool of plan-only jobs over a small shape alphabet.
fn random_jobs(rng: &mut Rng, n: usize) -> Vec<GemmJob> {
    let shapes = [
        Gemm::new(128, 256, 128),
        Gemm::new(256, 512, 256),
        Gemm::new(64, 1024, 512),
        Gemm::new(512, 512, 512),
    ];
    (0..n as u64)
        .map(|i| {
            GemmJob::plan_only(
                i,
                shapes[rng.below(shapes.len())],
                if rng.bool(0.5) {
                    Objective::Throughput
                } else {
                    Objective::EnergyEfficiency
                },
            )
        })
        .collect()
}

#[test]
fn property_every_job_completes_exactly_once() {
    let cfg = quick_cfg();
    let eng = engine(&cfg);
    forall(
        0xC0DE,
        6,
        |r| {
            let n = r.range_usize(1, 24);
            let planners = r.range_usize(1, 3);
            (random_jobs(r, n), planners)
        },
        |(jobs, planners)| {
            let mut coord = Coordinator::start(&cfg, eng.clone(), None, *planners);
            let n = jobs.len();
            let results = coord.run_batch(jobs.clone());
            assert_eq!(results.len(), n, "lost or duplicated jobs");
            let mut ids: Vec<u64> = results.iter().map(|r| r.id).collect();
            ids.sort_unstable();
            ids.dedup();
            assert_eq!(ids.len(), n, "duplicate completions");
            assert!(coord.next_result().is_none(), "phantom extra result");
        },
    );
}

#[test]
fn property_cache_is_coherent() {
    // Jobs with the same (gemm, objective) must all receive the same plan
    // regardless of planner interleaving.
    let cfg = quick_cfg();
    let eng = engine(&cfg);
    forall(
        0xCACE,
        5,
        |r| random_jobs(r, 20),
        |jobs| {
            let mut coord = Coordinator::start(&cfg, eng.clone(), None, 2);
            let results = coord.run_batch(jobs.clone());
            use std::collections::HashMap;
            let mut seen: HashMap<(String, &str), _> = HashMap::new();
            for res in &results {
                let plan = res.plan.expect("plan");
                let key = (res.gemm.label(), res.objective.label());
                match seen.get(&key) {
                    None => {
                        seen.insert(key, plan.tiling);
                    }
                    Some(prev) => assert_eq!(
                        *prev, plan.tiling,
                        "cache served different plans for {key:?}"
                    ),
                }
            }
            let stats = coord.stats();
            // Every planned job is exactly one of: cache hit, cache miss
            // (ran the exploration), or coalesced onto another job's
            // in-flight exploration.
            assert_eq!(
                stats.cache_hits + stats.cache_misses + stats.coalesced_plans,
                results.len() as u64
            );
            // Single-flight: at most one exploration per distinct key —
            // the seed could run one per planner racing the same key.
            assert_eq!(stats.cache_misses as usize, seen.len());
        },
    );
}

#[test]
fn property_stats_monotonic_across_batches() {
    let cfg = quick_cfg();
    let eng = engine(&cfg);
    let mut coord = Coordinator::start(&cfg, eng, None, 2);
    let mut rng = Rng::new(7);
    let mut prev_completed = 0u64;
    let mut prev_energy = 0.0f64;
    for round in 0..4 {
        let jobs = random_jobs(&mut rng, 6)
            .into_iter()
            .enumerate()
            .map(|(i, mut j)| {
                j.id = (round * 10 + i) as u64;
                j
            })
            .collect();
        let _ = coord.run_batch(jobs);
        let s = coord.stats();
        assert!(s.jobs_completed >= prev_completed);
        assert!(s.simulated_energy_j >= prev_energy);
        prev_completed = s.jobs_completed;
        prev_energy = s.simulated_energy_j;
    }
}

#[test]
fn property_results_sorted_and_plans_valid() {
    let cfg = quick_cfg();
    let eng = engine(&cfg);
    forall(
        0x50FA,
        4,
        |r| {
            let n = r.range_usize(2, 16);
            random_jobs(r, n)
        },
        |jobs| {
            let mut coord = Coordinator::start(&cfg, eng.clone(), None, 2);
            let results = coord.run_batch(jobs.clone());
            // run_batch returns id-sorted results.
            for w in results.windows(2) {
                assert!(w[0].id < w[1].id);
            }
            for res in &results {
                let plan = res.plan.expect("plan");
                // The chosen tiling partitions its workload.
                assert!(plan.tiling.l3_iters(&res.gemm, 32).is_some());
                assert!(plan.simulated.gflops > 0.0);
                assert!(plan.simulated.power_w > 10.0);
            }
        },
    );
}
