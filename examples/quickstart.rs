//! Quickstart: train (or load) the models, then ask the framework for
//! both a throughput-optimal and an energy-optimal mapping of one GEMM,
//! and check the predictions against the simulated board.
//!
//! Run with: `cargo run --release --example quickstart [-- MxNxK]`

use versal_gemm::config::Config;
use versal_gemm::dse::{best_buildable, Objective};
use versal_gemm::report::Lab;
use versal_gemm::versal::VersalSim;
use versal_gemm::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    let arg = std::env::args().nth(1).unwrap_or_else(|| "512x3072x768".into());
    let dims: Vec<usize> = arg.split('x').map(|d| d.parse().unwrap()).collect();
    anyhow::ensure!(dims.len() == 3, "expected MxNxK, got {arg}");
    let g = Gemm::new(dims[0], dims[1], dims[2]);

    // Offline phase (cached in data/): ~6000-design dataset + GBDT models.
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;
    let engine = lab.engine();
    let sim = VersalSim::new(&cfg);

    println!("== versal-gemm quickstart: GEMM {} ==", g.label());
    let result = engine.explore(&g)?;
    println!(
        "design space: {} candidates, {} feasible, Pareto front of {} ({} ms DSE)\n",
        result.n_candidates,
        result.n_feasible,
        result.pareto.len(),
        result.elapsed.as_millis()
    );

    for objective in [Objective::Throughput, Objective::EnergyEfficiency] {
        let (sel, m) = best_buildable(&result, &sim, &g, objective)
            .ok_or_else(|| anyhow::anyhow!("no buildable design"))?;
        println!("objective {}:", objective.label());
        println!("  mapping   {}  (#AIE = {})", sel.tiling.label(), sel.tiling.n_aie());
        println!(
            "  predicted {:>8.1} GFLOP/s  {:>6.1} W  {:>6.2} GFLOP/s/W",
            sel.gflops, sel.prediction.power_w, sel.energy_eff
        );
        println!(
            "  measured  {:>8.1} GFLOP/s  {:>6.1} W  {:>6.2} GFLOP/s/W  ({:.3} ms)",
            m.gflops,
            m.power_w,
            m.energy_eff,
            m.latency_s * 1e3
        );
        println!();
    }
    Ok(())
}
