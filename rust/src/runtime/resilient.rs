//! Resilient execution: deadlines, retry/backoff, and circuit-breaker
//! backend failover (DESIGN.md §10).
//!
//! [`ResilientExec`] wraps the capability chain of execution tiers
//! (`pjrt → cpu → sim` under `auto`; a single tier under an explicit
//! `--backend`) and gives every executed job:
//!
//! * **a deadline** — when a job (or `CoordinatorOptions`) carries
//!   `deadline_ms`, the backend call runs on a watchdog-supervised
//!   worker thread and the caller waits with `recv_timeout`; a hung
//!   backend yields a typed `deadline exceeded` error and a respawned
//!   worker instead of a wedged executor;
//! * **retries** — transient failures retry with decorrelated-jitter
//!   exponential backoff up to `retry_budget`, and the backoff sleep is
//!   cancellation-aware so shutdown never waits on a retrying job;
//! * **failover** — each tier carries a circuit breaker
//!   (Closed → Open after K consecutive failures or one permanent
//!   failure → HalfOpen probe after a cooldown); while a breaker is
//!   open, jobs demote to the next live tier, and a successful probe
//!   promotes straight back because selection always prefers the
//!   highest tier that admits.
//!
//! With no deadline and no fault plan the chain is pass-through: the
//! preferred tier executes inline on the executor thread through
//! exactly the PR-8 code path — no worker hop, no operand clones, and
//! bit-identical numerics.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::runtime::backend::{make_single_backend, BackendChoice, ExecBackend};
use crate::runtime::faults::{FaultInjector, FaultPlan, FaultyBackend, PERMANENT_MARKER};
use crate::runtime::microkernel::CpuProfileChoice;
use crate::tiling::Tiling;
use crate::util::backoff;
use crate::util::rng::Rng;
use crate::versal::{Measurement, VersalSim};
use crate::workloads::Gemm;

/// Marker in errors produced by a deadline expiry; kept transient by
/// [`classify`] (the next attempt may land on a healthy tier).
pub const TIMEOUT_MARKER: &str = "deadline exceeded";

/// Marker in errors from a tier whose backend failed to construct.
/// Such a tier is demoted permanently (dead) without consuming the
/// job's retry budget — the runtime analogue of the old startup probe.
const BUILD_FAILED_MARKER: &str = "backend build failed";

/// First backoff delay; successive delays random-walk toward the cap.
const BACKOFF_BASE: Duration = Duration::from_millis(10);

/// Ceiling on a single retry backoff sleep.
const BACKOFF_CAP: Duration = Duration::from_millis(250);

/// Retry/deadline/breaker policy. Defaults are the serving defaults:
/// no deadline (pure pass-through), three retries, breaker trips after
/// three consecutive failures and probes again after eight selection
/// passes.
#[derive(Debug, Clone, PartialEq)]
pub struct ResilientOptions {
    /// Default per-attempt deadline applied to jobs without their own.
    /// `None` disables supervision entirely (inline execution).
    pub job_deadline_ms: Option<u64>,
    /// Max retries per job (attempts = retries + 1).
    pub retry_budget: u32,
    /// Consecutive transient failures that open a tier's breaker.
    pub breaker_threshold: u32,
    /// Selection passes an open breaker waits before half-opening.
    pub breaker_cooldown: u64,
    /// Fault-injection plan; `None` in production.
    pub faults: Option<FaultPlan>,
}

impl Default for ResilientOptions {
    fn default() -> ResilientOptions {
        ResilientOptions {
            job_deadline_ms: None,
            retry_budget: 3,
            breaker_threshold: 3,
            breaker_cooldown: 8,
            faults: None,
        }
    }
}

/// Transient errors are retried (possibly on another tier); permanent
/// errors trip the tier's breaker immediately and are never retried on
/// the same tier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorClass {
    Transient,
    Permanent,
}

/// Substring taxonomy over backend error text. Permanent: injected
/// permanent faults, backend construction failures, artifact/PJRT
/// load problems, and shape/capability mismatches no retry can fix.
/// Everything else — injected transients, deadline expiries, worker
/// panics, I/O blips — is transient.
pub fn classify(error: &str) -> ErrorClass {
    const PERMANENT: [&str; 7] = [
        PERMANENT_MARKER,
        BUILD_FAILED_MARKER,
        "artifact",
        "PJRT",
        "unsupported",
        "does not support",
        "shapes do not match",
    ];
    if PERMANENT.iter().any(|m| error.contains(m)) {
        ErrorClass::Permanent
    } else {
        ErrorClass::Transient
    }
}

/// Per-tier circuit breaker state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// The Closed → Open → HalfOpen machine guarding one tier. Cooldown is
/// counted in selection passes, not wall time, so tests and CI replay
/// deterministically.
#[derive(Debug, Clone)]
struct Breaker {
    state: BreakerState,
    consecutive: u32,
    cooldown_left: u64,
    threshold: u32,
    cooldown: u64,
}

impl Breaker {
    fn new(threshold: u32, cooldown: u64) -> Breaker {
        Breaker {
            state: BreakerState::Closed,
            consecutive: 0,
            cooldown_left: 0,
            threshold: threshold.max(1),
            cooldown: cooldown.max(1),
        }
    }

    /// Whether the tier may execute now. Called once per selection
    /// pass; an open breaker ticks its cooldown here and half-opens
    /// (admitting one probe) when it reaches zero.
    fn admits(&mut self) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                self.cooldown_left = self.cooldown_left.saturating_sub(1);
                if self.cooldown_left == 0 {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    fn record_success(&mut self) {
        self.state = BreakerState::Closed;
        self.consecutive = 0;
    }

    /// Record a failed attempt; returns `true` when this failure newly
    /// opened the breaker. A permanent failure or a failed HalfOpen
    /// probe trips immediately; transients trip on the Kth consecutive.
    fn record_failure(&mut self, class: ErrorClass) -> bool {
        self.consecutive = self.consecutive.saturating_add(1);
        let trip = class == ErrorClass::Permanent
            || self.state == BreakerState::HalfOpen
            || self.consecutive >= self.threshold;
        if trip && self.state != BreakerState::Open {
            self.state = BreakerState::Open;
            self.cooldown_left = self.cooldown;
            return true;
        }
        if trip {
            // Already open (forced probe while cooling): restart cooldown.
            self.cooldown_left = self.cooldown;
        }
        false
    }
}

/// One rung of the capability chain.
struct Tier {
    choice: BackendChoice,
    breaker: Breaker,
    /// Build failure text; a dead tier is permanently demoted.
    dead: Option<String>,
    /// Inline backend instance, built lazily on the executor thread.
    backend: Option<Box<dyn ExecBackend>>,
}

/// Monotonic resilience counters, surfaced into `CoordinatorStats`.
/// `breaker_state` is the number of live tiers whose breaker is not
/// Closed — 0 reads "healthy".
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ResilienceCounters {
    pub retries_total: u64,
    pub timeouts_total: u64,
    pub failovers_total: u64,
    pub faults_injected: u64,
    pub breaker_state: u64,
}

/// One execution request, borrowed from the job.
pub struct ExecRequest<'a> {
    pub a: &'a [f32],
    pub b: &'a [f32],
    pub g: Gemm,
    /// Selected mapping, for the sim tier's board measurement stamp.
    pub tiling: Option<Tiling>,
    /// Per-job deadline override; falls back to the options default.
    pub deadline_ms: Option<u64>,
}

/// What one job's execution produced, success or not.
pub struct ExecReport {
    pub result: Result<Vec<f32>, String>,
    pub exec_time: Duration,
    pub measurement: Option<Measurement>,
    /// The tier that produced the final outcome (`None` only when no
    /// tier could be constructed at all).
    pub backend_used: Option<&'static str>,
    pub kernel_profile: Option<&'static str>,
    pub retries: u32,
    pub timed_out: bool,
}

/// Everything the watchdog worker needs to build backends inside
/// itself; all `Send + Clone`, unlike the backends it constructs.
#[derive(Clone)]
struct WorkerCfg {
    cpu_profile: CpuProfileChoice,
    artifacts_dir: Option<PathBuf>,
    sim: VersalSim,
    injector: Option<Arc<FaultInjector>>,
}

struct SupRequest {
    seq: u64,
    tier: BackendChoice,
    a: Vec<f32>,
    b: Vec<f32>,
    g: Gemm,
    tiling: Option<Tiling>,
}

struct SupResponse {
    seq: u64,
    outcome: Result<(Vec<f32>, Option<Measurement>), String>,
    exec_time: Duration,
    name: &'static str,
    kernel_profile: Option<&'static str>,
}

/// Caller-side handle to the watchdog worker. Dropping it disconnects
/// both channels; a hung worker notices once its backend call resolves
/// and exits instead of publishing a stale result.
struct Supervisor {
    tx: Sender<SupRequest>,
    rx: Receiver<SupResponse>,
    next_seq: u64,
}

impl Supervisor {
    fn spawn(cfg: WorkerCfg) -> Result<Supervisor, String> {
        let (tx, req_rx) = mpsc::channel::<SupRequest>();
        let (resp_tx, rx) = mpsc::channel::<SupResponse>();
        std::thread::Builder::new()
            .name("exec-watchdog".to_string())
            .spawn(move || supervisor_worker(cfg, req_rx, resp_tx))
            .map_err(|e| format!("failed to spawn watchdog worker: {e}"))?;
        Ok(Supervisor {
            tx,
            rx,
            next_seq: 0,
        })
    }
}

/// The worker loop: build (and cache) backends per tier inside this
/// thread, execute requests, and report back. Panics in a backend are
/// caught and surfaced as transient errors so the watchdog survives.
fn supervisor_worker(cfg: WorkerCfg, rx: Receiver<SupRequest>, tx: Sender<SupResponse>) {
    let mut cache: Vec<(BackendChoice, Box<dyn ExecBackend>)> = Vec::new();
    while let Ok(req) = rx.recv() {
        let seq = req.seq;
        let resp = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            serve_request(&cfg, &mut cache, req)
        }))
        .unwrap_or_else(|_| SupResponse {
            seq,
            outcome: Err("backend panicked inside the watchdog worker".to_string()),
            exec_time: Duration::ZERO,
            name: "?",
            kernel_profile: None,
        });
        if tx.send(resp).is_err() {
            return; // supervisor gone (timeout or shutdown)
        }
    }
}

fn serve_request(
    cfg: &WorkerCfg,
    cache: &mut Vec<(BackendChoice, Box<dyn ExecBackend>)>,
    req: SupRequest,
) -> SupResponse {
    let label = req.tier.label();
    if !cache.iter().any(|(c, _)| *c == req.tier) {
        match build_backend(req.tier, cfg.cpu_profile, cfg.artifacts_dir.as_deref(), &cfg.sim, &cfg.injector)
        {
            Ok(b) => cache.push((req.tier, b)),
            Err(e) => {
                return SupResponse {
                    seq: req.seq,
                    outcome: Err(e),
                    exec_time: Duration::ZERO,
                    name: label,
                    kernel_profile: None,
                }
            }
        }
    }
    let Some((_, b)) = cache.iter().find(|(c, _)| *c == req.tier) else {
        return SupResponse {
            seq: req.seq,
            outcome: Err(format!("{BUILD_FAILED_MARKER} (`{label}`): missing from cache")),
            exec_time: Duration::ZERO,
            name: label,
            kernel_profile: None,
        };
    };
    let (outcome, exec_time) = run_attempt(b.as_ref(), &req.a, &req.b, req.g, req.tiling.as_ref());
    SupResponse {
        seq: req.seq,
        outcome,
        exec_time,
        name: b.name(),
        kernel_profile: b.kernel_profile(),
    }
}

/// One backend call: capability check, GEMM, optional board stamp.
/// `exec_time` covers the GEMM only, matching the inline path.
fn run_attempt(
    b: &dyn ExecBackend,
    a: &[f32],
    bm: &[f32],
    g: Gemm,
    tiling: Option<&Tiling>,
) -> (Result<(Vec<f32>, Option<Measurement>), String>, Duration) {
    if !b.supports(&g) {
        let msg = format!("backend `{}` does not support {}x{}x{}", b.name(), g.m, g.n, g.k);
        return (Err(msg), Duration::ZERO);
    }
    let started = Instant::now();
    match b.gemm(a, bm, g.m, g.n, g.k) {
        Ok(c) => {
            let exec_time = started.elapsed();
            let measurement = tiling.and_then(|t| b.board_measurement(&g, t));
            (Ok((c, measurement)), exec_time)
        }
        Err(e) => (Err(format!("{e:#}")), started.elapsed()),
    }
}

/// Construct (and, under a fault plan, wrap) one concrete tier.
fn build_backend(
    tier: BackendChoice,
    cpu_profile: CpuProfileChoice,
    artifacts_dir: Option<&Path>,
    sim: &VersalSim,
    injector: &Option<Arc<FaultInjector>>,
) -> Result<Box<dyn ExecBackend>, String> {
    let built = make_single_backend(tier, cpu_profile, artifacts_dir, sim.clone())
        .map_err(|e| format!("{BUILD_FAILED_MARKER} (`{}`): {e:#}", tier.label()))?;
    Ok(match injector {
        Some(inj) => Box::new(FaultyBackend::wrap(built, Arc::clone(inj))),
        None => built,
    })
}

struct Attempt {
    outcome: Result<(Vec<f32>, Option<Measurement>), String>,
    exec_time: Duration,
    name: &'static str,
    kernel_profile: Option<&'static str>,
    timed_out: bool,
}

/// The resilient execution chain. Owned by the coordinator's executor
/// thread (deliberately not `Send`, like the backends it holds).
pub struct ResilientExec {
    tiers: Vec<Tier>,
    opts: ResilientOptions,
    cfg: WorkerCfg,
    supervisor: Option<Supervisor>,
    cancel: Arc<AtomicBool>,
    rng: Rng,
    retries_total: u64,
    timeouts_total: u64,
    failovers_total: u64,
}

impl ResilientExec {
    pub fn new(
        choice: BackendChoice,
        cpu_profile: CpuProfileChoice,
        artifacts_dir: Option<&Path>,
        sim: VersalSim,
        opts: ResilientOptions,
    ) -> ResilientExec {
        let injector = opts
            .faults
            .clone()
            .map(|plan| Arc::new(FaultInjector::new(plan)));
        let tiers = choice
            .capability_chain(artifacts_dir.is_some())
            .into_iter()
            .map(|c| Tier {
                choice: c,
                breaker: Breaker::new(opts.breaker_threshold, opts.breaker_cooldown),
                dead: None,
                backend: None,
            })
            .collect();
        let seed = opts.faults.as_ref().map(|p| p.seed).unwrap_or(0x5EED);
        ResilientExec {
            tiers,
            cfg: WorkerCfg {
                cpu_profile,
                artifacts_dir: artifacts_dir.map(Path::to_path_buf),
                sim,
                injector,
            },
            supervisor: None,
            cancel: Arc::new(AtomicBool::new(false)),
            rng: Rng::new(seed ^ 0xBAC0FF),
            retries_total: 0,
            timeouts_total: 0,
            failovers_total: 0,
            opts,
        }
    }

    /// Flag that aborts in-flight retry backoffs; the coordinator sets
    /// it on shutdown so a retrying job never delays teardown.
    pub fn cancel_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.cancel)
    }

    /// Share an external cancellation flag (the coordinator's shutdown
    /// flag) instead of the internal default.
    pub fn with_cancel(mut self, cancel: Arc<AtomicBool>) -> ResilientExec {
        self.cancel = cancel;
        self
    }

    /// Preferred live tier's backend name, or `none (<reason>)` when
    /// the whole chain failed to construct.
    pub fn backend_name(&mut self) -> String {
        for ti in 0..self.tiers.len() {
            if self.ensure_built(ti).is_ok() {
                if let Some(b) = self.tiers[ti].backend.as_ref() {
                    return b.name().to_string();
                }
            }
        }
        let why = self
            .tiers
            .iter()
            .find_map(|t| t.dead.clone())
            .unwrap_or_else(|| "no tiers configured".to_string());
        format!("none ({why})")
    }

    /// Kernel profile of the preferred live tier, if it has one.
    pub fn kernel_profile(&mut self) -> Option<&'static str> {
        for ti in 0..self.tiers.len() {
            if self.ensure_built(ti).is_ok() {
                return self.tiers[ti].backend.as_ref().and_then(|b| b.kernel_profile());
            }
        }
        None
    }

    /// Variant hint from the preferred live tier (batch grouping).
    pub fn variant_hint(&mut self, m: usize, n: usize, k: usize) -> Option<usize> {
        for ti in 0..self.tiers.len() {
            if self.ensure_built(ti).is_ok() {
                return self.tiers[ti]
                    .backend
                    .as_ref()
                    .and_then(|b| b.variant_hint(m, n, k));
            }
        }
        None
    }

    /// Canonical fault-spec label, when chaos is configured.
    pub fn fault_label(&self) -> Option<String> {
        self.cfg.injector.as_ref().map(|i| i.plan().label())
    }

    pub fn counters(&self) -> ResilienceCounters {
        ResilienceCounters {
            retries_total: self.retries_total,
            timeouts_total: self.timeouts_total,
            failovers_total: self.failovers_total,
            faults_injected: self.cfg.injector.as_ref().map(|i| i.injected()).unwrap_or(0),
            breaker_state: self
                .tiers
                .iter()
                .filter(|t| t.dead.is_none() && t.breaker.state != BreakerState::Closed)
                .count() as u64,
        }
    }

    /// Execute one job through the chain: select a tier, attempt
    /// (inline or supervised), classify, retry/failover until success
    /// or the retry budget is spent.
    pub fn execute(&mut self, req: &ExecRequest<'_>) -> ExecReport {
        let deadline_ms = req.deadline_ms.or(self.opts.job_deadline_ms);
        let mut retries: u32 = 0;
        let mut timed_out = false;
        let mut prev_delay = BACKOFF_BASE;
        let mut last_err: Option<(String, &'static str)> = None;
        loop {
            let Some(ti) = self.select_tier() else {
                let why = match &last_err {
                    Some((e, _)) => e.clone(),
                    None => self
                        .tiers
                        .iter()
                        .find_map(|t| t.dead.clone())
                        .unwrap_or_else(|| "no tiers configured".to_string()),
                };
                return ExecReport {
                    result: Err(format!("no execution backend: {why}")),
                    exec_time: Duration::ZERO,
                    measurement: None,
                    backend_used: last_err.as_ref().map(|(_, n)| *n),
                    kernel_profile: None,
                    retries,
                    timed_out,
                };
            };
            let attempt = match deadline_ms {
                None => self.inline_attempt(ti, req),
                Some(ms) => self.supervised_attempt(ti, req, Duration::from_millis(ms.max(1))),
            };
            timed_out |= attempt.timed_out;
            match attempt.outcome {
                Ok((c, measurement)) => {
                    self.tiers[ti].breaker.record_success();
                    return ExecReport {
                        result: Ok(c),
                        exec_time: attempt.exec_time,
                        measurement,
                        backend_used: Some(attempt.name),
                        kernel_profile: attempt.kernel_profile,
                        retries,
                        timed_out,
                    };
                }
                Err(e) => {
                    if e.contains(BUILD_FAILED_MARKER) {
                        // The tier never came up: demote it for good and
                        // move down the chain without spending the
                        // job's retry budget (the runtime analogue of
                        // the old startup probe's auto-fallback).
                        eprintln!("exec backend: tier `{}` unavailable; demoting ({e})", attempt.name);
                        self.tiers[ti].dead = Some(e.clone());
                        last_err = Some((e, attempt.name));
                        continue;
                    }
                    let class = classify(&e);
                    let tripped = self.tiers[ti].breaker.record_failure(class);
                    if tripped && self.live_alternative(ti) {
                        self.failovers_total += 1;
                    }
                    last_err = Some((e.clone(), attempt.name));
                    // A permanent error with nowhere to fail over is a
                    // dead end: retrying the same tier cannot succeed.
                    let dead_end =
                        class == ErrorClass::Permanent && !self.live_alternative(ti);
                    if dead_end || retries >= self.opts.retry_budget {
                        return ExecReport {
                            result: Err(format!("execution failed after {retries} retries: {e}")),
                            exec_time: Duration::ZERO,
                            measurement: None,
                            backend_used: Some(attempt.name),
                            kernel_profile: attempt.kernel_profile,
                            retries,
                            timed_out,
                        };
                    }
                    retries += 1;
                    self.retries_total += 1;
                    // Back off before retrying a transient; permanent
                    // failures fail over immediately and a timed-out
                    // attempt already burned its deadline.
                    if class == ErrorClass::Transient && !attempt.timed_out {
                        prev_delay = backoff::decorrelated_jitter(
                            &mut self.rng,
                            prev_delay,
                            BACKOFF_BASE,
                            BACKOFF_CAP,
                        );
                        if !backoff::cancellable_sleep(prev_delay, &self.cancel) {
                            return ExecReport {
                                result: Err(format!(
                                    "cancelled during retry backoff after {retries} retries: {e}"
                                )),
                                exec_time: Duration::ZERO,
                                measurement: None,
                                backend_used: Some(attempt.name),
                                kernel_profile: attempt.kernel_profile,
                                retries,
                                timed_out,
                            };
                        }
                    }
                }
            }
        }
    }

    /// Highest live tier whose breaker admits. Every live tier's
    /// breaker ticks its cooldown each pass. If nothing admits (all
    /// breakers cooling), force-probe the highest live tier rather
    /// than starve the job.
    fn select_tier(&mut self) -> Option<usize> {
        let mut chosen = None;
        for (i, t) in self.tiers.iter_mut().enumerate() {
            if t.dead.is_some() {
                continue;
            }
            let admits = t.breaker.admits();
            if admits && chosen.is_none() {
                chosen = Some(i);
            }
        }
        chosen.or_else(|| self.tiers.iter().position(|t| t.dead.is_none()))
    }

    /// Is there another live tier to fail over to?
    fn live_alternative(&self, ti: usize) -> bool {
        self.tiers
            .iter()
            .enumerate()
            .any(|(i, t)| i != ti && t.dead.is_none())
    }

    fn ensure_built(&mut self, ti: usize) -> Result<(), String> {
        if let Some(dead) = &self.tiers[ti].dead {
            return Err(dead.clone());
        }
        if self.tiers[ti].backend.is_some() {
            return Ok(());
        }
        match build_backend(
            self.tiers[ti].choice,
            self.cfg.cpu_profile,
            self.cfg.artifacts_dir.as_deref(),
            &self.cfg.sim,
            &self.cfg.injector,
        ) {
            Ok(b) => {
                self.tiers[ti].backend = Some(b);
                Ok(())
            }
            Err(e) => {
                self.tiers[ti].dead = Some(e.clone());
                Err(e)
            }
        }
    }

    /// Inline execution on the executor thread — the pass-through path.
    fn inline_attempt(&mut self, ti: usize, req: &ExecRequest<'_>) -> Attempt {
        let label = self.tiers[ti].choice.label();
        if let Err(e) = self.ensure_built(ti) {
            return Attempt {
                outcome: Err(e),
                exec_time: Duration::ZERO,
                name: label,
                kernel_profile: None,
                timed_out: false,
            };
        }
        let Some(b) = self.tiers[ti].backend.as_ref() else {
            return Attempt {
                outcome: Err(format!("{BUILD_FAILED_MARKER} (`{label}`): backend missing")),
                exec_time: Duration::ZERO,
                name: label,
                kernel_profile: None,
                timed_out: false,
            };
        };
        let (outcome, exec_time) = run_attempt(b.as_ref(), req.a, req.b, req.g, req.tiling.as_ref());
        Attempt {
            outcome,
            exec_time,
            name: b.name(),
            kernel_profile: b.kernel_profile(),
            timed_out: false,
        }
    }

    /// Deadline-supervised execution: ship the attempt to the watchdog
    /// worker and wait at most `deadline`. On expiry the supervisor is
    /// dropped (the hung worker exits once its call resolves — injected
    /// hangs are bounded) and respawned lazily on the next attempt.
    fn supervised_attempt(&mut self, ti: usize, req: &ExecRequest<'_>, deadline: Duration) -> Attempt {
        let tier = self.tiers[ti].choice;
        let label = tier.label();
        let fail = |msg: String, timed_out: bool| Attempt {
            outcome: Err(msg),
            exec_time: Duration::ZERO,
            name: label,
            kernel_profile: None,
            timed_out,
        };
        let mut sup = match self.supervisor.take() {
            Some(s) => s,
            None => match Supervisor::spawn(self.cfg.clone()) {
                Ok(s) => s,
                Err(e) => return fail(e, false),
            },
        };
        sup.next_seq += 1;
        let seq = sup.next_seq;
        let request = SupRequest {
            seq,
            tier,
            a: req.a.to_vec(),
            b: req.b.to_vec(),
            g: req.g,
            tiling: req.tiling,
        };
        if sup.tx.send(request).is_err() {
            return fail("watchdog worker exited; will respawn".to_string(), false);
        }
        let deadline_at = Instant::now() + deadline;
        loop {
            let left = deadline_at.saturating_duration_since(Instant::now());
            match sup.rx.recv_timeout(left) {
                Ok(resp) if resp.seq == seq => {
                    self.supervisor = Some(sup);
                    return Attempt {
                        outcome: resp.outcome,
                        exec_time: resp.exec_time,
                        name: resp.name,
                        kernel_profile: resp.kernel_profile,
                        timed_out: false,
                    };
                }
                Ok(_stale) => continue,
                Err(RecvTimeoutError::Timeout) => {
                    // Drop the supervisor: its channels disconnect, the
                    // wedged worker exits when its call finally
                    // resolves, and the next attempt gets a fresh one.
                    self.timeouts_total += 1;
                    return fail(
                        format!(
                            "{TIMEOUT_MARKER}: `{label}` attempt exceeded its {}ms deadline",
                            deadline.as_millis()
                        ),
                        true,
                    );
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return fail("watchdog worker exited; will respawn".to_string(), false);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::runtime::backend::CpuBackend;
    use crate::runtime::faults::TRANSIENT_MARKER;
    use crate::runtime::{matmul_ref, max_abs_diff};
    use crate::util::rng::Rng as TestRng;
    use crate::versal::BufferPlacement;

    fn sim() -> VersalSim {
        VersalSim::new(&Config::default())
    }

    fn exec_with(choice: BackendChoice, opts: ResilientOptions) -> ResilientExec {
        ResilientExec::new(choice, CpuProfileChoice::Generic, None, sim(), opts)
    }

    fn operands(m: usize, n: usize, k: usize) -> (Vec<f32>, Vec<f32>) {
        let mut rng = TestRng::new(23);
        let a = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b = (0..k * n).map(|_| rng.normal() as f32).collect();
        (a, b)
    }

    fn request<'x>(a: &'x [f32], b: &'x [f32], g: Gemm) -> ExecRequest<'x> {
        ExecRequest {
            a,
            b,
            g,
            tiling: None,
            deadline_ms: None,
        }
    }

    fn faults(spec: &str) -> Option<FaultPlan> {
        Some(FaultPlan::parse(spec).unwrap())
    }

    #[test]
    fn classify_separates_transient_from_permanent() {
        assert_eq!(classify("injected transient fault: 8x8x8"), ErrorClass::Transient);
        assert_eq!(classify("deadline exceeded: `cpu` attempt"), ErrorClass::Transient);
        assert_eq!(classify("connection reset by peer"), ErrorClass::Transient);
        assert_eq!(classify("injected permanent fault: 8x8x8"), ErrorClass::Permanent);
        assert_eq!(classify("backend build failed (`pjrt`): x"), ErrorClass::Permanent);
        assert_eq!(
            classify("backend `pjrt` requires an artifacts directory"),
            ErrorClass::Permanent
        );
        assert_eq!(classify("operand shapes do not match 4x4x4"), ErrorClass::Permanent);
    }

    #[test]
    fn breaker_trips_cools_probes_and_recovers() {
        let mut b = Breaker::new(3, 4);
        assert!(b.admits());
        assert!(!b.record_failure(ErrorClass::Transient));
        assert!(!b.record_failure(ErrorClass::Transient));
        assert!(b.record_failure(ErrorClass::Transient), "third strike trips");
        assert_eq!(b.state, BreakerState::Open);
        // Cooldown: three denied passes, then the fourth half-opens.
        assert!(!b.admits());
        assert!(!b.admits());
        assert!(!b.admits());
        assert!(b.admits());
        assert_eq!(b.state, BreakerState::HalfOpen);
        // A failed probe re-trips instantly; a success recovers.
        assert!(b.record_failure(ErrorClass::Transient));
        assert_eq!(b.state, BreakerState::Open);
        for _ in 0..4 {
            b.admits();
        }
        assert_eq!(b.state, BreakerState::HalfOpen);
        b.record_success();
        assert_eq!(b.state, BreakerState::Closed);
        // Permanent failures trip from Closed in one shot.
        let mut p = Breaker::new(3, 4);
        assert!(p.record_failure(ErrorClass::Permanent));
        assert_eq!(p.state, BreakerState::Open);
    }

    #[test]
    fn passthrough_is_bit_identical_to_the_bare_backend() {
        let (m, n, k) = (48, 40, 56);
        let (a, b) = operands(m, n, k);
        let mut exec = exec_with(BackendChoice::Cpu, ResilientOptions::default());
        let report = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
        let got = report.result.expect("cpu path cannot fail");
        let bare = CpuBackend::new().gemm(&a, &b, m, n, k).unwrap();
        assert_eq!(got, bare, "inline pass-through must be bit-identical");
        assert_eq!(report.retries, 0);
        assert_eq!(report.backend_used, Some("cpu"));
        assert_eq!(report.kernel_profile, Some("generic"));
        assert!(!report.timed_out);
        assert_eq!(exec.counters(), ResilienceCounters::default());
    }

    #[test]
    fn transient_exhaustion_reports_last_error_and_retry_count() {
        let (m, n, k) = (8, 8, 8);
        let (a, b) = operands(m, n, k);
        let mut exec = exec_with(
            BackendChoice::Cpu,
            ResilientOptions {
                retry_budget: 2,
                faults: faults("err:p=1;seed:11"),
                ..ResilientOptions::default()
            },
        );
        let report = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
        let err = report.result.unwrap_err();
        assert!(err.contains("after 2 retries"), "{err}");
        assert!(err.contains(TRANSIENT_MARKER), "{err}");
        assert_eq!(report.retries, 2);
        assert_eq!(report.backend_used, Some("cpu"));
        let c = exec.counters();
        assert_eq!(c.retries_total, 2);
        assert_eq!(c.faults_injected, 3, "three attempts, all injected");
    }

    #[test]
    fn permanent_failure_trips_breaker_and_fails_over_to_sim() {
        let (m, n, k) = (16, 16, 16);
        let (a, b) = operands(m, n, k);
        // Auto chain without artifacts: [cpu, sim]; every cpu call
        // fails permanently, so the first job must complete on sim.
        let mut exec = exec_with(
            BackendChoice::Auto,
            ResilientOptions {
                faults: faults("perm:p=1,backend=cpu;seed:12"),
                ..ResilientOptions::default()
            },
        );
        let report = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
        let got = report.result.expect("sim tier must absorb the job");
        assert!(max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k)) < 1e-3);
        assert_eq!(report.backend_used, Some("sim"));
        assert_eq!(report.retries, 1, "one failover retry");
        let c = exec.counters();
        assert!(c.failovers_total >= 1, "breaker trip with a live lower tier");
        assert_eq!(c.breaker_state, 1, "cpu breaker open");
        // Subsequent jobs go straight to sim while cpu cools down.
        let next = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
        assert!(next.result.is_ok());
        assert_eq!(next.backend_used, Some("sim"));
        assert_eq!(next.retries, 0);
    }

    #[test]
    fn deadline_times_out_a_hung_backend_quickly() {
        let started = Instant::now();
        let (m, n, k) = (8, 8, 8);
        let (a, b) = operands(m, n, k);
        let mut exec = exec_with(
            BackendChoice::Cpu,
            ResilientOptions {
                retry_budget: 1,
                faults: faults("hang:p=1,ms=600;seed:13"),
                ..ResilientOptions::default()
            },
        );
        let mut req = request(&a, &b, Gemm::new(m, n, k));
        req.deadline_ms = Some(120);
        let report = exec.execute(&req);
        let err = report.result.unwrap_err();
        assert!(err.contains(TIMEOUT_MARKER), "{err}");
        assert!(report.timed_out);
        assert_eq!(report.retries, 1);
        assert!(exec.counters().timeouts_total >= 2, "both attempts expired");
        assert!(
            started.elapsed() < Duration::from_secs(2),
            "deadline must bound the wait, took {:?}",
            started.elapsed()
        );
    }

    #[test]
    fn supervised_mode_matches_inline_numerics_and_stamps() {
        let (m, n, k) = (32, 24, 40);
        let (a, b) = operands(m, n, k);
        let g = Gemm::new(m, n, k);
        let t = Tiling::new((2, 2, 2), (2, 2, 2));
        let mut exec = exec_with(BackendChoice::Sim, ResilientOptions::default());
        let mut req = request(&a, &b, g);
        req.tiling = Some(t);
        req.deadline_ms = Some(5_000);
        let report = exec.execute(&req);
        let got = report.result.expect("supervised sim path");
        let bare = CpuBackend::new().gemm(&a, &b, m, n, k).unwrap();
        assert_eq!(got, bare, "worker hop must not perturb numerics");
        assert_eq!(report.backend_used, Some("sim"));
        assert_eq!(report.kernel_profile, Some("generic"));
        let expect_stamp = sim().evaluate(&g, &t, BufferPlacement::UramFirst).is_ok();
        assert_eq!(report.measurement.is_some(), expect_stamp);
        assert_eq!(exec.counters().timeouts_total, 0);
    }

    #[test]
    fn same_spec_and_seed_replays_identical_outcomes() {
        let spec = "err:p=0.4;slow:p=0.2,x=2;seed:21";
        let run = || {
            let (m, n, k) = (8, 8, 8);
            let (a, b) = operands(m, n, k);
            let mut exec = exec_with(
                BackendChoice::Cpu,
                ResilientOptions {
                    faults: faults(spec),
                    ..ResilientOptions::default()
                },
            );
            (0..12)
                .map(|_| {
                    let r = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
                    (r.result.is_ok(), r.retries)
                })
                .collect::<Vec<_>>()
        };
        let first = run();
        let second = run();
        assert_eq!(first, second, "schedule must replay bit-identically");
        assert!(first.iter().any(|(_, retries)| *retries > 0), "p=0.4 must retry");
    }

    #[test]
    fn dead_chain_reports_no_backend_with_reason() {
        let cfg = Config::default();
        let missing = Path::new("definitely/not/artifacts");
        let mut exec = ResilientExec::new(
            BackendChoice::Pjrt,
            CpuProfileChoice::Generic,
            Some(missing),
            VersalSim::new(&cfg),
            ResilientOptions::default(),
        );
        assert!(exec.backend_name().starts_with("none"), "{}", exec.backend_name());
        let (m, n, k) = (4, 4, 4);
        let (a, b) = operands(m, n, k);
        let report = exec.execute(&request(&a, &b, Gemm::new(m, n, k)));
        let err = report.result.unwrap_err();
        assert!(err.contains("no execution backend"), "{err}");
        assert_eq!(report.retries, 0, "dead tiers consume no retry budget");
    }
}
