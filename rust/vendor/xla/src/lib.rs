//! Offline stub of the `xla-rs` PJRT bindings.
//!
//! The real crate links libxla and compiles AOT HLO artifacts on the
//! PJRT CPU client. The offline crate set cannot link it, so this stub
//! mirrors the API surface `versal_gemm::runtime` uses and fails at
//! [`PjRtClient::cpu`] with a descriptive error. A failed client/engine
//! load makes the coordinator's `auto` backend selection fall back to
//! the always-available CPU execution backend
//! (`runtime::backend::CpuBackend`), so the full framework — DSE,
//! coordinator planning *and* data-job execution, simulator, reports —
//! runs unaffected. Swap this path dependency for the real `xla` crate
//! to enable the PJRT execution path.

/// Error type mirroring xla-rs's; only ever Debug/Display-formatted.
#[derive(Debug, Clone)]
pub struct XlaError(pub String);

impl std::fmt::Display for XlaError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for XlaError {}

pub type Result<T> = std::result::Result<T, XlaError>;

fn unavailable<T>() -> Result<T> {
    Err(XlaError(
        "PJRT runtime unavailable (offline xla stub; link the real xla crate to execute artifacts)"
            .to_string(),
    ))
}

/// PJRT client handle (never successfully constructed by the stub).
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "cpu".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    /// Execute on borrowed device buffers; generic over the buffer
    /// argument type like the real binding (`execute_b::<&PjRtBuffer>`).
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// Host literal (stub).
pub struct Literal;

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable()
    }

    pub fn to_vec<T: Copy>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cpu_client_reports_unavailable() {
        let err = match PjRtClient::cpu() {
            Ok(_) => panic!("stub must not construct a client"),
            Err(e) => e,
        };
        assert!(err.to_string().contains("unavailable"));
    }
}
