//! GEMM workload definitions and catalogs.
//!
//! The paper uses two disjoint workload sets:
//! * **Training set** (offline phase, §IV-A.1): 18 GEMMs extracted from
//!   NCF, MLP benchmarks, ViT and BERT — the dataset the ML model is
//!   trained on (≈6000 hardware designs total).
//! * **Evaluation set** (§V-A): 13 GEMMs `G1..G13` from Swin-Tiny,
//!   DeiT-Base, Qwen2.5-0.5B and LLaMA-3-1B, *not* in the training set,
//!   ordered by increasing FLOPs / arithmetic intensity (Figs. 4, 8, 9,
//!   Table III).


pub mod graph;
pub mod models;
/// One GEMM workload: `C[M,N] = A[M,K] @ B[K,N]`, FP32.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Gemm {
    pub m: usize,
    pub n: usize,
    pub k: usize,
}

impl Gemm {
    pub const fn new(m: usize, n: usize, k: usize) -> Gemm {
        Gemm { m, n, k }
    }

    /// Total floating point operations (multiply + add).
    pub fn flops(&self) -> f64 {
        2.0 * self.m as f64 * self.n as f64 * self.k as f64
    }

    /// Bytes touched in DDR assuming each matrix moves once (FP32).
    pub fn min_bytes(&self) -> f64 {
        4.0 * (self.m * self.k + self.k * self.n + self.m * self.n) as f64
    }

    /// Arithmetic intensity (FLOP / byte) — the x-ordering of Figs. 8/9.
    pub fn arithmetic_intensity(&self) -> f64 {
        self.flops() / self.min_bytes()
    }

    /// Dimension padded up to multiples of the AIE micro-tile.
    pub fn padded(&self, tile: usize) -> Gemm {
        let pad = |d: usize| d.div_ceil(tile) * tile;
        Gemm::new(pad(self.m), pad(self.n), pad(self.k))
    }

    /// Per-dimension tile counts after padding.
    pub fn tiles(&self, tile: usize) -> (usize, usize, usize) {
        (
            self.m.div_ceil(tile),
            self.n.div_ceil(tile),
            self.k.div_ceil(tile),
        )
    }

    pub fn label(&self) -> String {
        format!("{}x{}x{}", self.m, self.n, self.k)
    }
}

/// A named workload with provenance (which model/layer it comes from).
#[derive(Debug, Clone, PartialEq)]
pub struct Workload {
    pub id: String,
    pub source: String,
    pub gemm: Gemm,
}

impl Workload {
    fn new(id: &str, source: &str, m: usize, n: usize, k: usize) -> Workload {
        Workload {
            id: id.to_string(),
            source: source.to_string(),
            gemm: Gemm::new(m, n, k),
        }
    }
}

/// The 18 offline-phase training workloads (NCF / MLP / ViT / BERT as in
/// CHARM and the paper). Sizes are the canonical layer GEMMs of each
/// model family.
pub fn training_workloads() -> Vec<Workload> {
    vec![
        // NCF (neural collaborative filtering MLP tower, batch 256).
        Workload::new("ncf_l1", "NCF", 256, 256, 512),
        Workload::new("ncf_l2", "NCF", 256, 128, 256),
        Workload::new("ncf_l3", "NCF", 256, 64, 128),
        Workload::new("ncf_emb", "NCF", 2048, 64, 256),
        // MLP benchmark (CHARM's MLP: 320-sample batch, wide layers).
        Workload::new("mlp_l1", "MLP", 320, 3072, 1024),
        Workload::new("mlp_l2", "MLP", 320, 1024, 3072),
        Workload::new("mlp_l3", "MLP", 320, 1024, 1024),
        Workload::new("mlp_wide", "MLP", 640, 4096, 1024),
        // ViT-Base (sequence 197 -> padded by the mapper; patch 16).
        Workload::new("vit_qkv", "ViT-Base", 197, 2304, 768),
        Workload::new("vit_proj", "ViT-Base", 197, 768, 768),
        Workload::new("vit_fc1", "ViT-Base", 197, 3072, 768),
        Workload::new("vit_fc2", "ViT-Base", 197, 768, 3072),
        // BERT-Base (sequence 512).
        Workload::new("bert_qkv", "BERT-Base", 512, 2304, 768),
        Workload::new("bert_attn_out", "BERT-Base", 512, 768, 768),
        Workload::new("bert_fc1", "BERT-Base", 512, 3072, 768),
        Workload::new("bert_fc2", "BERT-Base", 512, 768, 3072),
        // BERT-Large closers (bigger hidden, stress high-FLOP corner).
        Workload::new("bertL_fc1", "BERT-Large", 512, 4096, 1024),
        Workload::new("bertL_attn", "BERT-Large", 512, 1024, 1024),
    ]
}

/// The 13 evaluation workloads `G1..G13` (paper §V-A): GEMMs from
/// Swin-Tiny, DeiT-Base, Qwen2.5-0.5B and LLaMA-3-1B inference, disjoint
/// from the training set and ordered by increasing FLOPs.
///
/// Decode-shaped layers (batch 32/64 token steps) supply the small,
/// memory-bound `G1..G4`; ViT layers the mid range; prefill LLaMA layers
/// the compute-bound tail, with `G12` the LM-head projection whose
/// skinny-M / huge-N shape quantizes badly on GPU tensor cores (the
/// paper's G12-beats-Orin point).
pub fn eval_workloads() -> Vec<Workload> {
    let mut wl = vec![
        Workload::new("qwen_dec_oproj", "Qwen2.5-0.5B", 32, 896, 896),
        Workload::new("swin_s1_attn", "Swin-Tiny", 3136, 96, 96),
        Workload::new("qwen_dec_gate", "Qwen2.5-0.5B", 32, 4864, 896),
        Workload::new("swin_s2_mlp", "Swin-Tiny", 784, 768, 192),
        Workload::new("deit_attn_proj", "DeiT-Base (batch 8)", 1576, 768, 768),
        Workload::new("deit_qkv", "DeiT-Base (batch 8)", 1576, 2304, 768),
        Workload::new("deit_fc1", "DeiT-Base (batch 8)", 1576, 3072, 768),
        Workload::new("qwen_pre_mlp", "Qwen2.5-0.5B", 1024, 4864, 896),
        Workload::new("llama_pre_qkv", "LLaMA-3-1B", 512, 3072, 2048),
        Workload::new("llama_pre_mlp", "LLaMA-3-1B", 512, 8192, 2048),
        Workload::new("llama_long_attn", "LLaMA-3-1B", 2048, 2048, 2048),
        Workload::new("llama_lm_head", "LLaMA-3-1B", 256, 128256, 2048),
        Workload::new("llama_long_mlp", "LLaMA-3-1B", 2048, 8192, 2048),
    ];
    wl.sort_by(|a, b| a.gemm.flops().total_cmp(&b.gemm.flops()));
    for (i, w) in wl.iter_mut().enumerate() {
        w.id = format!("G{}", i + 1);
    }
    wl
}

/// Look up an eval workload by its `G<n>` id.
pub fn eval_workload(id: &str) -> Option<Workload> {
    eval_workloads().into_iter().find(|w| w.id == id)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flops_and_intensity() {
        let g = Gemm::new(64, 128, 256);
        assert_eq!(g.flops(), 2.0 * 64.0 * 128.0 * 256.0);
        assert!(g.arithmetic_intensity() > 0.0);
        // Bigger square GEMMs have higher arithmetic intensity.
        assert!(
            Gemm::new(1024, 1024, 1024).arithmetic_intensity()
                > Gemm::new(128, 128, 128).arithmetic_intensity()
        );
    }

    #[test]
    fn padding() {
        let g = Gemm::new(197, 768, 768).padded(32);
        assert_eq!(g, Gemm::new(224, 768, 768));
        assert_eq!(Gemm::new(32, 32, 32).padded(32), Gemm::new(32, 32, 32));
        assert_eq!(Gemm::new(197, 768, 768).tiles(32), (7, 24, 24));
    }

    #[test]
    fn training_set_has_18_unique() {
        let wl = training_workloads();
        assert_eq!(wl.len(), 18);
        let mut ids: Vec<&str> = wl.iter().map(|w| w.id.as_str()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 18);
    }

    #[test]
    fn eval_set_is_13_sorted_by_flops() {
        let wl = eval_workloads();
        assert_eq!(wl.len(), 13);
        for i in 1..wl.len() {
            assert!(wl[i].gemm.flops() >= wl[i - 1].gemm.flops());
            assert_eq!(wl[i].id, format!("G{}", i + 1));
        }
    }

    #[test]
    fn train_and_eval_disjoint() {
        let train = training_workloads();
        let eval = eval_workloads();
        for e in &eval {
            assert!(
                train.iter().all(|t| t.gemm != e.gemm),
                "eval workload {} leaked into training set",
                e.id
            );
        }
    }

    #[test]
    fn eval_lookup() {
        assert!(eval_workload("G1").is_some());
        assert!(eval_workload("G13").is_some());
        assert!(eval_workload("G14").is_none());
    }

    #[test]
    fn eval_spans_three_orders_of_magnitude() {
        let wl = eval_workloads();
        let lo = wl.first().unwrap().gemm.flops();
        let hi = wl.last().unwrap().gemm.flops();
        assert!(hi / lo > 500.0, "span {}", hi / lo);
    }
}
