//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Model class** — GBDT (the paper's choice) vs ridge regression vs
//!    k-NN vs the analytical model, on known and unknown workloads;
//! 2. **Feature ablation** — drop each Set-II feature group and measure
//!    the unknown-workload MAPE (why ρ and the R-ratios matter);
//! 3. **Sampling strategy** — analytically-guided offline sampling
//!    (paper §IV-A.1) vs pure-random sampling at the same budget.

use crate::analytical::AnalyticalModel;
use crate::dataset::Dataset;
use crate::features::{featurize, FeatureSet, N_FEATURES};
use crate::gbdt::baselines::{Knn, Ridge};
use crate::gbdt::{FeatureMatrix, Gbdt};
use crate::metrics::mape;
use crate::report::Lab;
use crate::util::rng::Rng;
use crate::util::table::Table;

/// Column indices of the ablatable Set-II feature groups.
const GROUPS: [(&str, &[usize]); 4] = [
    ("none (full Set-I&II)", &[]),
    ("drop N_AIE + rho", &[9, 10]),
    ("drop R_P ratios", &[11, 12, 13]),
    ("drop R_B ratios", &[14, 15, 16]),
];

fn matrix_without(ds: &Dataset, micro: usize, drop: &[usize]) -> FeatureMatrix {
    let rows: Vec<Vec<f64>> = ds
        .points
        .iter()
        .map(|p| {
            let full = featurize(&p.gemm, &p.tiling, micro);
            (0..N_FEATURES)
                .filter(|j| !drop.contains(j))
                .map(|j| full[j])
                .collect()
        })
        .collect();
    FeatureMatrix::from_rows(&rows)
}

fn log_latency(ds: &Dataset) -> Vec<f64> {
    ds.points.iter().map(|p| p.measurement.latency_s.ln()).collect()
}

fn latency(ds: &Dataset) -> Vec<f64> {
    ds.points.iter().map(|p| p.measurement.latency_s).collect()
}

/// Render the full ablation report.
pub fn ablation(lab: &Lab) -> String {
    let cfg = &lab.cfg;
    let micro = cfg.board.micro_tile;
    let mut out = String::new();
    out.push_str("== Ablation studies ==\n\n");

    // Unknown-workload split (the hard generalization case).
    let ids = lab.dataset.workload_ids();
    let held: Vec<&str> = ids.iter().step_by(5).map(String::as_str).collect();
    let (train, test) = lab.dataset.split_by_workload(&held);
    let truth = latency(&test);

    // ---- 1. model class --------------------------------------------------
    let xtr = train.feature_matrix(micro, FeatureSet::SetIAndII);
    let ytr = log_latency(&train);
    let xte = test.feature_matrix(micro, FeatureSet::SetIAndII);

    let mut rng = Rng::new(cfg.train.seed);
    let gbdt = Gbdt::fit(&xtr, &ytr, &cfg.train, None, &mut rng);
    let ridge = Ridge::fit(&xtr, &ytr, 1.0);
    let knn = Knn::fit(&xtr, &ytr, 7);
    let analytical = AnalyticalModel::new(&cfg.board);

    // Batched evaluation: the GBDT goes through the compiled-forest
    // row-blocked path, the baselines through their scratch-reusing
    // batch entries.
    let expd = |mut v: Vec<f64>| -> Vec<f64> {
        for p in &mut v {
            *p = p.exp();
        }
        v
    };
    let gbdt_pred = expd(gbdt.predict_batch(&xte));
    let ridge_pred = expd(ridge.predict_batch(&xte));
    let knn_pred = expd(knn.predict_batch(&xte));
    let ana_pred: Vec<f64> = test
        .points
        .iter()
        .map(|p| analytical.latency(&p.gemm, &p.tiling).unwrap_or(p.measurement.latency_s))
        .collect();

    let mut t1 = Table::new(
        "(1) model class — latency MAPE on UNKNOWN workloads (%)",
        &["model", "MAPE"],
    );
    t1.row(vec!["GBDT (paper's choice)".into(), format!("{:.2}", mape(&truth, &gbdt_pred))]);
    t1.row(vec!["ridge regression".into(), format!("{:.2}", mape(&truth, &ridge_pred))]);
    t1.row(vec!["k-NN (k=7)".into(), format!("{:.2}", mape(&truth, &knn_pred))]);
    t1.row(vec!["analytical [19]".into(), format!("{:.2}", mape(&truth, &ana_pred))]);
    out.push_str(&t1.render());
    out.push('\n');

    // ---- 2. feature ablation ----------------------------------------------
    let mut t2 = Table::new(
        "(2) Set-II feature ablation — latency MAPE on UNKNOWN workloads (%)",
        &["ablated group", "MAPE"],
    );
    for (name, drop) in GROUPS {
        let xtr = matrix_without(&train, micro, drop);
        let xte = matrix_without(&test, micro, drop);
        let mut rng = Rng::new(cfg.train.seed);
        let model = Gbdt::fit(&xtr, &ytr, &cfg.train, None, &mut rng);
        let pred = expd(model.predict_batch(&xte));
        t2.row(vec![name.to_string(), format!("{:.2}", mape(&truth, &pred))]);
    }
    out.push_str(&t2.render());
    out.push('\n');

    // ---- 3. sampling strategy ----------------------------------------------
    // Regenerate the dataset with guided sampling replaced by pure random
    // at the SAME per-workload budget, and compare model quality on the
    // same unknown-workload split.
    let mut random_cfg = cfg.clone();
    random_cfg.dataset.top_k = 0;
    random_cfg.dataset.bottom_k = 0;
    random_cfg.dataset.random_k =
        cfg.dataset.top_k + cfg.dataset.bottom_k + cfg.dataset.random_k;
    let random_ds = Dataset::generate(&random_cfg, &crate::workloads::training_workloads());
    let (rtrain, rtest) = random_ds.split_by_workload(&held);
    let rtruth = latency(&rtest);
    let rx = rtrain.feature_matrix(micro, FeatureSet::SetIAndII);
    let ry = log_latency(&rtrain);
    let rxe = rtest.feature_matrix(micro, FeatureSet::SetIAndII);
    let mut rng = Rng::new(cfg.train.seed);
    let rmodel = Gbdt::fit(&rx, &ry, &cfg.train, None, &mut rng);
    let rpred = expd(rmodel.predict_batch(&rxe));

    let mut t3 = Table::new(
        "(3) offline sampling strategy — latency MAPE on UNKNOWN workloads (%)",
        &["strategy", "designs", "MAPE"],
    );
    t3.row(vec![
        "analytically guided (paper)".into(),
        lab.dataset.len().to_string(),
        format!("{:.2}", mape(&truth, &gbdt_pred)),
    ]);
    t3.row(vec![
        "pure random, same budget".into(),
        random_ds.len().to_string(),
        format!("{:.2}", mape(&rtruth, &rpred)),
    ]);
    out.push_str(&t3.render());
    out.push_str(
        "\nguided sampling covers the top/bottom of the analytical ranking, so the\n\
         model sees the extremes the DSE must discriminate; random sampling wastes\n\
         budget on the bland middle of the space.\n",
    );
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::features::FeatureSet;
    use crate::models::Predictors;
    use crate::workloads::training_workloads;

    fn quick_lab() -> Lab {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 8;
        cfg.dataset.bottom_k = 6;
        cfg.dataset.random_k = 26;
        cfg.train.n_trees = 50;
        cfg.train.learning_rate = 0.2;
        let ds = Dataset::generate(&cfg, &training_workloads());
        let predictors = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
        Lab::in_memory(cfg, ds, predictors)
    }

    #[test]
    fn ablation_renders_all_three_studies() {
        let lab = quick_lab();
        let s = ablation(&lab);
        assert!(s.contains("model class"));
        assert!(s.contains("feature ablation"));
        assert!(s.contains("sampling strategy"));
        assert!(s.contains("GBDT"));
        assert!(s.contains("ridge"));
    }

    #[test]
    fn gbdt_beats_linear_baseline_on_unknown_workloads() {
        // The core justification for the paper's model choice.
        let lab = quick_lab();
        let cfg = &lab.cfg;
        let ids = lab.dataset.workload_ids();
        let held: Vec<&str> = ids.iter().step_by(5).map(String::as_str).collect();
        let (train, test) = lab.dataset.split_by_workload(&held);
        let xtr = train.feature_matrix(32, FeatureSet::SetIAndII);
        let ytr = log_latency(&train);
        let xte = test.feature_matrix(32, FeatureSet::SetIAndII);
        let truth = latency(&test);
        let mut rng = Rng::new(cfg.train.seed);
        let gbdt = Gbdt::fit(&xtr, &ytr, &cfg.train, None, &mut rng);
        let ridge = Ridge::fit(&xtr, &ytr, 1.0);
        let g: Vec<f64> = gbdt.predict_batch(&xte).iter().map(|p| p.exp()).collect();
        let l: Vec<f64> = ridge.predict_batch(&xte).iter().map(|p| p.exp()).collect();
        assert!(
            mape(&truth, &g) < mape(&truth, &l),
            "gbdt {} >= ridge {}",
            mape(&truth, &g),
            mape(&truth, &l)
        );
    }
}
