//! Report generation: regenerates every table and figure of the paper's
//! evaluation as ASCII tables/plots (see DESIGN.md §8 for the index).
//!
//! [`Lab`] is the shared experiment context: it loads (or generates and
//! caches) the offline-phase dataset and the trained predictors, so
//! every figure starts from the same artifacts the real framework would.

pub mod ablation;
pub mod figures;

use std::cell::RefCell;
use std::path::PathBuf;

use crate::config::Config;
use crate::dataset::Dataset;
use crate::dse::compare::{compare_frameworks, WorkloadComparison};
use crate::dse::DseEngine;
use crate::features::FeatureSet;
use crate::models::Predictors;
use crate::workloads::{eval_workloads, training_workloads, Workload};

/// Shared experiment context for all reports.
pub struct Lab {
    pub cfg: Config,
    pub data_dir: PathBuf,
    pub dataset: Dataset,
    pub predictors: Predictors,
    comparisons: RefCell<Option<Vec<(Workload, WorkloadComparison)>>>,
}

impl Lab {
    /// Load the dataset + models from `data_dir`, generating and caching
    /// them on first use (the offline phase).
    pub fn prepare(cfg: Config, data_dir: PathBuf) -> anyhow::Result<Lab> {
        std::fs::create_dir_all(&data_dir)?;
        let ds_path = data_dir.join("dataset.csv");
        let dataset = if ds_path.exists() {
            let ds = Dataset::load(&cfg, &ds_path)?;
            eprintln!("[lab] loaded dataset: {} designs from {}", ds.len(), ds_path.display());
            ds
        } else {
            eprintln!("[lab] generating offline-phase dataset (~6000 designs)...");
            let ds = Dataset::generate(&cfg, &training_workloads());
            ds.save(&cfg, &ds_path)?;
            eprintln!("[lab] saved {} designs to {}", ds.len(), ds_path.display());
            ds
        };
        let model_path = data_dir.join("predictors.json");
        let predictors = if model_path.exists() {
            let p = Predictors::load(&model_path)?;
            eprintln!("[lab] loaded predictors from {}", model_path.display());
            p
        } else {
            eprintln!("[lab] training predictors (L, P, R models)...");
            let p = Predictors::train(&dataset, &cfg, FeatureSet::SetIAndII);
            p.save(&model_path)?;
            eprintln!("[lab] saved predictors to {}", model_path.display());
            p
        };
        Ok(Lab {
            cfg,
            data_dir,
            dataset,
            predictors,
            comparisons: RefCell::new(None),
        })
    }

    /// In-memory lab for tests/benches (no disk caching).
    pub fn in_memory(cfg: Config, dataset: Dataset, predictors: Predictors) -> Lab {
        Lab {
            cfg,
            data_dir: PathBuf::new(),
            dataset,
            predictors,
            comparisons: RefCell::new(None),
        }
    }

    pub fn engine(&self) -> DseEngine {
        DseEngine::new(self.predictors.clone(), &self.cfg.board)
    }

    /// CHARM/ARIES/Ours on all 13 eval workloads, computed once.
    pub fn comparisons(&self) -> Vec<(Workload, WorkloadComparison)> {
        if let Some(c) = self.comparisons.borrow().as_ref() {
            return c.clone();
        }
        let engine = self.engine();
        let out: Vec<(Workload, WorkloadComparison)> = eval_workloads()
            .into_iter()
            .map(|w| {
                let c = compare_frameworks(&self.cfg, &engine, &w.gemm);
                (w, c)
            })
            .collect();
        *self.comparisons.borrow_mut() = Some(out.clone());
        out
    }
}

/// Render a report by its id (`fig1`, ..., `table3`, `model-quality`).
pub fn render(lab: &Lab, id: &str) -> anyhow::Result<String> {
    Ok(match id {
        "fig1" => figures::fig1_tiling_impact(lab),
        "fig3" => figures::fig3_power_vs_aies(lab),
        "fig4" => figures::fig4_tradeoffs(lab),
        "fig6" => figures::fig6_r2_vs_training_size(lab),
        "fig7" => figures::fig7_prediction_error(lab),
        "fig8" => figures::fig8_sota_comparison(lab),
        "fig9" => figures::fig9_gpu_comparison(lab),
        "fig10" => figures::fig10_pareto_fronts(lab),
        "table2" => figures::table2_devices(),
        "table3" => figures::table3_resources(lab),
        "model-quality" => figures::model_quality(lab),
        "ablation" => ablation::ablation(lab),
        "all" => {
            let ids = [
                "table2", "fig1", "fig3", "fig4", "fig6", "fig7", "fig8", "table3", "fig9",
                "fig10", "model-quality", "ablation",
            ];
            let mut out = String::new();
            for i in ids {
                out.push_str(&render(lab, i)?);
                out.push('\n');
            }
            out
        }
        other => anyhow::bail!(
            "unknown report `{other}` (fig1|fig3|fig4|fig6|fig7|fig8|fig9|fig10|table2|table3|model-quality|ablation|all)"
        ),
    })
}
