//! Bench: L3 execution hot path — the packed-panel CPU microkernel
//! behind `runtime::backend` vs the legacy blocked oracle.
//!
//! Section 1 gates numerics (always asserted, smoke included): the
//! packed-panel GEMM must match `matmul_ref` within the k·eps forward-
//! error bound and agree *bitwise* with the legacy blocked loop and the
//! reference on integer-valued operands. Section 2 sweeps GFLOPS per
//! kernel profile × pool width {1, 4} against `gemm_blocked_legacy`
//! (timing is report-only in smoke; CI gates on the emitted
//! `BENCH_gemm.json` instead). Section 3 (non-smoke) is the acceptance
//! assert: ≥3x single-thread GFLOPS over the legacy path on 1024³ with
//! ulp-scaled numerics vs `matmul_ref`. Section 4 serves data jobs
//! through a coordinator with `--backend cpu` and asserts the per-job
//! energy accounting plus the new kernel-profile/packed-GFLOPS stats
//! surface. Section 5 is the original PJRT tiled executor over the AOT
//! Pallas artifacts (requires `make artifacts`).
//!
//! `--smoke` (CI on every PR) runs sections 1–2 and 4 with reduced
//! shapes and writes the perf-trajectory snapshot `BENCH_gemm.json`.
use std::sync::Arc;

use versal_gemm::config::Config;
use versal_gemm::coordinator::{BackendChoice, Coordinator, CoordinatorOptions, GemmJob};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::{DseEngine, DsePool, Objective};
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::runtime::backend::{gemm_blocked_legacy, CpuBackend, ExecBackend};
use versal_gemm::runtime::microkernel::KernelProfile;
use versal_gemm::runtime::{matmul_ref, GemmEngine};
use versal_gemm::util::bench::{bench, once, report, report_throughput};
use versal_gemm::util::json::{num, obj, s};
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::{training_workloads, Gemm};

fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| rng.normal() as f32).collect()
}

fn randi(rng: &mut Rng, n: usize) -> Vec<f32> {
    (0..n).map(|_| (rng.below(13) as f32) - 6.0).collect()
}

/// Elementwise `|got - want| <= k · eps · Σ|a||b|` — the standard
/// forward-error bound for a k-term f32 dot product, i.e. an ulp-scaled
/// tolerance that adapts to operand magnitude instead of a fixed 1e-3.
fn assert_ulp_scaled(got: &[f32], want: &[f32], bound: &[f32], k: usize, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: shape mismatch");
    for (i, ((g, w), b)) in got.iter().zip(want).zip(bound).enumerate() {
        let tol = (k as f32) * f32::EPSILON * b + f32::MIN_POSITIVE;
        assert!((g - w).abs() <= tol, "{what}: element {i}: got {g} want {w} (tol {tol})");
    }
}

/// `Σ|a||b|` per output element: the magnitude scale of each dot
/// product, used to size the ulp tolerance above.
fn abs_bound(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let aa: Vec<f32> = a.iter().map(|v| v.abs()).collect();
    let ab: Vec<f32> = b.iter().map(|v| v.abs()).collect();
    matmul_ref(&aa, &ab, m, n, k)
}

fn median_gflops(stats: &versal_gemm::util::bench::BenchStats, flops: f64) -> f64 {
    flops / 1e9 / stats.median.as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let mut rng = Rng::new(3);

    // ---- 1. numerics gates: packed-panel vs reference + legacy oracle --
    println!("== bench: packed-panel cpu microkernel — numerics gates ==");
    let cpu = CpuBackend::new(); // generic profile, global pool
    for &(m, n, k) in &[(96usize, 80usize, 72usize), (128, 128, 128), (70, 50, 90)] {
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let got = cpu.gemm(&a, &b, m, n, k)?;
        let want = matmul_ref(&a, &b, m, n, k);
        assert_ulp_scaled(&got, &want, &abs_bound(&a, &b, m, n, k), k, "packed vs ref");
        // Integer operands are exact in f32: packed, legacy, and the
        // reference must agree to the bit.
        let ai = randi(&mut rng, m * k);
        let bi = randi(&mut rng, k * n);
        let pi = cpu.gemm(&ai, &bi, m, n, k)?;
        assert_eq!(pi, gemm_blocked_legacy(&ai, &bi, m, n, k), "{m}x{n}x{k} vs legacy");
        assert_eq!(pi, matmul_ref(&ai, &bi, m, n, k), "{m}x{n}x{k} vs ref");
    }
    println!("numerics gates OK (ulp-scaled vs ref, bitwise vs legacy on integers)");

    // ---- 2. GFLOPS per kernel profile × pool width ---------------------
    let (m, n, k) = if smoke { (256, 256, 256) } else { (512, 512, 512) };
    println!("== bench: microkernel GFLOPS per profile × pool width ({m}x{n}x{k}) ==");
    let a = randn(&mut rng, m * k);
    let b = randn(&mut rng, k * n);
    let flops = 2.0 * (m * n * k) as f64;
    let (warmup, iters) = if smoke { (1, 3) } else { (2, 6) };
    let legacy_stats = bench(warmup, iters, || {
        std::hint::black_box(gemm_blocked_legacy(&a, &b, m, n, k));
    });
    let legacy_gflops = median_gflops(&legacy_stats, flops);
    report_throughput("legacy blocked loop (oracle)", &legacy_stats, flops / 1e9, "GFLOP");
    let mut sweep: Vec<(String, f64)> = Vec::new();
    let mut microkernel_gflops = 0.0;
    for profile in [
        KernelProfile::generic(),
        KernelProfile::l2_small(),
        KernelProfile::l2_large(),
    ] {
        for width in [1usize, 4] {
            let backend = CpuBackend::new()
                .with_profile(profile)
                .with_pool(Arc::new(DsePool::new(width)));
            let stats = bench(warmup, iters, || {
                std::hint::black_box(backend.gemm(&a, &b, m, n, k).unwrap());
            });
            let gflops = median_gflops(&stats, flops);
            let label = format!("packed {} (pool width {width})", profile.name);
            report_throughput(&label, &stats, flops / 1e9, "GFLOP");
            if profile.name == "generic" && width == 1 {
                microkernel_gflops = gflops;
            }
            let key = format!("{}_w{}_gflops", profile.name.replace('-', "_"), width);
            sweep.push((key, gflops));
        }
    }
    let auto_profile = KernelProfile::detect();
    println!(
        "single-thread generic {microkernel_gflops:.2} GFLOP/s vs legacy {legacy_gflops:.2} \
         GFLOP/s ({:.1}x); auto profile resolves to `{}`",
        microkernel_gflops / legacy_gflops.max(1e-12),
        auto_profile.name
    );

    // ---- 3. acceptance: ≥3x single-thread over legacy on 1024³ ---------
    if !smoke {
        let (m, n, k) = (1024usize, 1024usize, 1024usize);
        println!("== bench: acceptance — packed vs legacy, single thread, {m}x{n}x{k} ==");
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let flops = 2.0 * (m * n * k) as f64;
        let solo = CpuBackend::new()
            .with_profile(auto_profile)
            .with_pool(Arc::new(DsePool::new(1)));
        let got = solo.gemm(&a, &b, m, n, k)?;
        assert_ulp_scaled(
            &got,
            &matmul_ref(&a, &b, m, n, k),
            &abs_bound(&a, &b, m, n, k),
            k,
            "packed 1024^3 vs ref",
        );
        let micro_stats = bench(1, 3, || {
            std::hint::black_box(solo.gemm(&a, &b, m, n, k).unwrap());
        });
        let legacy_stats = bench(1, 2, || {
            std::hint::black_box(gemm_blocked_legacy(&a, &b, m, n, k));
        });
        let micro = median_gflops(&micro_stats, flops);
        let legacy = median_gflops(&legacy_stats, flops);
        println!(
            "single-thread {m}x{n}x{k}: packed ({}) {micro:.2} GFLOP/s vs legacy \
             {legacy:.2} GFLOP/s — {:.1}x (acceptance floor: 3x)",
            auto_profile.name,
            micro / legacy.max(1e-12)
        );
        assert!(
            micro >= 3.0 * legacy,
            "packed-panel microkernel not >=3x legacy: {micro:.2} vs {legacy:.2} GFLOP/s"
        );
    }

    // ---- 4. serving energy accounting over the CPU backend -------------
    println!("== bench: coordinator data jobs + per-job energy accounting (backend cpu) ==");
    let mut cfg = Config::default();
    cfg.dataset.top_k = 10;
    cfg.dataset.bottom_k = 6;
    cfg.dataset.random_k = 30;
    cfg.train.n_trees = 60;
    cfg.train.learning_rate = 0.2;
    let engine = once("offline phase (reduced dataset + train)", || {
        let wl: Vec<_> = training_workloads().into_iter().take(4).collect();
        let ds = Dataset::generate(&cfg, &wl);
        DseEngine::new(Predictors::train(&ds, &cfg, FeatureSet::SetIAndII), &cfg.board)
    });
    let options = CoordinatorOptions {
        backend: BackendChoice::Cpu,
        ..CoordinatorOptions::default()
    };
    let mut coord = Coordinator::start_with(&cfg, engine, None, 2, options);
    let n_jobs = if smoke { 4u64 } else { 12 };
    let jobs: Vec<GemmJob> = (0..n_jobs)
        .map(|i| {
            let g = Gemm::new(64 * (1 + i as usize % 3), 256, 128);
            let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
            let mut j = GemmJob::with_data(i, g, Objective::Throughput, a, b);
            j.validate = i % 2 == 0;
            j
        })
        .collect();
    let results = once(&format!("run_batch ({n_jobs} data jobs)"), || {
        coord.run_batch(jobs)
    });
    assert_eq!(results.len(), n_jobs as usize);
    for r in &results {
        assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
        let exec = r.exec_time.expect("executed").as_secs_f64();
        let energy = r.energy_j.expect("energy accounted");
        let avg_w = r.avg_power_w.expect("avg power");
        let gpw = r.gflops_per_w.expect("gflops/W");
        assert!(energy.is_finite() && energy > 0.0, "job {}: energy {energy}", r.id);
        assert!(avg_w.is_finite() && avg_w > 0.0);
        assert!(gpw.is_finite() && gpw > 0.0);
        let drift = (energy - avg_w * exec).abs() / energy;
        assert!(drift < 1e-9, "job {}: energy/power inconsistent ({drift})", r.id);
        if let Some(err) = r.validation_err {
            assert!(err < 1e-2, "job {} numerics {err}", r.id);
        }
    }
    let stats = coord.stats();
    assert_eq!(coord.backend_name(), "cpu");
    assert_eq!(stats.executed_jobs, n_jobs);
    assert!(stats.executed_energy_j > 0.0);
    assert!(stats.executed_gflops_per_w > 0.0);
    // Satellite: the selected kernel profile and packed-panel GFLOPS
    // are visible in the stats surface operators read.
    let profile = coord.kernel_profile().expect("kernel profile");
    assert_eq!(stats.cpu_kernel_profile, profile);
    assert!(stats.cpu_gemm_gflops > 0.0, "packed-panel GFLOPS missing from stats");
    println!(
        "backend `{}` (profile {profile}): {} jobs, {:.2} GFLOP/s executed, packed-panel \
         {:.2} GFLOP/s host, {:.3} J total, {:.2} GFLOPS/W aggregate",
        coord.backend_name(),
        stats.executed_jobs,
        stats.executed_gflops(),
        stats.cpu_gemm_gflops,
        stats.executed_energy_j,
        stats.executed_gflops_per_w
    );
    coord.shutdown();

    if smoke {
        // Perf trajectory (ROADMAP): persist the smoke numbers so every
        // CI run leaves a diffable GFLOPS snapshot at the repo root,
        // next to BENCH_serve.json / BENCH_dse.json. CI's
        // perf-trajectory step fails the build when microkernel_gflops
        // regresses below legacy_gflops.
        let mut fields = vec![
            ("bench", s("runtime_gemm")),
            ("mode", s("smoke")),
            ("shape", s(&format!("{m}x{n}x{k}"))),
            ("microkernel_gflops", num(microkernel_gflops)),
            ("legacy_gflops", num(legacy_gflops)),
            ("speedup_vs_legacy", num(microkernel_gflops / legacy_gflops.max(1e-12))),
            ("profile_auto", s(auto_profile.name)),
            ("coordinator_cpu_gemm_gflops", num(stats.cpu_gemm_gflops)),
        ];
        for (key, gflops) in &sweep {
            fields.push((key.as_str(), num(*gflops)));
        }
        let snapshot = obj(fields);
        std::fs::write("BENCH_gemm.json", snapshot.to_string_pretty())?;
        println!("\nwrote BENCH_gemm.json (profiles × widths sweep at {m}x{n}x{k})");
        println!("smoke OK: packed-panel numerics + energy accounting");
        return Ok(());
    }

    // ---- 5. PJRT tiled executor over the AOT artifacts -----------------
    let engine = GemmEngine::load(std::path::Path::new("artifacts"))?;
    println!("== bench: PJRT tiled GEMM executor (platform {}) ==", engine.platform());
    let mut rng = Rng::new(3);
    for &(m, n, k) in &[(128usize, 128usize, 128usize), (256, 256, 256), (32, 896, 896), (512, 512, 512)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let flops = 2.0 * (m * n * k) as f64;
        let stats = bench(2, 8, || {
            std::hint::black_box(engine.gemm(&a, &b, m, n, k).unwrap());
        });
        report(&format!("pjrt gemm {m}x{n}x{k}"), &stats);
        report_throughput("  throughput", &stats, flops / 1e9, "GFLOP");
        let ref_stats = bench(1, 3, || {
            std::hint::black_box(matmul_ref(&a, &b, m, n, k));
        });
        report(&format!("rust ref  {m}x{n}x{k}"), &ref_stats);
    }
    println!("total kernel invocations: {}", engine.invocations.get());
    Ok(())
}
