//! Partial-reconfiguration cost model.
//!
//! Each mapping the framework emits is a distinct bitstream; a serving
//! deployment that switches mappings between jobs pays a reconfiguration
//! penalty: PL partial bitstream load over ICAP/PCAP plus AIE array
//! re-initialization. The coordinator's dynamic batcher uses this model
//! to order jobs so that consecutive jobs share a mapping (and accounts
//! the simulated switch cost in its stats) — the deployment-side
//! extension of the paper's per-workload mapping story.

use crate::config::BoardConfig;
use crate::tiling::Tiling;
use crate::versal::pl::{resources, BufferPlacement};

/// Reconfiguration interface parameters (Versal PCAP-class numbers).
#[derive(Debug, Clone, Copy)]
pub struct ReconfigModel {
    /// Configuration port bandwidth (bytes/s).
    pub pcap_bps: f64,
    /// Bitstream bytes per BRAM/URAM column and per kLUT of region.
    pub bytes_per_bram: f64,
    pub bytes_per_uram: f64,
    pub bytes_per_klut: f64,
    /// Per-AIE ELF load + array reset (s).
    pub aie_load_s: f64,
    /// Fixed handshake/driver overhead per reconfiguration (s).
    pub fixed_s: f64,
}

impl Default for ReconfigModel {
    fn default() -> Self {
        ReconfigModel {
            pcap_bps: 400e6,
            bytes_per_bram: 12.0 * 1024.0,
            bytes_per_uram: 48.0 * 1024.0,
            bytes_per_klut: 24.0 * 1024.0,
            aie_load_s: 60e-6,
            fixed_s: 3e-3,
        }
    }
}

impl ReconfigModel {
    /// Partial-bitstream size for a design's PL region.
    pub fn bitstream_bytes(&self, t: &Tiling, board: &BoardConfig) -> f64 {
        let r = resources(t, board, BufferPlacement::UramFirst);
        self.bytes_per_bram * r.bram as f64
            + self.bytes_per_uram * r.uram as f64
            + self.bytes_per_klut * r.lut as f64 / 1000.0
    }

    /// Seconds to switch `from` one mapping `to` another. `None` for
    /// `from` means cold start (full region load). Switching to the same
    /// mapping is free.
    pub fn switch_time(&self, from: Option<&Tiling>, to: &Tiling, board: &BoardConfig) -> f64 {
        if from == Some(to) {
            return 0.0;
        }
        let pl = self.bitstream_bytes(to, board) / self.pcap_bps;
        let aie = to.n_aie() as f64 * self.aie_load_s;
        self.fixed_s + pl + aie
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn board() -> BoardConfig {
        BoardConfig::default()
    }

    #[test]
    fn same_mapping_is_free() {
        let m = ReconfigModel::default();
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        assert_eq!(m.switch_time(Some(&t), &t, &board()), 0.0);
    }

    #[test]
    fn cold_start_costs_more_than_nothing() {
        let m = ReconfigModel::default();
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let cost = m.switch_time(None, &t, &board());
        assert!(cost > m.fixed_s);
        assert!(cost < 1.0, "reconfig {cost}s absurd");
    }

    #[test]
    fn bigger_regions_cost_more() {
        let m = ReconfigModel::default();
        let small = Tiling::new((2, 2, 1), (1, 1, 1));
        let big = Tiling::new((8, 8, 4), (2, 2, 2));
        let b = board();
        assert!(m.switch_time(None, &big, &b) > m.switch_time(None, &small, &b));
        assert!(m.bitstream_bytes(&big, &b) > m.bitstream_bytes(&small, &b));
    }

    #[test]
    fn switch_between_distinct_mappings_charged() {
        let m = ReconfigModel::default();
        let a = Tiling::new((2, 2, 1), (1, 1, 1));
        let bt = Tiling::new((4, 2, 1), (1, 1, 1));
        assert!(m.switch_time(Some(&a), &bt, &board()) > 0.0);
    }
}
