//! BEAM-style power telemetry (paper §V: "each workload is executed for
//! 60 seconds, during which power data is collected via BEAM tool
//! running on Versal's System Controller").
//!
//! The simulator's [`crate::versal::Measurement`] carries the
//! steady-state mean; this module expands it into the *trace* a BEAM
//! session would log — launch ramp, steady phase with AR(1) supply
//! noise, and trailing idle — and the aggregation the paper applies
//! (window mean of total board power). Used by the offline-phase
//! example, the telemetry tests, and the `sweep` reporting.

use crate::util::rng::{fnv1a, Rng};
use crate::versal::Measurement;

/// A sampled power trace.
#[derive(Debug, Clone, PartialEq)]
pub struct PowerTrace {
    /// Watts per sample.
    pub samples: Vec<f64>,
    /// Sampling period in seconds (BEAM default ~100 ms).
    pub period_s: f64,
}

impl PowerTrace {
    pub fn duration_s(&self) -> f64 {
        self.samples.len() as f64 * self.period_s
    }

    pub fn mean(&self) -> f64 {
        self.samples.iter().sum::<f64>() / self.samples.len().max(1) as f64
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    /// Energy over the window (J).
    pub fn energy_j(&self) -> f64 {
        self.samples.iter().sum::<f64>() * self.period_s
    }

    /// Mean over the steady phase only (what the paper reports as the
    /// workload's power: ramp and tail excluded).
    pub fn steady_mean(&self) -> f64 {
        let n = self.samples.len();
        if n < 10 {
            return self.mean();
        }
        let lo = n / 10;
        let hi = n - n / 20;
        let window = &self.samples[lo..hi];
        window.iter().sum::<f64>() / window.len() as f64
    }
}

/// Parameters of the telemetry session.
#[derive(Debug, Clone, Copy)]
pub struct BeamSession {
    pub duration_s: f64,
    pub sample_rate_hz: f64,
    /// Idle board power before the kernel launches.
    pub idle_w: f64,
    /// AR(1) coefficient and noise scale of the supply regulation.
    pub ar_coeff: f64,
    pub noise_w: f64,
}

impl Default for BeamSession {
    fn default() -> Self {
        BeamSession {
            duration_s: 60.0,
            sample_rate_hz: 10.0,
            idle_w: 11.5,
            ar_coeff: 0.85,
            noise_w: 0.35,
        }
    }
}

impl BeamSession {
    /// Deterministically synthesize the trace a BEAM run of `m` would
    /// log. Keyed by `design_key` so re-measuring a design reproduces
    /// the same trace (as the simulator's noise model does).
    pub fn trace(&self, m: &Measurement, design_key: u64) -> PowerTrace {
        let n = (self.duration_s * self.sample_rate_hz).round() as usize;
        let mut rng = Rng::new(fnv1a(&design_key.to_le_bytes()) ^ 0xBEA0_BEA0);
        let mut samples = Vec::with_capacity(n);
        let ramp = (n / 20).max(1); // launch + clock ramp
        let tail = (n / 40).max(1); // drain + idle return
        let mut ar = 0.0f64;
        for i in 0..n {
            let phase = if i < ramp {
                // Exponential approach to the steady level.
                let x = i as f64 / ramp as f64;
                self.idle_w + (m.power_w - self.idle_w) * (1.0 - (-4.0 * x).exp())
            } else if i >= n - tail {
                self.idle_w + (m.power_w - self.idle_w) * 0.3
            } else {
                m.power_w
            };
            ar = self.ar_coeff * ar + self.noise_w * rng.normal();
            samples.push((phase + ar).max(0.0));
        }
        PowerTrace {
            samples,
            period_s: 1.0 / self.sample_rate_hz,
        }
    }

    /// Synthesize the power trace of one *executed serving job*: a
    /// steady draw of `steady_w` over `duration_s`, with the session's
    /// AR(1) supply noise. Sampled at the session rate but never fewer
    /// than 8 samples, so the coordinator's energy integral
    /// (`JobResult::energy_j = ∫ trace`) stays meaningful for
    /// sub-100-ms host executions. Deterministic per `design_key`.
    pub fn execution_trace(&self, steady_w: f64, duration_s: f64, design_key: u64) -> PowerTrace {
        let duration_s = if duration_s.is_finite() && duration_s > 0.0 {
            duration_s
        } else {
            1.0 / self.sample_rate_hz
        };
        let n = ((duration_s * self.sample_rate_hz).ceil() as usize).clamp(8, 4096);
        let mut rng = Rng::new(fnv1a(&design_key.to_le_bytes()) ^ 0xE4EC_E4EC);
        let mut ar = 0.0f64;
        let mut samples = Vec::with_capacity(n);
        for _ in 0..n {
            ar = self.ar_coeff * ar + self.noise_w * rng.normal();
            samples.push((steady_w + ar).max(0.0));
        }
        PowerTrace {
            samples,
            period_s: duration_s / n as f64,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::versal::Resources;

    fn measurement(power: f64) -> Measurement {
        Measurement {
            latency_s: 1e-3,
            power_w: power,
            resources: Resources::default(),
            gflops: 100.0,
            energy_eff: 100.0 / power,
            busy: 0.9,
        }
    }

    #[test]
    fn steady_mean_recovers_measurement_power() {
        let session = BeamSession::default();
        let m = measurement(30.0);
        let trace = session.trace(&m, 42);
        assert_eq!(trace.samples.len(), 600);
        let err = (trace.steady_mean() - 30.0).abs();
        assert!(err < 0.5, "steady mean off by {err} W");
        // Plain mean is pulled down by ramp/tail.
        assert!(trace.mean() < trace.steady_mean());
    }

    #[test]
    fn trace_is_deterministic_per_design() {
        let session = BeamSession::default();
        let m = measurement(25.0);
        assert_eq!(session.trace(&m, 7), session.trace(&m, 7));
        assert_ne!(session.trace(&m, 7), session.trace(&m, 8));
    }

    #[test]
    fn ramp_starts_near_idle() {
        let session = BeamSession::default();
        let m = measurement(40.0);
        let trace = session.trace(&m, 1);
        assert!(trace.samples[0] < 20.0, "first sample {}", trace.samples[0]);
        assert!(trace.max() > 38.0);
    }

    #[test]
    fn energy_consistent_with_mean() {
        let session = BeamSession::default();
        let m = measurement(20.0);
        let trace = session.trace(&m, 3);
        let e = trace.energy_j();
        assert!((e - trace.mean() * trace.duration_s()).abs() < 1e-9);
        assert!((trace.duration_s() - 60.0).abs() < 1e-9);
    }

    #[test]
    fn execution_trace_integrates_to_steady_energy() {
        let session = BeamSession::default();
        // Long execution: sampled at the session rate.
        let t = session.execution_trace(30.0, 2.0, 42);
        assert!((t.duration_s() - 2.0).abs() < 1e-9);
        assert_eq!(t.samples.len(), 20);
        let e = t.energy_j();
        assert!((e - 60.0).abs() / 60.0 < 0.1, "energy {e} vs ~60 J");
        // Sub-sample-period execution still integrates over >= 8 samples.
        let tiny = session.execution_trace(20.0, 1e-4, 7);
        assert_eq!(tiny.samples.len(), 8);
        assert!((tiny.duration_s() - 1e-4).abs() < 1e-12);
        let e = tiny.energy_j();
        assert!((e - 20.0 * 1e-4).abs() / (20.0 * 1e-4) < 0.2, "tiny energy {e}");
        // Deterministic per design key; degenerate durations don't panic.
        assert_eq!(
            session.execution_trace(30.0, 0.5, 3),
            session.execution_trace(30.0, 0.5, 3)
        );
        assert!(session.execution_trace(30.0, 0.0, 3).energy_j().is_finite());
        assert!(session.execution_trace(30.0, f64::NAN, 3).energy_j().is_finite());
    }

    #[test]
    fn short_trace_falls_back_to_mean() {
        let t = PowerTrace {
            samples: vec![10.0, 12.0],
            period_s: 0.1,
        };
        assert_eq!(t.steady_mean(), t.mean());
        assert_eq!(t.min(), 10.0);
    }
}
