//! Minimal TOML-subset parser for the config system.
//!
//! Supports what our configs need: `[section]` and `[section.sub]`
//! headers, `key = value` with string / integer / float / bool / array
//! values, `#` comments, and blank lines. Keys flatten to dotted paths
//! (`"sim.ddr_peak_gbps"`). No multi-line strings, dates, or table
//! arrays — config files stay within this subset by construction.

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            TomlValue::Int(x) => Some(*x as f64),
            TomlValue::Float(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            TomlValue::Int(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A flat map of dotted keys to values.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, TomlValue>,
}

#[derive(Debug)]
pub struct TomlError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "toml parse error on line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for TomlError {}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc, TomlError> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            let err = |msg: &str| TomlError {
                line: lineno + 1,
                msg: msg.to_string(),
            };
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest.strip_suffix(']').ok_or_else(|| err("unterminated section"))?;
                let name = name.trim();
                if name.is_empty() {
                    return Err(err("empty section name"));
                }
                section = name.to_string();
            } else {
                let eq = line.find('=').ok_or_else(|| err("expected `key = value`"))?;
                let key = line[..eq].trim();
                if key.is_empty() {
                    return Err(err("empty key"));
                }
                let value = parse_value(line[eq + 1..].trim()).map_err(|m| err(&m))?;
                let full = if section.is_empty() {
                    key.to_string()
                } else {
                    format!("{section}.{key}")
                };
                doc.entries.insert(full, value);
            }
        }
        Ok(doc)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.entries.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(TomlValue::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(TomlValue::as_usize).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key)
            .and_then(TomlValue::as_i64)
            .and_then(|x| u64::try_from(x).ok())
            .unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(TomlValue::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(TomlValue::as_bool).unwrap_or(default)
    }
}

fn strip_comment(line: &str) -> &str {
    // `#` starts a comment unless inside a quoted string.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue, String> {
    let text = text.trim();
    if text.is_empty() {
        return Err("empty value".into());
    }
    if let Some(inner) = text.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| "unterminated string".to_string())?;
        return Ok(TomlValue::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if let Some(inner) = text.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| "unterminated array".to_string())?;
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            let part = part.trim();
            if !part.is_empty() {
                items.push(parse_value(part)?);
            }
        }
        return Ok(TomlValue::Array(items));
    }
    match text {
        "true" => return Ok(TomlValue::Bool(true)),
        "false" => return Ok(TomlValue::Bool(false)),
        _ => {}
    }
    let clean = text.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    Err(format!("unrecognized value `{text}`"))
}

/// Split on commas that are not nested inside brackets or strings.
fn split_top_level(text: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0usize;
    let mut in_str = false;
    let mut start = 0usize;
    for (i, c) in text.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                parts.push(&text[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&text[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
# board spec
seed = 42
[board]
name = "vck190"           # device
aie_total = 400
aie_clock_ghz = 1.25
uram_banks = 463
flag = true
dims = [32, 64, 128]
"#,
        )
        .unwrap();
        assert_eq!(doc.u64_or("seed", 0), 42);
        assert_eq!(doc.str_or("board.name", ""), "vck190");
        assert_eq!(doc.usize_or("board.aie_total", 0), 400);
        assert!((doc.f64_or("board.aie_clock_ghz", 0.0) - 1.25).abs() < 1e-12);
        assert!(doc.bool_or("board.flag", false));
        let arr = doc.get("board.dims").unwrap();
        assert_eq!(
            arr,
            &TomlValue::Array(vec![
                TomlValue::Int(32),
                TomlValue::Int(64),
                TomlValue::Int(128)
            ])
        );
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("missing", 7), 7);
        assert_eq!(doc.str_or("missing", "x"), "x");
    }

    #[test]
    fn comments_inside_strings() {
        let doc = TomlDoc::parse("k = \"a#b\"").unwrap();
        assert_eq!(doc.str_or("k", ""), "a#b");
    }

    #[test]
    fn underscored_numbers() {
        let doc = TomlDoc::parse("bw = 25_600_000_000").unwrap();
        assert_eq!(doc.get("bw").unwrap().as_i64(), Some(25_600_000_000));
    }

    #[test]
    fn error_reports_line() {
        let err = TomlDoc::parse("ok = 1\nbroken").unwrap_err();
        assert_eq!(err.line, 2);
    }

    #[test]
    fn nested_arrays() {
        let doc = TomlDoc::parse("x = [[1, 2], [3]]").unwrap();
        match doc.get("x").unwrap() {
            TomlValue::Array(items) => assert_eq!(items.len(), 2),
            _ => panic!("expected array"),
        }
    }
}
