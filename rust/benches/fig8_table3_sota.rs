//! Bench: Fig. 8 + Table III — full framework comparison (CHARM, ARIES,
//! Ours) across G1..G13, end to end.
use versal_gemm::config::Config;
use versal_gemm::report::{figures, render, Lab};
use versal_gemm::util::bench::once;

fn main() -> anyhow::Result<()> {
    let lab = Lab::prepare(Config::default(), "data".into())?;
    let fig8 = once("fig8: CHARM/ARIES/Ours on G1..G13", || {
        figures::fig8_sota_comparison(&lab)
    });
    println!("{fig8}");
    let t3 = once("table3: resource utilization (cached comparisons)", || {
        render(&lab, "table3").unwrap()
    });
    println!("{t3}");
    Ok(())
}
