//! Self-application: the repo must be lint-clean at HEAD.
//!
//! This is the tier-1 enforcement point for the serving-stack
//! invariants — `cargo test` fails if anyone reintroduces a
//! NaN-unsafe ordering, a panic on a serve-critical path, a raw
//! mutex lock, a wire-protocol gap, or an unsurfaced coordinator
//! stat. Fix the finding, waive it in place with
//! `// lint:allow(rule-id) reason`, or (exceptionally) baseline it.

use std::path::Path;

use versal_gemm::lint::{run_at, Baseline};

fn repo_root() -> &'static Path {
    // CARGO_MANIFEST_DIR is the repo root (Cargo.toml lives there and
    // points into rust/src).
    Path::new(env!("CARGO_MANIFEST_DIR"))
}

#[test]
fn repo_is_lint_clean_at_head() {
    let root = repo_root();
    let baseline = Baseline::load(&root.join("lint-baseline.json")).expect("baseline parses");
    let report = run_at(root, &baseline).expect("walk repo");
    assert!(
        report.files_scanned > 30,
        "scan looks wrong: only {} files found under {}",
        report.files_scanned,
        root.display()
    );
    let failing: Vec<String> = report
        .unwaived()
        .map(|f| format!("{}:{} [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        failing.is_empty(),
        "repo is not lint-clean ({} finding(s)):\n  {}",
        failing.len(),
        failing.join("\n  ")
    );
}

#[test]
fn panic_freedom_is_not_baselined_in_server() {
    // The serve path burned down its unwrap debt in this PR; the
    // baseline must not quietly re-absorb it.
    let baseline =
        Baseline::load(&repo_root().join("lint-baseline.json")).expect("baseline parses");
    let offenders: Vec<&str> = baseline
        .entries
        .iter()
        .filter(|e| e.rule == "panic-freedom" && e.file.starts_with("rust/src/server/"))
        .map(|e| e.file.as_str())
        .collect();
    assert!(
        offenders.is_empty(),
        "panic-freedom baselined in server/: {offenders:?}"
    );
}
