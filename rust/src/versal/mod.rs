//! VCK190 simulator — the "board" substrate.
//!
//! The paper's ground truth is 40+ days of on-board measurements
//! (latency via XRT, power via the BEAM tool on the System Controller).
//! This module replaces the board with a cycle-approximate model
//! `(G, tiling) → (latency, power, resources)` calibrated to every
//! number the paper reports, **including the nonlinear interaction
//! effects that analytical models miss** — those effects are precisely
//! what makes the paper's ML-driven DSE outperform analytical DSE, so
//! the substitution preserves the phenomenon under study (DESIGN.md §1).
//!
//! Components:
//! * [`aie`]    — micro-kernel cycles, cascade sync, placement congestion;
//! * [`noc`]    — PL→AIE stream feed and broadcast serialization;
//! * [`ddr`]    — burst-efficiency bandwidth model for tile streaming;
//! * [`pl`]     — BRAM/URAM packing and LUT/FF/DSP allocation;
//! * [`power`]  — component-wise power (static, AIE, PL, NoC, DDR);
//! * [`sim`]    — composition into a [`sim::Measurement`], with
//!   deterministic per-design measurement noise and build-failure gating.

pub mod aie;
pub mod ddr;
pub mod noc;
pub mod pl;
pub mod power;
pub mod reconfig;
pub mod sim;
pub mod telemetry;

pub use pl::{BufferPlacement, Resources, ResourceUtil};
pub use sim::{Measurement, SimError, VersalSim};
