//! Minimal JSON parser/writer (no serde in the offline crate set).
//!
//! Covers the full JSON grammar; used for the AOT `manifest.json`, model
//! persistence, and dataset/report export. Not performance-critical.

use std::collections::BTreeMap;
use std::fmt::Write as _;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl std::fmt::Display for JsonError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 && x <= u64::MAX as f64 {
                Some(x as u64)
            } else {
                None
            }
        })
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Required-field helpers for manifest parsing.
    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("missing string field `{key}`"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| anyhow::anyhow!("missing integer field `{key}`"))
    }

    pub fn req_f64(&self, key: &str) -> anyhow::Result<f64> {
        self.get(key)
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("missing number field `{key}`"))
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push_str("  ");
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, it) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    it.write(out, indent + 1, pretty);
                }
                if !items.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !map.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 1e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience constructors.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
    Json::Arr(items.into_iter().collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected `{}`", b as char)))
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected `,` or `}`")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected `,` or `]`")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    Some(b'r') => out.push('\r'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => out.push(c as char),
                Some(c) => {
                    // Re-decode multi-byte UTF-8 (input is a &str, so valid).
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    self.pos = start + len;
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos]).unwrap());
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": false}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let v = obj(vec![
            ("name", s("micro_32")),
            ("m", num(32.0)),
            ("ok", Json::Bool(true)),
            ("xs", arr([num(1.0), num(2.5)])),
            ("nested", obj(vec![("k", Json::Null)])),
        ]);
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_roundtrip() {
        let v = Json::parse(r#""α→β""#).unwrap();
        assert_eq!(v.as_str(), Some("α→β"));
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }

    #[test]
    fn real_manifest_shape() {
        let text = r#"{"version": 1, "variants": [{"name": "micro_32", "m": 32, "file": "micro_32.hlo.txt"}]}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.req_usize("version").unwrap(), 1);
        let variants = v.get("variants").unwrap().as_arr().unwrap();
        assert_eq!(variants[0].req_str("name").unwrap(), "micro_32");
        assert_eq!(variants[0].req_usize("m").unwrap(), 32);
    }
}
