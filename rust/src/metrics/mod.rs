//! Statistical metrics used across evaluation: MAPE, R², Pearson r,
//! geometric mean, quantiles, and the 2-D hypervolume indicator for
//! Pareto-front quality (Fig. 10).

/// Mean absolute percentage error (%), as in Fig. 7.
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    let mut acc = 0.0;
    for (t, p) in truth.iter().zip(pred) {
        assert!(*t != 0.0, "MAPE undefined for zero truth");
        acc += ((t - p) / t).abs();
    }
    100.0 * acc / truth.len() as f64
}

/// Coefficient of determination R² (Fig. 6).
pub fn r2(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_res: f64 = truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - mean) * (t - mean)).sum();
    if ss_tot == 0.0 {
        if ss_res == 0.0 {
            1.0
        } else {
            f64::NEG_INFINITY
        }
    } else {
        1.0 - ss_res / ss_tot
    }
}

/// Pearson correlation coefficient (paper: r = 0.81 between ρ and latency).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(x.len() > 1);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for (a, b) in x.iter().zip(y) {
        cov += (a - mx) * (b - my);
        vx += (a - mx) * (a - mx);
        vy += (b - my) * (b - my);
    }
    if vx == 0.0 || vy == 0.0 {
        return 0.0;
    }
    cov / (vx * vy).sqrt()
}

/// Geometric mean (the paper's headline aggregation).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let log_sum: f64 = xs
        .iter()
        .map(|x| {
            assert!(*x > 0.0, "geomean requires positive values, got {x}");
            x.ln()
        })
        .sum();
    (log_sum / xs.len() as f64).exp()
}

pub fn mean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Quantile with linear interpolation, `q ∈ [0,1]`. NaN-safe: the sort
/// uses `total_cmp` (NaNs order above +inf instead of panicking the
/// comparator), so an adversarial sample cannot take down a caller —
/// this feeds the serve path's `plan_p50_ms` readout.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut sorted = xs.to_vec();
    sorted.sort_by(f64::total_cmp);
    let pos = q * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// 2-D hypervolume dominated by a maximization Pareto front, with respect
/// to reference point `(0, 0)` after normalizing both axes by `scale`.
/// Points are `(throughput, energy_efficiency)`; larger is better on both
/// axes. This is the indicator behind the paper's "2.18× higher
/// hypervolume area on geomean".
pub fn hypervolume_2d(points: &[(f64, f64)], scale: (f64, f64)) -> f64 {
    // Degenerate reference scales (empty fronts produce 0-maxima, NaN
    // measurements produce NaN scales) yield an empty indicator rather
    // than panicking a report/serve path.
    if points.is_empty() || !(scale.0 > 0.0 && scale.1 > 0.0) || !scale.0.is_finite() || !scale.1.is_finite() {
        return 0.0;
    }
    // Normalize, keep only the non-dominated set, sweep by x descending.
    let norm: Vec<(f64, f64)> = points
        .iter()
        .map(|&(x, y)| (x / scale.0, y / scale.1))
        .collect();
    let front = pareto_front_max(&norm);
    let mut sorted = front;
    sorted.sort_by(|a, b| b.0.total_cmp(&a.0));
    let mut hv = 0.0;
    let mut prev_y = 0.0;
    for (x, y) in sorted {
        if y > prev_y {
            hv += x * (y - prev_y);
            prev_y = y;
        }
    }
    hv
}

/// Non-dominated subset for 2-D maximization. Non-finite points are
/// skipped (a NaN coordinate can neither dominate nor be dominated
/// meaningfully) and duplicate points collapse to one front member, so
/// adversarial inputs cannot panic the sort or loop forever.
pub fn pareto_front_max(points: &[(f64, f64)]) -> Vec<(f64, f64)> {
    let mut idx: Vec<usize> = (0..points.len())
        .filter(|&i| points[i].0.is_finite() && points[i].1.is_finite())
        .collect();
    // Sort by x desc, then y desc; sweep keeping strictly increasing y.
    idx.sort_by(|&a, &b| {
        points[b]
            .0
            .total_cmp(&points[a].0)
            .then(points[b].1.total_cmp(&points[a].1))
    });
    let mut front = Vec::new();
    let mut best_y = f64::NEG_INFINITY;
    let mut prev: Option<(f64, f64)> = None;
    for i in idx {
        let (x, y) = points[i];
        if prev == Some((x, y)) {
            continue; // exact duplicate of the previous kept/seen point
        }
        prev = Some((x, y));
        if y > best_y {
            front.push((x, y));
            best_y = y;
        }
    }
    front
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::forall;

    #[test]
    fn mape_basic() {
        assert_eq!(mape(&[100.0, 200.0], &[110.0, 180.0]), 10.0);
        assert_eq!(mape(&[50.0], &[50.0]), 0.0);
    }

    #[test]
    fn r2_perfect_and_mean_predictor() {
        let t = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(r2(&t, &t), 1.0);
        let mean_pred = [2.5; 4];
        assert!(r2(&t, &mean_pred).abs() < 1e-12);
    }

    #[test]
    fn pearson_signs() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y_pos = [2.0, 4.0, 6.0, 8.0];
        let y_neg = [8.0, 6.0, 4.0, 2.0];
        assert!((pearson(&x, &y_pos) - 1.0).abs() < 1e-12);
        assert!((pearson(&x, &y_neg) + 1.0).abs() < 1e-12);
        let y_const = [3.0; 4];
        assert_eq!(pearson(&x, &y_const), 0.0);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn geomean_rejects_nonpositive() {
        geomean(&[1.0, 0.0]);
    }

    #[test]
    fn quantiles() {
        let xs = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[1.0, 2.0, 9.0]), 2.0);
    }

    #[test]
    fn quantile_and_median_survive_nan_input() {
        // Regression: the old `partial_cmp().unwrap()` comparator
        // panicked on any NaN sample, which could take down the serve
        // path's p50 readout. `total_cmp` orders NaN above +inf, so
        // finite quantiles of a partially-NaN sample stay meaningful.
        let xs = [4.0, f64::NAN, 1.0, 3.0, 2.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(median(&xs), 3.0);
        assert_eq!(median(&[1.0, f64::NAN, 2.0]), 2.0);
        assert_eq!(quantile(&[f64::NAN, 7.0], 0.0), 7.0);
        // All-NaN input degrades to NaN, not a panic.
        assert!(median(&[f64::NAN, f64::NAN]).is_nan());
        // NaN lands in the top tail, so q = 1.0 reads it back.
        assert!(quantile(&xs, 1.0).is_nan());
    }

    #[test]
    fn pareto_front_filters_dominated() {
        let pts = [(1.0, 5.0), (2.0, 4.0), (1.5, 3.0), (3.0, 1.0), (0.5, 0.5)];
        let front = pareto_front_max(&pts);
        assert_eq!(front.len(), 3);
        assert!(front.contains(&(3.0, 1.0)));
        assert!(front.contains(&(2.0, 4.0)));
        assert!(front.contains(&(1.0, 5.0)));
        assert!(!front.contains(&(1.5, 3.0)));
    }

    #[test]
    fn hypervolume_rectangles() {
        // Single point (1,1) normalized: hv = 1.
        assert!((hypervolume_2d(&[(2.0, 3.0)], (2.0, 3.0)) - 1.0).abs() < 1e-12);
        // Two points forming a staircase.
        let hv = hypervolume_2d(&[(1.0, 0.5), (0.5, 1.0)], (1.0, 1.0));
        assert!((hv - 0.75).abs() < 1e-12);
        // Dominated point adds nothing.
        let hv2 = hypervolume_2d(&[(1.0, 0.5), (0.5, 1.0), (0.4, 0.4)], (1.0, 1.0));
        assert!((hv2 - 0.75).abs() < 1e-12);
    }

    #[test]
    fn degenerate_inputs_do_not_panic() {
        // Empty points / zero / NaN scales: 0 indicator, no panic.
        assert_eq!(hypervolume_2d(&[], (1.0, 1.0)), 0.0);
        assert_eq!(hypervolume_2d(&[(1.0, 1.0)], (0.0, 1.0)), 0.0);
        assert_eq!(hypervolume_2d(&[(1.0, 1.0)], (f64::NAN, 1.0)), 0.0);
        // NaN points are skipped, not propagated.
        let front = pareto_front_max(&[(f64::NAN, 2.0), (1.0, f64::NAN), (1.0, 1.0)]);
        assert_eq!(front, vec![(1.0, 1.0)]);
        let hv = hypervolume_2d(&[(f64::NAN, 2.0), (1.0, 1.0)], (1.0, 1.0));
        assert!((hv - 1.0).abs() < 1e-12);
        // Duplicate points collapse to one front member.
        let front = pareto_front_max(&[(2.0, 3.0), (2.0, 3.0), (2.0, 3.0)]);
        assert_eq!(front, vec![(2.0, 3.0)]);
        assert!(pareto_front_max(&[]).is_empty());
    }

    #[test]
    fn property_hypervolume_monotone_under_point_addition() {
        forall(
            0xBEEF,
            60,
            |r| {
                let n = r.range_usize(1, 12);
                let pts: Vec<(f64, f64)> = (0..n)
                    .map(|_| (r.range_f64(0.1, 10.0), r.range_f64(0.1, 10.0)))
                    .collect();
                let extra = (r.range_f64(0.1, 10.0), r.range_f64(0.1, 10.0));
                (pts, extra)
            },
            |(pts, extra)| {
                let scale = (10.0, 10.0);
                let base = hypervolume_2d(pts, scale);
                let mut bigger = pts.clone();
                bigger.push(*extra);
                let after = hypervolume_2d(&bigger, scale);
                assert!(after + 1e-12 >= base, "hv shrank: {base} -> {after}");
            },
        );
    }

    #[test]
    fn property_front_members_not_dominated() {
        forall(
            0xF00D,
            40,
            |r| {
                let n = r.range_usize(2, 30);
                (0..n)
                    .map(|_| (r.range_f64(0.0, 1.0), r.range_f64(0.0, 1.0)))
                    .collect::<Vec<_>>()
            },
            |pts| {
                let front = pareto_front_max(pts);
                for &(fx, fy) in &front {
                    for &(px, py) in pts.iter() {
                        let dominates = px >= fx && py >= fy && (px > fx || py > fy);
                        assert!(!dominates, "({px},{py}) dominates front point ({fx},{fy})");
                    }
                }
            },
        );
    }
}
