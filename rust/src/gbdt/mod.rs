//! From-scratch Gradient Boosted Decision Trees (the paper's model class,
//! §IV-A.3): exact-split regression trees, squared-loss boosting with
//! shrinkage and row/column subsampling, a multi-output wrapper for the
//! resource model, and k-fold CV + hyper-parameter search.

pub mod baselines;
pub mod boost;
pub mod cv;
pub mod multi;
pub mod tree;

pub use boost::Gbdt;
pub use multi::MultiGbdt;
pub use tree::{FeatureMatrix, RegressionTree, TreeParams};
