//! Baseline regressors for the model-choice ablation.
//!
//! The paper picks Gradient Boosted Decision Trees "well-suited for
//! accurate prediction on bounded datasets" [30], [31]. The `ablation`
//! report quantifies that choice by comparing the GBDT against:
//! * ridge regression on standardized (log-)features — the strongest
//!   *linear* alternative;
//! * k-nearest-neighbours in standardized feature space — the strongest
//!   *memorizing* alternative (interpolates known workloads well,
//!   extrapolates to unseen ones poorly).

use crate::gbdt::tree::FeatureMatrix;

/// Column-wise standardization parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct Scaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Scaler {
    pub fn fit(x: &FeatureMatrix) -> Scaler {
        let n = x.n_rows as f64;
        let mut mean = vec![0.0; x.n_cols];
        for i in 0..x.n_rows {
            for (j, v) in x.row(i).iter().enumerate() {
                mean[j] += v;
            }
        }
        for m in &mut mean {
            *m /= n;
        }
        let mut std = vec![0.0; x.n_cols];
        for i in 0..x.n_rows {
            for (j, v) in x.row(i).iter().enumerate() {
                std[j] += (v - mean[j]) * (v - mean[j]);
            }
        }
        for s in &mut std {
            *s = (*s / n).sqrt().max(1e-12);
        }
        Scaler { mean, std }
    }

    pub fn transform_row(&self, row: &[f64], out: &mut Vec<f64>) {
        out.clear();
        for (j, v) in row.iter().enumerate() {
            out.push((v - self.mean[j]) / self.std[j]);
        }
    }
}

/// Ridge regression fit by solving the regularized normal equations with
/// Cholesky decomposition (the feature count is tiny: 9 or 17).
#[derive(Debug, Clone, PartialEq)]
pub struct Ridge {
    pub scaler: Scaler,
    pub weights: Vec<f64>,
    pub bias: f64,
}

impl Ridge {
    pub fn fit(x: &FeatureMatrix, y: &[f64], lambda: f64) -> Ridge {
        assert_eq!(x.n_rows, y.len());
        let scaler = Scaler::fit(x);
        let d = x.n_cols;
        let y_mean = y.iter().sum::<f64>() / y.len() as f64;

        // Gram matrix and rhs over standardized, centred data.
        let mut gram = vec![0.0; d * d];
        let mut rhs = vec![0.0; d];
        let mut z = Vec::with_capacity(d);
        for i in 0..x.n_rows {
            scaler.transform_row(x.row(i), &mut z);
            let yc = y[i] - y_mean;
            for a in 0..d {
                rhs[a] += z[a] * yc;
                for b in a..d {
                    gram[a * d + b] += z[a] * z[b];
                }
            }
        }
        for a in 0..d {
            for b in 0..a {
                gram[a * d + b] = gram[b * d + a];
            }
            gram[a * d + a] += lambda;
        }
        let weights = cholesky_solve(&gram, &rhs, d);
        Ridge {
            scaler,
            weights,
            bias: y_mean,
        }
    }

    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut z = Vec::with_capacity(row.len());
        self.scaler.transform_row(row, &mut z);
        self.predict_scaled(&z)
    }

    /// Batched evaluation sharing one standardization scratch buffer
    /// (the per-row entry allocates per call) — the ablation report's
    /// counterpart to the GBDT forest batch path.
    pub fn predict_batch(&self, x: &FeatureMatrix) -> Vec<f64> {
        let mut z = Vec::with_capacity(x.n_cols);
        (0..x.n_rows)
            .map(|i| {
                self.scaler.transform_row(x.row(i), &mut z);
                self.predict_scaled(&z)
            })
            .collect()
    }

    #[inline]
    fn predict_scaled(&self, z: &[f64]) -> f64 {
        self.bias + z.iter().zip(&self.weights).map(|(a, b)| a * b).sum::<f64>()
    }
}

/// Solve `A w = b` for symmetric positive-definite `A` (row-major d x d).
fn cholesky_solve(a: &[f64], b: &[f64], d: usize) -> Vec<f64> {
    // L L^T = A.
    let mut l = vec![0.0; d * d];
    for i in 0..d {
        for j in 0..=i {
            let mut sum = a[i * d + j];
            for k in 0..j {
                sum -= l[i * d + k] * l[j * d + k];
            }
            if i == j {
                l[i * d + i] = sum.max(1e-12).sqrt();
            } else {
                l[i * d + j] = sum / l[j * d + j];
            }
        }
    }
    // Forward then backward substitution.
    let mut y = vec![0.0; d];
    for i in 0..d {
        let mut sum = b[i];
        for k in 0..i {
            sum -= l[i * d + k] * y[k];
        }
        y[i] = sum / l[i * d + i];
    }
    let mut w = vec![0.0; d];
    for i in (0..d).rev() {
        let mut sum = y[i];
        for k in i + 1..d {
            sum -= l[k * d + i] * w[k];
        }
        w[i] = sum / l[i * d + i];
    }
    w
}

/// Brute-force k-NN regressor in standardized feature space.
#[derive(Debug, Clone)]
pub struct Knn {
    pub scaler: Scaler,
    pub k: usize,
    points: Vec<Vec<f64>>,
    targets: Vec<f64>,
}

impl Knn {
    pub fn fit(x: &FeatureMatrix, y: &[f64], k: usize) -> Knn {
        assert_eq!(x.n_rows, y.len());
        let scaler = Scaler::fit(x);
        let mut points = Vec::with_capacity(x.n_rows);
        let mut z = Vec::new();
        for i in 0..x.n_rows {
            scaler.transform_row(x.row(i), &mut z);
            points.push(z.clone());
        }
        Knn {
            scaler,
            k: k.max(1),
            points,
            targets: y.to_vec(),
        }
    }

    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut z = Vec::with_capacity(row.len());
        let mut best: Vec<(f64, f64)> = Vec::with_capacity(self.k + 1);
        self.predict_scratch(row, &mut z, &mut best)
    }

    /// Batched evaluation reusing the standardization and k-best
    /// scratch buffers across rows.
    pub fn predict_batch(&self, x: &FeatureMatrix) -> Vec<f64> {
        let mut z = Vec::with_capacity(x.n_cols);
        let mut best: Vec<(f64, f64)> = Vec::with_capacity(self.k + 1);
        (0..x.n_rows)
            .map(|i| self.predict_scratch(x.row(i), &mut z, &mut best))
            .collect()
    }

    fn predict_scratch(&self, row: &[f64], z: &mut Vec<f64>, best: &mut Vec<(f64, f64)>) -> f64 {
        self.scaler.transform_row(row, z);
        // Partial selection of the k smallest distances. NaN distances
        // (NaN features in the query or training rows) are skipped
        // outright: sorted last they could still enter during the fill
        // phase and then block every later replacement (`d2 < NaN` is
        // always false), silently corrupting the neighbor set.
        best.clear();
        for (p, &t) in self.points.iter().zip(&self.targets) {
            let d2: f64 = p.iter().zip(z.iter()).map(|(a, b)| (a - b) * (a - b)).sum();
            if d2.is_nan() {
                continue;
            }
            if best.len() < self.k {
                best.push((d2, t));
                best.sort_by(|a, b| a.0.total_cmp(&b.0));
            } else if d2 < best[self.k - 1].0 {
                best[self.k - 1] = (d2, t);
                best.sort_by(|a, b| a.0.total_cmp(&b.0));
            }
        }
        best.iter().map(|(_, t)| t).sum::<f64>() / best.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::r2;
    use crate::util::rng::Rng;

    fn linear_data(n: usize, seed: u64) -> (FeatureMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(0.0, 10.0);
            let b = rng.range_f64(0.0, 10.0);
            rows.push(vec![a, b]);
            y.push(3.0 * a - 2.0 * b + 5.0);
        }
        (FeatureMatrix::from_rows(&rows), y)
    }

    #[test]
    fn ridge_recovers_linear_function() {
        let (x, y) = linear_data(200, 1);
        let model = Ridge::fit(&x, &y, 1e-6);
        let (xt, yt) = linear_data(50, 2);
        let pred: Vec<f64> = (0..xt.n_rows).map(|i| model.predict_one(xt.row(i))).collect();
        assert!(r2(&yt, &pred) > 0.999);
    }

    #[test]
    fn ridge_regularization_shrinks_weights() {
        let (x, y) = linear_data(100, 3);
        let loose = Ridge::fit(&x, &y, 1e-6);
        let tight = Ridge::fit(&x, &y, 1e4);
        let norm = |w: &[f64]| w.iter().map(|v| v * v).sum::<f64>();
        assert!(norm(&tight.weights) < norm(&loose.weights));
    }

    #[test]
    fn cholesky_solves_known_system() {
        // A = [[4,2],[2,3]], b = [10, 8] -> w = [1.75, 1.5]
        let a = vec![4.0, 2.0, 2.0, 3.0];
        let b = vec![10.0, 8.0];
        let w = cholesky_solve(&a, &b, 2);
        assert!((w[0] - 1.75).abs() < 1e-9);
        assert!((w[1] - 1.5).abs() < 1e-9);
    }

    #[test]
    fn knn_interpolates_but_needs_neighbours() {
        let (x, y) = linear_data(400, 4);
        let model = Knn::fit(&x, &y, 5);
        // In-distribution: good.
        let (xt, yt) = linear_data(50, 5);
        let pred: Vec<f64> = (0..xt.n_rows).map(|i| model.predict_one(xt.row(i))).collect();
        assert!(r2(&yt, &pred) > 0.95);
        // Far out of distribution: poor (memorizer, not extrapolator).
        let far = model.predict_one(&[100.0, 100.0]);
        let truth = 3.0 * 100.0 - 2.0 * 100.0 + 5.0;
        assert!((far - truth).abs() > 20.0);
    }

    #[test]
    fn batch_paths_match_per_row() {
        let (x, y) = linear_data(120, 7);
        let ridge = Ridge::fit(&x, &y, 1e-3);
        let knn = Knn::fit(&x, &y, 3);
        let rb = ridge.predict_batch(&x);
        let kb = knn.predict_batch(&x);
        for i in 0..x.n_rows {
            assert_eq!(rb[i], ridge.predict_one(x.row(i)));
            assert_eq!(kb[i], knn.predict_one(x.row(i)));
        }
    }

    #[test]
    fn scaler_standardizes() {
        let (x, _) = linear_data(500, 6);
        let s = Scaler::fit(&x);
        let mut z = Vec::new();
        let mut sums = vec![0.0; x.n_cols];
        for i in 0..x.n_rows {
            s.transform_row(x.row(i), &mut z);
            for (j, v) in z.iter().enumerate() {
                sums[j] += v;
            }
        }
        for v in sums {
            assert!((v / x.n_rows as f64).abs() < 1e-9);
        }
    }
}
