//! Tiny CLI argument parser (no clap in the offline crate set).
//!
//! Grammar: `versal-gemm <subcommand> [--flag] [--key value] [positional...]`.
//! Flags may also be written `--key=value`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Args {
        let mut args = Args::default();
        let mut iter = raw.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(name) = tok.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    args.options.insert(name.to_string(), v);
                } else {
                    args.flags.push(name.to_string());
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        args
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn opt_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.opt(name).unwrap_or(default)
    }

    pub fn opt_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn opt_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got `{v}`")),
        }
    }

    pub fn opt_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got `{v}`")),
        }
    }

    /// Parse `MxNxK` GEMM dims, e.g. `--gemm 512x2048x2048`.
    pub fn opt_gemm_dims(&self, name: &str) -> anyhow::Result<Option<(usize, usize, usize)>> {
        match self.opt(name) {
            None => Ok(None),
            Some(v) => {
                let parts: Vec<&str> = v.split('x').collect();
                if parts.len() != 3 {
                    anyhow::bail!("--{name} expects MxNxK, got `{v}`");
                }
                let m = parts[0].parse()?;
                let n = parts[1].parse()?;
                let k = parts[2].parse()?;
                Ok(Some((m, n, k)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(tokens: &[&str]) -> Args {
        Args::parse(tokens.iter().map(|s| s.to_string()))
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse(&["dse", "pos1", "pos2"]);
        assert_eq!(a.subcommand.as_deref(), Some("dse"));
        assert_eq!(a.positional, vec!["pos1", "pos2"]);
    }

    #[test]
    fn options_both_styles() {
        let a = parse(&["train", "--seed", "7", "--out=models.json"]);
        assert_eq!(a.opt("seed"), Some("7"));
        assert_eq!(a.opt("out"), Some("models.json"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn bare_flags() {
        let a = parse(&["report", "fig8", "--verbose"]);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        assert_eq!(a.positional, vec!["fig8"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["x", "--a", "--b", "v"]);
        assert!(a.flag("a"));
        assert_eq!(a.opt("b"), Some("v"));
    }

    #[test]
    fn gemm_dims() {
        let a = parse(&["dse", "--gemm", "512x2048x1024"]);
        assert_eq!(a.opt_gemm_dims("gemm").unwrap(), Some((512, 2048, 1024)));
        let bad = parse(&["dse", "--gemm", "512x2048"]);
        assert!(bad.opt_gemm_dims("gemm").is_err());
    }

    #[test]
    fn bad_numeric_is_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.opt_usize("n", 1).is_err());
    }
}
