//! Bench: Fig. 1 exhaustive tiling sweep — simulator evaluation
//! throughput over the full candidate space of one medium GEMM.
use versal_gemm::config::Config;
use versal_gemm::dse::ExhaustiveExplorer;
use versal_gemm::report::{figures, Lab};
use versal_gemm::util::bench::{bench, once, report_throughput};
use versal_gemm::versal::VersalSim;
use versal_gemm::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let g = Gemm::new(224, 3072, 768);
    let ex = ExhaustiveExplorer::new(VersalSim::new(&cfg));
    let n = ex.explore(&g).len();
    println!("== bench: Fig. 1 exhaustive sweep ({n} buildable designs) ==");
    let stats = bench(1, 5, || {
        std::hint::black_box(ex.explore(&g).len());
    });
    report_throughput("exhaustive sweep (enumerate+simulate)", &stats, n as f64, "designs");
    let lab = Lab::prepare(cfg, "data".into())?;
    let fig = once("render fig1", || figures::fig1_tiling_impact(&lab));
    println!("{fig}");
    Ok(())
}
