//! Integration: the PJRT runtime over the real AOT artifacts.
//!
//! Requires the AOT artifacts (python/compile/aot.py) and a real PJRT
//! runtime; without them every test here skips with a notice.
//! These tests exercise the L1→L2→L3 composition for real: Pallas
//! kernels lowered to HLO text, compiled on the PJRT CPU client, and
//! driven by the Rust tiled executor and the serving coordinator.

use std::path::Path;

use versal_gemm::config::Config;
use versal_gemm::coordinator::{Coordinator, GemmJob};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::Objective;
use versal_gemm::dse::DseEngine;
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::runtime::{matmul_ref, max_abs_diff, GemmEngine};
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::{training_workloads, Gemm};

/// The AOT artifacts and a linked PJRT runtime are optional in the
/// offline environment: when either is missing these integration tests
/// skip (the always-available CPU execution backend is covered by
/// `backend_equivalence`, plan coordination by `coordinator_props`).
fn engine() -> Option<GemmEngine> {
    let p = Path::new("artifacts");
    if !p.join("manifest.json").exists() {
        eprintln!("skipping PJRT test: artifacts/manifest.json missing (run `make artifacts`)");
        return None;
    }
    match GemmEngine::load(p) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("skipping PJRT test: engine unavailable ({err})");
            None
        }
    }
}

#[test]
fn engine_loads_all_variants() {
    let Some(engine) = engine() else { return };
    assert_eq!(engine.platform(), "cpu");
    assert!(engine.manifest.variants.len() >= 5);
    for name in ["micro_32", "tile_64", "tile_128", "tile_32x128x128", "tile_128_fused"] {
        assert!(engine.variant_index(name).is_some(), "missing variant {name}");
    }
}

#[test]
fn micro_kernel_matches_reference() {
    let Some(engine) = engine() else { return };
    let idx = engine.variant_index("micro_32").unwrap();
    let mut rng = Rng::new(1);
    let a: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..32 * 32).map(|_| rng.normal() as f32).collect();
    let got = engine.execute_variant(idx, &a, &b).unwrap();
    let want = matmul_ref(&a, &b, 32, 32, 32);
    assert!(max_abs_diff(&got, &want) < 1e-4);
}

#[test]
fn fused_variant_matches_blocked_variant() {
    let Some(engine) = engine() else { return };
    let blocked = engine.variant_index("tile_128").unwrap();
    let fused = engine.variant_index("tile_128_fused").unwrap();
    let mut rng = Rng::new(2);
    let a: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let b: Vec<f32> = (0..128 * 128).map(|_| rng.normal() as f32).collect();
    let x = engine.execute_variant(blocked, &a, &b).unwrap();
    let y = engine.execute_variant(fused, &a, &b).unwrap();
    assert!(max_abs_diff(&x, &y) < 1e-3);
}

#[test]
fn tiled_executor_handles_unaligned_shapes() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::new(3);
    for (m, n, k) in [(32, 32, 32), (96, 64, 160), (70, 50, 90), (197, 128, 64), (1, 33, 7)] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = engine.gemm(&a, &b, m, n, k).unwrap();
        let want = matmul_ref(&a, &b, m, n, k);
        let err = max_abs_diff(&got, &want);
        assert!(err < 1e-3, "{m}x{n}x{k}: err {err}");
    }
}

#[test]
fn executor_rejects_bad_shapes() {
    let Some(engine) = engine() else { return };
    let a = vec![0f32; 10];
    let b = vec![0f32; 10];
    assert!(engine.gemm(&a, &b, 4, 4, 4).is_err());
    let idx = engine.variant_index("micro_32").unwrap();
    assert!(engine.execute_variant(idx, &a, &b).is_err());
}

#[test]
fn coordinator_executes_and_validates_end_to_end() {
    if engine().is_none() {
        return;
    }
    let cfg = {
        let mut c = Config::default();
        c.dataset.top_k = 8;
        c.dataset.bottom_k = 6;
        c.dataset.random_k = 20;
        c.train.n_trees = 50;
        c.train.learning_rate = 0.2;
        c
    };
    let wl: Vec<_> = training_workloads().into_iter().take(3).collect();
    let ds = Dataset::generate(&cfg, &wl);
    let engine = DseEngine::new(Predictors::train(&ds, &cfg, FeatureSet::SetIAndII), &cfg.board);
    let mut coord = Coordinator::start(&cfg, engine, Some("artifacts".into()), 2);

    let mut rng = Rng::new(9);
    let jobs: Vec<GemmJob> = (0..4u64)
        .map(|i| {
            let g = Gemm::new(64, 128 * (1 + i as usize % 2), 96);
            let a: Vec<f32> = (0..g.m * g.k).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..g.k * g.n).map(|_| rng.normal() as f32).collect();
            let mut j = GemmJob::with_data(i, g, Objective::Throughput, a, b);
            j.validate = true;
            j
        })
        .collect();
    let results = coord.run_batch(jobs);
    assert_eq!(results.len(), 4);
    for r in results {
        assert!(r.error.is_none(), "job {} error {:?}", r.id, r.error);
        assert!(r.exec_time.is_some());
        let err = r.validation_err.expect("validated");
        assert!(err < 1e-3, "job {} numerics {err}", r.id);
        assert!(r.plan.is_some());
    }
    let stats = coord.stats();
    assert_eq!(stats.executed_jobs, 4);
    assert!(stats.executed_gflops() > 0.0);
}
