//! `pallas-lint` — project-native static analysis for the serving stack.
//!
//! PRs 2, 3, and 5 each re-fixed the same bug classes by hand (NaN-unsafe
//! `partial_cmp` orderings, panics on serve-critical paths, raw mutex
//! locking), and PRs 4–7 were verified with an ad-hoc delimiter-lexer scan.
//! This module formalizes that scan into a first-class subsystem: a
//! token-level lexer ([`lexer`]), a [`Rule`] engine with project-specific
//! invariant checks ([`rules`]), and table/JSON reporting ([`report`]).
//! `cargo run -- lint` runs it over the repo; the `lint_clean` integration
//! test asserts the repo itself is clean at HEAD.
//!
//! ## Waivers
//!
//! A finding can be waived in place with a plain (non-doc) comment on the
//! finding's line or the line directly above it:
//!
//! ```text
//! // lint:allow(stats-parity) non-numeric; carried in the backend label
//! ```
//!
//! The rule id must name a real rule and a reason is mandatory — a
//! malformed, unknown, or reasonless waiver is itself reported (rule
//! `waiver-syntax`, which cannot be waived). Doc comments (`///`, `//!`)
//! are never parsed for waivers, so rule documentation can show the syntax
//! freely.
//!
//! ## Baseline
//!
//! `lint-baseline.json` at the repo root carries `{file, rule, count}`
//! entries that tolerate pre-existing findings during incremental adoption.
//! It ships empty: new findings must be fixed or waived, not baselined
//! (the file exists so a future large-scale rule landing has a ratchet).

pub mod lexer;
pub mod report;
pub mod rules;

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::util::json::Json;
use lexer::{lex, Tok, TokKind};

/// Engine-level pseudo-rule for malformed/unknown/reasonless waivers.
pub const WAIVER_SYNTAX: &str = "waiver-syntax";

/// An inline `lint:allow` annotation parsed from a plain comment.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// 1-based line of the comment (its first line, for block comments).
    pub line: u32,
    /// Rule ids listed inside the parentheses.
    pub rules: Vec<String>,
    /// Free-text justification after the closing parenthesis.
    pub reason: String,
    /// False when the `(rule, ...)` list never closed.
    pub well_formed: bool,
}

/// One lexed source file plus the derived facts every rule needs:
/// the non-comment token stream, `#[cfg(test)]`/`#[test]` byte spans,
/// and inline waivers.
#[derive(Debug)]
pub struct SourceFile {
    /// Path relative to the repo root, forward slashes.
    pub rel: String,
    pub text: String,
    pub toks: Vec<Tok>,
    /// Indices into `toks` of non-comment tokens (the structural stream
    /// rules do pattern matching over).
    pub code: Vec<usize>,
    /// Byte spans of test-only items (attribute start to item end).
    pub test_spans: Vec<(usize, usize)>,
    pub waivers: Vec<Waiver>,
}

impl SourceFile {
    pub fn new(rel: &str, text: &str) -> SourceFile {
        let toks = lex(text);
        let code: Vec<usize> = toks
            .iter()
            .enumerate()
            .filter(|(_, t)| t.kind != TokKind::Comment)
            .map(|(i, _)| i)
            .collect();
        let test_spans = find_test_spans(text, &toks, &code);
        let waivers = find_waivers(text, &toks);
        SourceFile {
            rel: rel.to_string(),
            text: text.to_string(),
            toks,
            code,
            test_spans,
            waivers,
        }
    }

    /// Number of non-comment tokens.
    pub fn n_code(&self) -> usize {
        self.code.len()
    }

    /// The `ci`-th non-comment token.
    pub fn ctok(&self, ci: usize) -> &Tok {
        &self.toks[self.code[ci]]
    }

    /// Text of the `ci`-th non-comment token.
    pub fn ctext(&self, ci: usize) -> &str {
        self.ctok(ci).text(&self.text)
    }

    /// True when the `ci`-th code token is the identifier `word`.
    pub fn is_ident(&self, ci: usize, word: &str) -> bool {
        ci < self.n_code()
            && self.ctok(ci).kind == TokKind::Ident
            && self.ctext(ci) == word
    }

    /// True when byte offset `pos` falls inside a test-only item.
    pub fn in_test(&self, pos: usize) -> bool {
        self.test_spans.iter().any(|&(s, e)| pos >= s && pos < e)
    }

    /// Code index of the delimiter matching the opener at `open_ci`
    /// (`(`/`[`/`{`). Returns `None` on unbalanced input.
    pub fn matching(&self, open_ci: usize) -> Option<usize> {
        let (open, close) = match self.ctok(open_ci).kind {
            TokKind::Punct(b'(') => (b'(', b')'),
            TokKind::Punct(b'[') => (b'[', b']'),
            TokKind::Punct(b'{') => (b'{', b'}'),
            _ => return None,
        };
        let mut depth = 0i64;
        for ci in open_ci..self.n_code() {
            match self.ctok(ci).kind {
                TokKind::Punct(b) if b == open => depth += 1,
                TokKind::Punct(b) if b == close => {
                    depth -= 1;
                    if depth == 0 {
                        return Some(ci);
                    }
                }
                _ => {}
            }
        }
        None
    }
}

/// Byte spans of items guarded by a test attribute: `#[test]`,
/// `#[cfg(test)]`, `#[cfg(all(test, ...))]`. An attribute mentioning
/// `not` (e.g. `#[cfg(not(test))]`) is treated as non-test. Coarse but
/// exact for this repo's usage, and errs toward *checking* code.
fn find_test_spans(src: &str, toks: &[Tok], code: &[usize]) -> Vec<(usize, usize)> {
    let n = code.len();
    let tok = |ci: usize| -> &Tok { &toks[code[ci]] };
    let text = |ci: usize| -> &str { tok(ci).text(src) };

    // Scan one attribute starting at `ci` (which must be `#`); returns
    // (code index past the closing `]`, attribute mentions test, mentions not).
    let scan_attr = |ci: usize| -> Option<(usize, bool, bool)> {
        if !tok(ci).is_punct(b'#') || ci + 1 >= n || !tok(ci + 1).is_punct(b'[') {
            return None;
        }
        let mut depth = 0i64;
        let mut has_test = false;
        let mut has_not = false;
        let mut j = ci + 1;
        while j < n {
            match tok(j).kind {
                TokKind::Punct(b'[') | TokKind::Punct(b'(') => depth += 1,
                TokKind::Punct(b']') | TokKind::Punct(b')') => {
                    depth -= 1;
                    if depth == 0 {
                        return Some((j + 1, has_test, has_not));
                    }
                }
                TokKind::Ident => match text(j) {
                    "test" => has_test = true,
                    "not" => has_not = true,
                    _ => {}
                },
                _ => {}
            }
            j += 1;
        }
        Some((n, has_test, has_not))
    };

    let mut spans = Vec::new();
    let mut ci = 0usize;
    while ci < n {
        let Some((mut after, has_test, has_not)) = scan_attr(ci) else {
            ci += 1;
            continue;
        };
        if !has_test || has_not {
            ci = after;
            continue;
        }
        let span_start = tok(ci).start;
        // Skip any further attributes stacked on the same item.
        while let Some((next, _, _)) = scan_attr(after) {
            after = next;
        }
        // Find the item end: first `;` at delimiter depth 0, or the brace
        // block matching the first `{` at depth 0.
        let mut depth = 0i64;
        let mut j = after;
        let mut end = src.len();
        while j < n {
            match tok(j).kind {
                TokKind::Punct(b'(') | TokKind::Punct(b'[') => depth += 1,
                TokKind::Punct(b')') | TokKind::Punct(b']') => depth -= 1,
                TokKind::Punct(b';') if depth <= 0 => {
                    end = tok(j).end;
                    break;
                }
                TokKind::Punct(b'{') if depth <= 0 => {
                    let mut braces = 0i64;
                    while j < n {
                        match tok(j).kind {
                            TokKind::Punct(b'{') => braces += 1,
                            TokKind::Punct(b'}') => {
                                braces -= 1;
                                if braces == 0 {
                                    break;
                                }
                            }
                            _ => {}
                        }
                        j += 1;
                    }
                    end = if j < n { tok(j).end } else { src.len() };
                    break;
                }
                _ => {}
            }
            j += 1;
        }
        spans.push((span_start, end));
        ci = after;
    }
    spans
}

/// Parse `lint:allow(rule, ...) reason` waivers out of plain comments.
/// Doc comments are skipped so documentation can quote the syntax.
fn find_waivers(src: &str, toks: &[Tok]) -> Vec<Waiver> {
    let mut out = Vec::new();
    for t in toks.iter().filter(|t| t.kind == TokKind::Comment) {
        let text = t.text(src);
        if text.starts_with("///")
            || text.starts_with("//!")
            || text.starts_with("/**")
            || text.starts_with("/*!")
        {
            continue;
        }
        let Some(pos) = text.find("lint:allow") else {
            continue;
        };
        let rest = &text[pos + "lint:allow".len()..];
        let close = rest.find(')');
        let well_formed = rest.starts_with('(') && close.is_some();
        let (rules, reason) = match (well_formed, close) {
            (true, Some(c)) => {
                let ids: Vec<String> = rest[1..c]
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                let mut reason = rest[c + 1..].trim();
                // Block comments: drop the trailing `*/` from the reason.
                if let Some(stripped) = reason.strip_suffix("*/") {
                    reason = stripped.trim();
                }
                (ids, reason.to_string())
            }
            _ => (Vec::new(), String::new()),
        };
        out.push(Waiver {
            line: t.line,
            rules,
            reason,
            well_formed,
        });
    }
    out
}

/// The scanned source set a lint run operates on.
#[derive(Debug)]
pub struct Repo {
    pub root: PathBuf,
    pub files: Vec<SourceFile>,
}

/// Directories scanned relative to the repo root. `rust/vendor` is
/// deliberately absent: vendored shims follow upstream style.
const SCAN_ROOTS: [&str; 4] = ["rust/src", "rust/benches", "rust/tests", "examples"];

impl Repo {
    /// Walk the standard source roots under `root` and lex every `.rs`
    /// file. Deterministic order (sorted by relative path).
    pub fn load(root: &Path) -> std::io::Result<Repo> {
        let mut files = Vec::new();
        for top in SCAN_ROOTS {
            let dir = root.join(top);
            if dir.is_dir() {
                walk(&dir, root, &mut files)?;
            }
        }
        files.sort_by(|a, b| a.rel.cmp(&b.rel));
        Ok(Repo {
            root: root.to_path_buf(),
            files,
        })
    }

    /// Build a repo from in-memory `(relative-path, source)` pairs —
    /// the fixture entry point rule tests use.
    pub fn from_sources(sources: &[(&str, &str)]) -> Repo {
        Repo {
            root: PathBuf::new(),
            files: sources
                .iter()
                .map(|(rel, text)| SourceFile::new(rel, text))
                .collect(),
        }
    }

    /// The file whose relative path ends with `suffix`, if any.
    pub fn file_ending(&self, suffix: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.rel.ends_with(suffix))
    }
}

fn walk(dir: &Path, root: &Path, out: &mut Vec<SourceFile>) -> std::io::Result<()> {
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .collect();
    entries.sort();
    for path in entries {
        let name = path
            .file_name()
            .map(|n| n.to_string_lossy().to_string())
            .unwrap_or_default();
        if name.starts_with('.') || name == "vendor" || name == "target" {
            continue;
        }
        if path.is_dir() {
            walk(&path, root, out)?;
        } else if name.ends_with(".rs") {
            let text = std::fs::read_to_string(&path)?;
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .to_string_lossy()
                .replace('\\', "/");
            out.push(SourceFile::new(&rel, &text));
        }
    }
    Ok(())
}

/// One reported violation, anchored at `file:line`.
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: u32,
    pub message: String,
    /// Suppressed by an inline `lint:allow` on this or the previous line.
    pub waived: bool,
    /// Absorbed by a `lint-baseline.json` allowance.
    pub baselined: bool,
}

/// A project-invariant check over the whole scanned repo.
pub trait Rule {
    /// Stable kebab-case id used in waivers, the baseline, and reports.
    fn id(&self) -> &'static str;
    /// One-line description for the rule table.
    fn describe(&self) -> &'static str;
    fn check(&self, repo: &Repo, out: &mut Vec<Finding>);
}

/// A `{file, rule, count}` allowance from `lint-baseline.json`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BaselineEntry {
    pub file: String,
    pub rule: String,
    pub count: usize,
}

/// Checked-in allowances for pre-existing findings. Ships empty; see
/// the module docs for the ratchet policy.
#[derive(Debug, Clone, Default)]
pub struct Baseline {
    pub entries: Vec<BaselineEntry>,
}

impl Baseline {
    pub fn empty() -> Baseline {
        Baseline::default()
    }

    /// Load from `path`; a missing file is an empty baseline.
    pub fn load(path: &Path) -> anyhow::Result<Baseline> {
        if !path.exists() {
            return Ok(Baseline::empty());
        }
        let text = std::fs::read_to_string(path)?;
        Baseline::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Baseline> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("baseline: {e}"))?;
        let mut entries = Vec::new();
        let items = v
            .get("entries")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("baseline: missing `entries` array"))?;
        for it in items {
            entries.push(BaselineEntry {
                file: it.req_str("file")?.to_string(),
                rule: it.req_str("rule")?.to_string(),
                count: it.req_usize("count")?,
            });
        }
        Ok(Baseline { entries })
    }
}

/// The outcome of one lint run: every finding (flags set), plus the rule
/// table and scan size for reporting.
#[derive(Debug)]
pub struct LintReport {
    pub findings: Vec<Finding>,
    pub files_scanned: usize,
    pub rules: Vec<(&'static str, &'static str)>,
}

impl LintReport {
    /// Findings that are neither waived nor baselined — the set that
    /// fails the build.
    pub fn unwaived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| !f.waived && !f.baselined)
    }

    pub fn count_unwaived(&self) -> usize {
        self.unwaived().count()
    }

    pub fn count_waived(&self) -> usize {
        self.findings.iter().filter(|f| f.waived).count()
    }

    pub fn count_baselined(&self) -> usize {
        self.findings.iter().filter(|f| f.baselined).count()
    }
}

/// Run every rule over `repo`, then apply waivers and the baseline.
pub fn run(repo: &Repo, baseline: &Baseline) -> LintReport {
    let rules = rules::all_rules();
    let mut findings = Vec::new();
    for r in &rules {
        r.check(repo, &mut findings);
    }

    // Engine-level waiver validation: a waiver that cannot take effect
    // must be loud, not silently useless.
    let known: BTreeSet<&str> = rules.iter().map(|r| r.id()).collect();
    for f in &repo.files {
        for w in &f.waivers {
            if !w.well_formed {
                findings.push(Finding {
                    rule: WAIVER_SYNTAX,
                    file: f.rel.clone(),
                    line: w.line,
                    message: "malformed waiver — expected `lint:allow(rule-id) reason`"
                        .to_string(),
                    waived: false,
                    baselined: false,
                });
                continue;
            }
            for id in &w.rules {
                if !known.contains(id.as_str()) {
                    findings.push(Finding {
                        rule: WAIVER_SYNTAX,
                        file: f.rel.clone(),
                        line: w.line,
                        message: format!("waiver names unknown rule `{id}`"),
                        waived: false,
                        baselined: false,
                    });
                }
            }
            if w.reason.is_empty() {
                findings.push(Finding {
                    rule: WAIVER_SYNTAX,
                    file: f.rel.clone(),
                    line: w.line,
                    message: "waiver has no reason — say why the finding is acceptable"
                        .to_string(),
                    waived: false,
                    baselined: false,
                });
            }
        }
    }

    findings.sort_by(|a, b| {
        a.file
            .cmp(&b.file)
            .then(a.line.cmp(&b.line))
            .then(a.rule.cmp(b.rule))
            .then(a.message.cmp(&b.message))
    });
    findings.dedup_by(|a, b| a.file == b.file && a.line == b.line && a.rule == b.rule);

    // Waivers: same line or the line directly above. `waiver-syntax`
    // findings cannot be waived.
    let by_rel: BTreeMap<&str, &SourceFile> =
        repo.files.iter().map(|f| (f.rel.as_str(), f)).collect();
    for f in &mut findings {
        if f.rule == WAIVER_SYNTAX {
            continue;
        }
        if let Some(sf) = by_rel.get(f.file.as_str()) {
            f.waived = sf.waivers.iter().any(|w| {
                w.well_formed
                    && !w.reason.is_empty()
                    && w.rules.iter().any(|r| r == f.rule)
                    && (w.line == f.line || w.line + 1 == f.line)
            });
        }
    }

    // Baseline: each `{file, rule, count}` entry absorbs up to `count`
    // unwaived findings of that rule in that file.
    let mut allow: BTreeMap<(&str, &str), usize> = BTreeMap::new();
    for e in &baseline.entries {
        *allow.entry((e.file.as_str(), e.rule.as_str())).or_insert(0) += e.count;
    }
    for f in &mut findings {
        if f.waived {
            continue;
        }
        if let Some(n) = allow.get_mut(&(f.file.as_str(), f.rule)) {
            if *n > 0 {
                *n -= 1;
                f.baselined = true;
            }
        }
    }

    LintReport {
        findings,
        files_scanned: repo.files.len(),
        rules: rules.iter().map(|r| (r.id(), r.describe())).collect(),
    }
}

/// Convenience: walk `root`, then [`run`].
pub fn run_at(root: &Path, baseline: &Baseline) -> std::io::Result<LintReport> {
    Ok(run(&Repo::load(root)?, baseline))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn test_spans_cover_cfg_test_mod_and_test_fn() {
        let src = "\
pub fn live() -> u32 { 1 }

#[cfg(test)]
mod tests {
    #[test]
    fn t() { assert_eq!(super::live(), 1); }
}
";
        let sf = SourceFile::new("rust/src/x.rs", src);
        let live_pos = src.find("fn live").expect("live");
        let assert_pos = src.find("assert_eq").expect("assert");
        assert!(!sf.in_test(live_pos));
        assert!(sf.in_test(assert_pos));
    }

    #[test]
    fn test_span_on_single_item_ends_at_brace() {
        let src = "\
#[test]
fn t() { helper(); }

pub fn after() -> u32 { 2 }
";
        let sf = SourceFile::new("rust/src/x.rs", src);
        assert!(sf.in_test(src.find("helper").expect("helper")));
        assert!(!sf.in_test(src.find("after").expect("after")));
    }

    #[test]
    fn cfg_not_test_is_not_a_test_span() {
        let src = "\
#[cfg(not(test))]
pub fn live() { risky(); }
";
        let sf = SourceFile::new("rust/src/x.rs", src);
        assert!(!sf.in_test(src.find("risky").expect("risky")));
    }

    #[test]
    fn waivers_parse_rules_and_reason() {
        let src = "\
// lint:allow(stats-parity) carried in the backend label
let x = 1; // lint:allow(nan-ordering, panic-freedom) fixture data
// lint:allow(panic-freedom
// lint:allow(panic-freedom)
";
        let sf = SourceFile::new("rust/src/x.rs", src);
        assert_eq!(sf.waivers.len(), 4);
        assert_eq!(sf.waivers[0].rules, vec!["stats-parity"]);
        assert_eq!(sf.waivers[0].reason, "carried in the backend label");
        assert_eq!(sf.waivers[1].line, 2);
        assert_eq!(sf.waivers[1].rules.len(), 2);
        assert!(!sf.waivers[2].well_formed, "unclosed list is malformed");
        assert!(sf.waivers[3].well_formed);
        assert!(sf.waivers[3].reason.is_empty());
    }

    #[test]
    fn doc_comments_never_carry_waivers() {
        let src = "/// lint:allow(panic-freedom) not a real waiver\nfn f() {}\n";
        let sf = SourceFile::new("rust/src/x.rs", src);
        assert!(sf.waivers.is_empty());
    }

    #[test]
    fn engine_reports_waiver_syntax_problems() {
        let src = "\
// lint:allow(no-such-rule) misspelled
// lint:allow(panic-freedom)
fn f() {}
";
        let repo = Repo::from_sources(&[("rust/src/x.rs", src)]);
        let report = run(&repo, &Baseline::empty());
        let ws: Vec<&Finding> = report
            .findings
            .iter()
            .filter(|f| f.rule == WAIVER_SYNTAX)
            .collect();
        assert_eq!(ws.len(), 2, "{ws:?}");
        assert!(ws[0].message.contains("no-such-rule"));
        assert!(ws[1].message.contains("no reason"));
        assert_eq!(report.count_unwaived(), 2);
    }

    #[test]
    fn baseline_absorbs_counted_findings() {
        let src = "pub fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        let repo = Repo::from_sources(&[("rust/src/server/fx.rs", src)]);
        let baseline = Baseline::parse(
            r#"{"version": 1, "entries": [
                {"file": "rust/src/server/fx.rs", "rule": "panic-freedom", "count": 1}
            ]}"#,
        )
        .expect("parse baseline");
        let report = run(&repo, &baseline);
        assert_eq!(report.count_unwaived(), 0, "{:?}", report.findings);
        assert_eq!(report.count_baselined(), 1);
        // Without the baseline the same repo fails.
        assert_eq!(run(&repo, &Baseline::empty()).count_unwaived(), 1);
    }

    #[test]
    fn baseline_missing_file_is_empty() {
        let b = Baseline::load(Path::new("/nonexistent/lint-baseline.json"))
            .expect("missing baseline is empty");
        assert!(b.entries.is_empty());
    }

    #[test]
    fn matching_delimiters() {
        let sf = SourceFile::new("x.rs", "f(a, (b), [c{d}])");
        // code tokens: f ( a , ( b ) , [ c { d } ] )
        assert_eq!(sf.matching(1), Some(14));
        assert_eq!(sf.matching(4), Some(6));
        assert_eq!(sf.matching(8), Some(13));
        assert_eq!(sf.matching(10), Some(12));
    }
}
