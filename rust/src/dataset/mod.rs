//! Offline phase: dataset generation (paper §IV-A.1/2).
//!
//! For each training workload the candidate space `C(G)` is sampled with
//! analytical guidance — top-performing, worst-performing and random
//! intermediate configurations, under *relaxed* resource constraints so
//! that designs the analytical model mis-ranks are not excluded — then
//! every sampled design is "built and measured on-board" (simulated).
//! Only successful builds are retained, exactly as the paper retains
//! successful bitstreams. The result is ≈6000 measurements across the 18
//! training workloads.

use crate::analytical::AnalyticalModel;
use crate::config::Config;
use crate::features::{featurize, FeatureSet, N_FEATURES};
use crate::gbdt::FeatureMatrix;
use crate::tiling::{enumerate_candidates, Tiling, TilingLimits};
use crate::util::csv::Csv;
use crate::util::rng::Rng;
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::{Gemm, Workload};

/// One measured design.
#[derive(Debug, Clone, PartialEq)]
pub struct DataPoint {
    pub workload_id: String,
    pub gemm: Gemm,
    pub tiling: Tiling,
    pub measurement: Measurement,
}

impl DataPoint {
    pub fn features(&self, micro: usize) -> [f64; N_FEATURES] {
        featurize(&self.gemm, &self.tiling, micro)
    }
}

/// The offline-phase dataset.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Dataset {
    pub points: Vec<DataPoint>,
}

/// Prediction targets extracted from a dataset.
#[derive(Debug, Clone)]
pub struct Targets {
    pub latency_s: Vec<f64>,
    pub power_w: Vec<f64>,
    /// 5 columns: BRAM/URAM/LUT/FF/DSP utilization in percent.
    pub resources_pct: Vec<Vec<f64>>,
}

impl Dataset {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Generate the dataset for `workloads` (paper: the 18 training
    /// GEMMs; ~340 samples each ≈ 6000 designs).
    pub fn generate(cfg: &Config, workloads: &[Workload]) -> Dataset {
        let sim = VersalSim::new(cfg);
        let analytical = AnalyticalModel::new(&cfg.board);
        let limits = TilingLimits::from_board(&cfg.board);
        let mut rng = Rng::new(cfg.dataset.seed);
        let mut points = Vec::new();
        // The paper generates designs through ARIES, so the dataset uses
        // its buffer placement.
        let placement = BufferPlacement::UramFirst;

        for w in workloads {
            let mut wl_rng = rng.fork(crate::util::rng::fnv1a(w.id.as_bytes()));
            let cands = enumerate_candidates(&w.gemm, cfg.board.micro_tile, &limits);
            // Relaxed resource pre-filter (exact check happens on-board).
            let relaxed: Vec<Tiling> = cands
                .into_iter()
                .filter(|t| {
                    sim.resources(t, placement).max_utilization(&cfg.board)
                        <= cfg.dataset.resource_relaxation
                })
                .collect();
            if relaxed.is_empty() {
                continue;
            }
            // Rank by analytical throughput to pick best/worst/random.
            // NaN-safe ranking: a degenerate analytical estimate must not
            // panic dataset generation (the old `partial_cmp().unwrap()`)
            // nor masquerade as a top design, so non-finite throughputs
            // are dropped before the `total_cmp` sort.
            let mut ranked: Vec<(f64, Tiling)> = relaxed
                .iter()
                .filter_map(|t| analytical.throughput(&w.gemm, t).map(|thr| (thr, *t)))
                .filter(|(thr, _)| thr.is_finite())
                .collect();
            ranked.sort_by(|a, b| b.0.total_cmp(&a.0));

            let n = ranked.len();
            let top = cfg.dataset.top_k.min(n);
            let bottom = cfg.dataset.bottom_k.min(n.saturating_sub(top));
            let mut chosen: Vec<Tiling> = Vec::new();
            chosen.extend(ranked[..top].iter().map(|(_, t)| *t));
            chosen.extend(ranked[n - bottom..].iter().map(|(_, t)| *t));
            let middle: Vec<Tiling> = ranked[top..n - bottom].iter().map(|(_, t)| *t).collect();
            let take = cfg.dataset.random_k.min(middle.len());
            for idx in wl_rng.sample_indices(middle.len(), take) {
                chosen.push(middle[idx]);
            }

            // "On-board" measurement; failed builds are dropped.
            for t in chosen {
                if let Ok(m) = sim.evaluate(&w.gemm, &t, placement) {
                    points.push(DataPoint {
                        workload_id: w.id.clone(),
                        gemm: w.gemm,
                        tiling: t,
                        measurement: m,
                    });
                }
            }
        }
        Dataset { points }
    }

    /// Feature matrix for the chosen feature subset.
    pub fn feature_matrix(&self, micro: usize, set: FeatureSet) -> FeatureMatrix {
        let rows: Vec<Vec<f64>> = self
            .points
            .iter()
            .map(|p| crate::features::project(&p.features(micro), set))
            .collect();
        FeatureMatrix::from_rows(&rows)
    }

    pub fn targets(&self, cfg: &Config) -> Targets {
        let board = &cfg.board;
        Targets {
            latency_s: self.points.iter().map(|p| p.measurement.latency_s).collect(),
            power_w: self.points.iter().map(|p| p.measurement.power_w).collect(),
            resources_pct: {
                let mut cols = vec![Vec::with_capacity(self.len()); 5];
                for p in &self.points {
                    let v = p.measurement.resources.as_percent_vec(board);
                    for (j, x) in v.iter().enumerate() {
                        cols[j].push(*x);
                    }
                }
                cols
            },
        }
    }

    /// Random row split (train, test) — the paper's 80/20.
    pub fn split_random(&self, test_fraction: f64, seed: u64) -> (Dataset, Dataset) {
        let mut idx: Vec<usize> = (0..self.len()).collect();
        Rng::new(seed).shuffle(&mut idx);
        let n_test = ((self.len() as f64) * test_fraction).round() as usize;
        let (test_idx, train_idx) = idx.split_at(n_test);
        (self.subset(train_idx), self.subset(test_idx))
    }

    /// Leave-workloads-out split: `held` ids form the "unknown workload"
    /// test set of Fig. 7b.
    pub fn split_by_workload(&self, held: &[&str]) -> (Dataset, Dataset) {
        let is_held = |p: &DataPoint| held.contains(&p.workload_id.as_str());
        let train: Vec<DataPoint> = self.points.iter().filter(|p| !is_held(p)).cloned().collect();
        let test: Vec<DataPoint> = self.points.iter().filter(|p| is_held(p)).cloned().collect();
        (Dataset { points: train }, Dataset { points: test })
    }

    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            points: idx.iter().map(|&i| self.points[i].clone()).collect(),
        }
    }

    /// Distinct workload ids, in first-appearance order.
    pub fn workload_ids(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for p in &self.points {
            if !out.contains(&p.workload_id) {
                out.push(p.workload_id.clone());
            }
        }
        out
    }

    // -- persistence -----------------------------------------------------

    const HEADER: [&'static str; 19] = [
        "workload", "m", "n", "k", "p_m", "p_n", "p_k", "b_m", "b_n", "b_k", "latency_s",
        "power_w", "gflops", "energy_eff", "bram_pct", "uram_pct", "lut_pct", "ff_pct",
        "dsp_pct",
    ];

    pub fn to_csv(&self, cfg: &Config) -> Csv {
        let mut csv = Csv::new(&Self::HEADER);
        for p in &self.points {
            let r = p.measurement.resources.as_percent_vec(&cfg.board);
            csv.push(vec![
                p.workload_id.clone(),
                p.gemm.m.to_string(),
                p.gemm.n.to_string(),
                p.gemm.k.to_string(),
                p.tiling.p_m.to_string(),
                p.tiling.p_n.to_string(),
                p.tiling.p_k.to_string(),
                p.tiling.b_m.to_string(),
                p.tiling.b_n.to_string(),
                p.tiling.b_k.to_string(),
                format!("{:.9e}", p.measurement.latency_s),
                format!("{:.6}", p.measurement.power_w),
                format!("{:.4}", p.measurement.gflops),
                format!("{:.6}", p.measurement.energy_eff),
                format!("{:.4}", r[0]),
                format!("{:.4}", r[1]),
                format!("{:.4}", r[2]),
                format!("{:.4}", r[3]),
                format!("{:.4}", r[4]),
            ]);
        }
        csv
    }

    pub fn from_csv(csv: &Csv, cfg: &Config) -> anyhow::Result<Dataset> {
        let col = |name: &str| {
            csv.col_index(name)
                .ok_or_else(|| anyhow::anyhow!("missing column {name}"))
        };
        let board = &cfg.board;
        let iw = col("workload")?;
        let dims = [col("m")?, col("n")?, col("k")?];
        let tix = [
            col("p_m")?,
            col("p_n")?,
            col("p_k")?,
            col("b_m")?,
            col("b_n")?,
            col("b_k")?,
        ];
        let il = col("latency_s")?;
        let ip = col("power_w")?;
        let ig = col("gflops")?;
        let ie = col("energy_eff")?;
        let ir = [
            col("bram_pct")?,
            col("uram_pct")?,
            col("lut_pct")?,
            col("ff_pct")?,
            col("dsp_pct")?,
        ];
        let mut points = Vec::with_capacity(csv.rows.len());
        for row in &csv.rows {
            let u = |i: usize| -> anyhow::Result<usize> {
                row[i].parse().map_err(|_| anyhow::anyhow!("bad int {}", row[i]))
            };
            let f = |i: usize| -> anyhow::Result<f64> {
                row[i].parse().map_err(|_| anyhow::anyhow!("bad f64 {}", row[i]))
            };
            let gemm = Gemm::new(u(dims[0])?, u(dims[1])?, u(dims[2])?);
            let tiling = Tiling::new(
                (u(tix[0])?, u(tix[1])?, u(tix[2])?),
                (u(tix[3])?, u(tix[4])?, u(tix[5])?),
            );
            let latency_s = f(il)?;
            let power_w = f(ip)?;
            let resources = crate::versal::Resources {
                bram: (f(ir[0])? / 100.0 * board.bram_total as f64).round() as usize,
                uram: (f(ir[1])? / 100.0 * board.uram_total as f64).round() as usize,
                lut: (f(ir[2])? / 100.0 * board.lut_total as f64).round() as usize,
                ff: (f(ir[3])? / 100.0 * board.ff_total as f64).round() as usize,
                dsp: (f(ir[4])? / 100.0 * board.dsp_total as f64).round() as usize,
            };
            points.push(DataPoint {
                workload_id: row[iw].clone(),
                gemm,
                tiling,
                measurement: Measurement {
                    latency_s,
                    power_w,
                    resources,
                    gflops: f(ig)?,
                    energy_eff: f(ie)?,
                    busy: 0.0,
                },
            });
        }
        Ok(Dataset { points })
    }

    pub fn save(&self, cfg: &Config, path: &std::path::Path) -> anyhow::Result<()> {
        self.to_csv(cfg).save(path)
    }

    pub fn load(cfg: &Config, path: &std::path::Path) -> anyhow::Result<Dataset> {
        Dataset::from_csv(&Csv::load(path)?, cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads::training_workloads;

    fn small_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 8;
        cfg.dataset.bottom_k = 6;
        cfg.dataset.random_k = 16;
        cfg
    }

    fn tiny_workloads() -> Vec<Workload> {
        training_workloads().into_iter().take(3).collect()
    }

    #[test]
    fn generation_is_deterministic() {
        let cfg = small_cfg();
        let wl = tiny_workloads();
        let a = Dataset::generate(&cfg, &wl);
        let b = Dataset::generate(&cfg, &wl);
        assert_eq!(a, b);
        assert!(!a.is_empty());
    }

    #[test]
    fn generation_covers_requested_mix() {
        let cfg = small_cfg();
        let wl = tiny_workloads();
        let ds = Dataset::generate(&cfg, &wl);
        // Per workload at most top+bottom+random samples, minus failures.
        let per_wl = cfg.dataset.top_k + cfg.dataset.bottom_k + cfg.dataset.random_k;
        assert!(ds.len() <= per_wl * wl.len());
        assert!(ds.len() >= per_wl * wl.len() / 2, "too many failures: {}", ds.len());
        // Wide spread of AIE allocations (full range coverage, §IV-A.1).
        let aies: Vec<usize> = ds.points.iter().map(|p| p.tiling.n_aie()).collect();
        assert!(aies.iter().copied().max().unwrap() >= 64);
        assert!(aies.iter().copied().min().unwrap() <= 4);
        assert_eq!(ds.workload_ids().len(), wl.len());
    }

    #[test]
    fn feature_matrix_shapes() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg, &tiny_workloads());
        let x1 = ds.feature_matrix(32, FeatureSet::SetI);
        let x2 = ds.feature_matrix(32, FeatureSet::SetIAndII);
        assert_eq!(x1.n_rows, ds.len());
        assert_eq!(x1.n_cols, 9);
        assert_eq!(x2.n_cols, 17);
        let t = ds.targets(&cfg);
        assert_eq!(t.latency_s.len(), ds.len());
        assert_eq!(t.resources_pct.len(), 5);
        assert!(t.latency_s.iter().all(|&l| l > 0.0));
        assert!(t.power_w.iter().all(|&p| (10.0..60.0).contains(&p)));
    }

    #[test]
    fn splits_partition() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg, &tiny_workloads());
        let (train, test) = ds.split_random(0.2, 7);
        assert_eq!(train.len() + test.len(), ds.len());
        assert!((test.len() as f64 / ds.len() as f64 - 0.2).abs() < 0.05);

        let held = ["ncf_l1"];
        let (known, unknown) = ds.split_by_workload(&held);
        assert_eq!(known.len() + unknown.len(), ds.len());
        assert!(unknown.points.iter().all(|p| p.workload_id == "ncf_l1"));
        assert!(known.points.iter().all(|p| p.workload_id != "ncf_l1"));
        assert!(!unknown.is_empty());
    }

    #[test]
    fn csv_roundtrip() {
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg, &tiny_workloads());
        let csv = ds.to_csv(&cfg);
        let back = Dataset::from_csv(&csv, &cfg).unwrap();
        assert_eq!(back.len(), ds.len());
        for (a, b) in ds.points.iter().zip(&back.points) {
            assert_eq!(a.workload_id, b.workload_id);
            assert_eq!(a.tiling, b.tiling);
            assert!((a.measurement.power_w - b.measurement.power_w).abs() < 1e-4);
            assert!(
                (a.measurement.latency_s - b.measurement.latency_s).abs()
                    / a.measurement.latency_s
                    < 1e-6
            );
            // Percent columns carry 4 decimals; LUT/FF counts may be off
            // by a unit or two after the roundtrip.
            let (ra, rb) = (a.measurement.resources, b.measurement.resources);
            assert_eq!(ra.bram, rb.bram);
            assert_eq!(ra.uram, rb.uram);
            assert_eq!(ra.dsp, rb.dsp);
            assert!(ra.lut.abs_diff(rb.lut) <= 2);
            assert!(ra.ff.abs_diff(rb.ff) <= 4);
        }
    }

    #[test]
    fn rho_latency_correlation_is_strong() {
        // Paper §IV-A.3: Pearson r = 0.81 between rho = FLOP/N_AIE and
        // execution time. Check the dataset reproduces a strong positive
        // correlation (in log space, where the relation is linear-ish).
        let cfg = small_cfg();
        let ds = Dataset::generate(&cfg, &training_workloads());
        let rho: Vec<f64> = ds
            .points
            .iter()
            .map(|p| (p.gemm.flops() / p.tiling.n_aie() as f64).ln())
            .collect();
        let lat: Vec<f64> = ds.points.iter().map(|p| p.measurement.latency_s.ln()).collect();
        let r = crate::metrics::pearson(&rho, &lat);
        assert!(r > 0.6, "pearson {r}");
    }
}
