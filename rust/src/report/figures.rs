//! One renderer per paper figure/table (DESIGN.md §8 experiment index).

use crate::analytical::AriesPolicy;
use crate::dse::compare::tradeoff_stats;
use crate::dse::{measured_hypervolume, ExhaustiveExplorer};
use crate::features::FeatureSet;
use crate::gpu::jetson_devices;
use crate::metrics::{geomean, mape, median, pareto_front_max, pearson, quantile, r2};
use crate::models::Predictors;
use crate::report::Lab;
use crate::util::table::{fnum, scatter_plot, Table};
use crate::versal::{BufferPlacement, VersalSim};
use crate::workloads::{eval_workloads, Gemm};

/// Fig. 1 — impact of tiling on throughput/energy-efficiency for one
/// GEMM: full design sweep on the simulator, highlighting the
/// highest-throughput, most-energy-efficient and analytical picks.
pub fn fig1_tiling_impact(lab: &Lab) -> String {
    let g = Gemm::new(224, 3072, 768); // medium ViT-style workload
    let ex = ExhaustiveExplorer::new(VersalSim::new(&lab.cfg));
    let all = ex.explore(&g);
    // NaN-safe selection: filter non-finite measurements out entirely
    // (under `total_cmp` alone a NaN would *win* a max_by, and the old
    // `partial_cmp().unwrap()` panicked).
    let best_thr = all
        .iter()
        .filter(|c| c.1.gflops.is_finite())
        .max_by(|a, b| a.1.gflops.total_cmp(&b.1.gflops))
        .unwrap();
    let best_eff = all
        .iter()
        .filter(|c| c.1.energy_eff.is_finite())
        .max_by(|a, b| a.1.energy_eff.total_cmp(&b.1.energy_eff))
        .unwrap();
    let aries_pick = AriesPolicy::new(&lab.cfg.board)
        .select(&g)
        .and_then(|d| ex.sim.evaluate(&g, &d.tiling, d.placement).ok());

    let mut pts: Vec<(f64, f64, char)> = all
        .iter()
        .map(|(_, m)| (m.gflops, m.energy_eff, '.'))
        .collect();
    pts.push((best_thr.1.gflops, best_thr.1.energy_eff, 'x'));
    pts.push((best_eff.1.gflops, best_eff.1.energy_eff, '*'));
    if let Some(a) = &aries_pick {
        pts.push((a.gflops, a.energy_eff, 'A'));
    }

    let eff_gap = 100.0 * (1.0 - best_thr.1.energy_eff / best_eff.1.energy_eff);
    let power_gap = best_thr.1.power_w - best_eff.1.power_w;
    let mut out = String::new();
    out.push_str(&format!(
        "== Fig. 1: impact of tiling on GEMM performance and power ({} designs, GEMM {}) ==\n",
        all.len(),
        g.label()
    ));
    out.push_str(&scatter_plot(
        "(a) throughput vs energy efficiency   x=best-thr  *=best-eff  A=analytical pick",
        &pts,
        72,
        18,
        "throughput GFLOP/s",
        "GFLOP/s/W",
    ));
    out.push_str(&format!(
        "best-throughput design: {:>9} GFLOP/s @ {:>5} W  {}\n",
        fnum(best_thr.1.gflops),
        fnum(best_thr.1.power_w),
        best_thr.0.label()
    ));
    out.push_str(&format!(
        "best-energy design:     {:>9} GFLOP/s @ {:>5} W  {}\n",
        fnum(best_eff.1.gflops),
        fnum(best_eff.1.power_w),
        best_eff.0.label()
    ));
    out.push_str(&format!(
        "highest-throughput design is {:.1}% less energy-efficient than the most \
         energy-efficient one (paper: 22.4%); power delta {:.1} W (paper: ~11 W)\n",
        eff_gap, power_gap
    ));
    if let Some(a) = &aries_pick {
        let thr_loss = 100.0 * (1.0 - a.gflops / best_thr.1.gflops);
        out.push_str(&format!(
            "analytical-model pick loses {:.1}% throughput vs actual best (paper: 17%)\n",
            thr_loss
        ));
    }
    out
}

/// Fig. 3 — system power vs number of active AIEs across the dataset.
pub fn fig3_power_vs_aies(lab: &Lab) -> String {
    let buckets: [usize; 10] = [1, 2, 4, 8, 16, 32, 64, 128, 256, 400];
    let mut table = Table::new(
        "== Fig. 3: system power for varying AIE utilization (dataset designs) ==",
        &["#AIEs (<=)", "designs", "P min [W]", "P median [W]", "P max [W]"],
    );
    let mut prev = 0usize;
    for &b in &buckets {
        let powers: Vec<f64> = lab
            .dataset
            .points
            .iter()
            .filter(|p| {
                let n = p.tiling.n_aie();
                n > prev && n <= b
            })
            .map(|p| p.measurement.power_w)
            .collect();
        if !powers.is_empty() {
            table.row(vec![
                b.to_string(),
                powers.len().to_string(),
                fnum(quantile(&powers, 0.0)),
                fnum(median(&powers)),
                fnum(quantile(&powers, 1.0)),
            ]);
        }
        prev = b;
    }
    let all: Vec<f64> = lab.dataset.points.iter().map(|p| p.measurement.power_w).collect();
    format!(
        "{}paper: medians 12->18 W for 1..32 AIEs, 19-38 W beyond, outliers to ~49 W\n\
         dataset span: {:.1}..{:.1} W over {} designs\n",
        table.render(),
        quantile(&all, 0.0),
        quantile(&all, 1.0),
        all.len()
    )
}

/// Fig. 4 — energy/throughput trade-offs across the eval workloads
/// (exhaustive ground truth).
pub fn fig4_tradeoffs(lab: &Lab) -> String {
    let mut table = Table::new(
        "== Fig. 4: trade-offs between energy- and throughput-oriented mappings ==",
        &[
            "G_n",
            "GEMM",
            "(a) thr loss of energy-opt [%]",
            "(b) eff loss of thr-opt [%]",
            "(c) #AIE thr-opt",
            "#AIE energy-opt",
        ],
    );
    for w in eval_workloads() {
        if let Some(t) = tradeoff_stats(&lab.cfg, &w.gemm) {
            table.row(vec![
                w.id.clone(),
                w.gemm.label(),
                format!("{:.1}", t.throughput_loss_pct),
                format!("{:.1}", t.energy_loss_pct),
                t.aie_throughput.to_string(),
                t.aie_energy.to_string(),
            ]);
        }
    }
    format!(
        "{}paper: low-FLOP G1-G3 lose 1.6-3.1% thr for large eff gains; mid-FLOP \
         G4-G10 show the largest trade-offs (up to ~20%); high-FLOP G11-G13 converge (0.1-2.1%)\n",
        table.render()
    )
}

/// Fig. 6 — R^2 of the latency model vs training-set size, Set-I vs
/// Set-I&II.
pub fn fig6_r2_vs_training_size(lab: &Lab) -> String {
    let fractions = [0.1, 0.2, 0.3, 0.5, 0.7, 0.9, 1.0];
    let (train_full, test) = lab.dataset.split_random(lab.cfg.train.test_fraction, 41);
    let mut cfg = lab.cfg.clone();
    cfg.train.n_trees = cfg.train.n_trees.min(200);

    let mut table = Table::new(
        "== Fig. 6: R^2 score of the latency model vs training-set fraction ==",
        &["fraction", "train designs", "R^2 Set-I", "R^2 Set-I&II"],
    );
    let truth: Vec<f64> = test.points.iter().map(|p| p.measurement.latency_s).collect();
    let mut final_r2 = (0.0, 0.0);
    for &f in &fractions {
        let n = ((train_full.len() as f64) * f).round() as usize;
        let idx: Vec<usize> = (0..n).collect();
        let sub = train_full.subset(&idx);
        let mut row = vec![format!("{:.0}%", f * 100.0), n.to_string()];
        let mut scores = (0.0, 0.0);
        for (slot, set) in [FeatureSet::SetI, FeatureSet::SetIAndII].iter().enumerate() {
            let model = Predictors::train(&sub, &cfg, *set);
            let pred: Vec<f64> = test
                .points
                .iter()
                .map(|p| model.predict(&p.gemm, &p.tiling).latency_s)
                .collect();
            let score = r2(&truth, &pred);
            row.push(format!("{score:.4}"));
            if slot == 0 {
                scores.0 = score;
            } else {
                scores.1 = score;
            }
        }
        final_r2 = scores;
        table.row(row);
    }
    format!(
        "{}paper: Set-I&II reaches R^2 = 0.986 with ~30% of the data; ours at 100%: \
         Set-I {:.3}, Set-I&II {:.3}\n",
        table.render(),
        final_r2.0,
        final_r2.1
    )
}

/// Fig. 7 — latency MAPE of the ML model vs the analytical model, for
/// known (random split) and unknown (held-out workloads) GEMMs.
pub fn fig7_prediction_error(lab: &Lab) -> String {
    let cfg = &lab.cfg;
    let analytical = crate::analytical::AnalyticalModel::new(&cfg.board);

    let mape_of = |test: &crate::dataset::Dataset, model: &Predictors| -> f64 {
        let truth: Vec<f64> = test.points.iter().map(|p| p.measurement.latency_s).collect();
        let pred: Vec<f64> = test
            .points
            .iter()
            .map(|p| model.predict(&p.gemm, &p.tiling).latency_s)
            .collect();
        mape(&truth, &pred)
    };
    let mape_analytical = |test: &crate::dataset::Dataset| -> f64 {
        let pairs: Vec<(f64, f64)> = test
            .points
            .iter()
            .filter_map(|p| {
                analytical
                    .latency(&p.gemm, &p.tiling)
                    .map(|est| (p.measurement.latency_s, est))
            })
            .collect();
        let truth: Vec<f64> = pairs.iter().map(|x| x.0).collect();
        let pred: Vec<f64> = pairs.iter().map(|x| x.1).collect();
        mape(&truth, &pred)
    };

    // (a) known workloads: random 80/20 over all designs.
    let (train_known, test_known) = lab.dataset.split_random(cfg.train.test_fraction, 77);
    let m1_known = Predictors::train(&train_known, cfg, FeatureSet::SetI);
    let m2_known = Predictors::train(&train_known, cfg, FeatureSet::SetIAndII);

    // (b) unknown workloads: hold out 4 of the 18 training GEMMs.
    let ids = lab.dataset.workload_ids();
    let held: Vec<&str> = ids.iter().step_by(5).map(String::as_str).collect();
    let (train_unk, test_unk) = lab.dataset.split_by_workload(&held);
    let m1_unk = Predictors::train(&train_unk, cfg, FeatureSet::SetI);
    let m2_unk = Predictors::train(&train_unk, cfg, FeatureSet::SetIAndII);

    let rows = [
        (
            "known (80/20 split)",
            mape_analytical(&test_known),
            mape_of(&test_known, &m1_known),
            mape_of(&test_known, &m2_known),
        ),
        (
            "unknown (held-out workloads)",
            mape_analytical(&test_unk),
            mape_of(&test_unk, &m1_unk),
            mape_of(&test_unk, &m2_unk),
        ),
    ];
    let mut table = Table::new(
        "== Fig. 7: latency prediction error (MAPE %, lower is better) ==",
        &["split", "analytical [19]", "ML Set-I", "ML Set-I&II"],
    );
    for (name, a, s1, s12) in rows {
        table.row(vec![
            name.to_string(),
            format!("{a:.2}"),
            format!("{s1:.2}"),
            format!("{s12:.2}"),
        ]);
    }
    let overall_gain = 100.0 * (1.0 - rows[1].3 / rows[1].1.max(1e-9));
    format!(
        "{}held-out workloads: {:?}\n\
         paper: analytical median 26.67%, ML Set-I 34.16%, Set-I&II 13.09% (50.9% better);\n\
         unknown-workload Set-II gain here: {:.1}% vs analytical\n",
        table.render(),
        held,
        overall_gain
    )
}

/// Fig. 8 — throughput and energy efficiency vs CHARM and ARIES on
/// G1..G13, normalized to CHARM.
pub fn fig8_sota_comparison(lab: &Lab) -> String {
    let comps = lab.comparisons();
    let mut table = Table::new(
        "== Fig. 8: throughput / energy-efficiency on VCK190, normalized to CHARM ==",
        &[
            "G_n", "GEMM", "thr CHARM", "thr ARIES", "thr Ours", "eff CHARM", "eff ARIES",
            "eff Ours",
        ],
    );
    let mut thr_vs_charm = Vec::new();
    let mut thr_vs_aries = Vec::new();
    let mut eff_vs_charm = Vec::new();
    let mut eff_vs_aries = Vec::new();
    for (w, c) in &comps {
        let (Some(ch), Some(ar), Some(ot), Some(oe)) =
            (&c.charm, &c.aries, &c.ours_throughput, &c.ours_energy)
        else {
            continue;
        };
        let base_t = ch.gflops;
        let base_e = ch.energy_eff;
        table.row(vec![
            w.id.clone(),
            w.gemm.label(),
            "1.00".into(),
            format!("{:.2}", ar.gflops / base_t),
            format!("{:.2}", ot.gflops / base_t),
            "1.00".into(),
            format!("{:.2}", ar.energy_eff / base_e),
            format!("{:.2}", oe.energy_eff / base_e),
        ]);
        thr_vs_charm.push(ot.gflops / ch.gflops);
        thr_vs_aries.push(ot.gflops / ar.gflops);
        eff_vs_charm.push(oe.energy_eff / ch.energy_eff);
        eff_vs_aries.push(oe.energy_eff / ar.energy_eff);
    }
    format!(
        "{}geomean speedup of Ours: {:.2}x vs CHARM (paper 1.73x), {:.2}x vs ARIES (paper 1.23x)\n\
         geomean energy-eff gain:  {:.2}x vs CHARM (paper 1.73x), {:.2}x vs ARIES (paper 1.25x)\n\
         ranges: thr vs ARIES {:.2}x..{:.2}x (paper 0.67-2.52), eff vs ARIES {:.2}x..{:.2}x (paper 0.84-2.69)\n",
        table.render(),
        geomean(&thr_vs_charm),
        geomean(&thr_vs_aries),
        geomean(&eff_vs_charm),
        geomean(&eff_vs_aries),
        thr_vs_aries.iter().copied().fold(f64::INFINITY, f64::min),
        thr_vs_aries.iter().copied().fold(0.0, f64::max),
        eff_vs_aries.iter().copied().fold(f64::INFINITY, f64::min),
        eff_vs_aries.iter().copied().fold(0.0, f64::max),
    )
}

/// Table II — evaluation platforms.
pub fn table2_devices() -> String {
    let board = crate::config::BoardConfig::default();
    let mut table = Table::new(
        "== Table II: evaluation setup ==",
        &["device", "compute", "peak GFLOP/s", "mem BW GB/s"],
    );
    for d in jetson_devices() {
        table.row(vec![
            d.name.clone(),
            "tensor cores".into(),
            fnum(d.peak_gflops),
            fnum(d.mem_bw_gbps),
        ]);
    }
    table.row(vec![
        "Versal VCK190".into(),
        format!("{} AIEs + PL", board.aie_total),
        fnum(board.peak_gflops()),
        fnum(board.ddr_peak_bps / 1e9),
    ]);
    table.render()
}

/// Table III — resource utilization of the generated designs.
pub fn table3_resources(lab: &Lab) -> String {
    let comps = lab.comparisons();
    let mut table = Table::new(
        "== Table III: resource utilization by workload ==",
        &[
            "G_n", "framework", "#AIE", "BRAM %", "URAM %", "LUT %", "FF %", "DSP %",
        ],
    );
    for (w, c) in &comps {
        let mut push = |name: &str, d: &Option<crate::dse::compare::MeasuredDesign>| {
            if let Some(d) = d {
                table.row(vec![
                    w.id.clone(),
                    name.to_string(),
                    d.n_aie.to_string(),
                    format!("{:.1}", d.resources_pct[0]),
                    format!("{:.1}", d.resources_pct[1]),
                    format!("{:.1}", d.resources_pct[2]),
                    format!("{:.1}", d.resources_pct[3]),
                    format!("{:.1}", d.resources_pct[4]),
                ]);
            }
        };
        push("CHARM", &c.charm);
        push("ARIES", &c.aries);
        push("Ours (Thr)", &c.ours_throughput);
        push("Ours (Eff)", &c.ours_energy);
    }
    // Paper headline: for the small/medium workloads our energy designs
    // use ~2.95x fewer AIEs than CHARM/ARIES.
    let mut ratios = Vec::new();
    for (w, c) in comps.iter().take(7) {
        if let (Some(ch), Some(oe)) = (&c.charm, &c.ours_energy) {
            if oe.n_aie > 0 {
                ratios.push(ch.n_aie as f64 / oe.n_aie as f64);
            }
        }
        let _ = w;
    }
    format!(
        "{}avg CHARM/Ours(Eff) AIE ratio on G1-G7: {:.2}x (paper: 2.95x fewer AIEs)\n",
        table.render(),
        if ratios.is_empty() { 0.0 } else { ratios.iter().sum::<f64>() / ratios.len() as f64 }
    )
}

/// Fig. 9 — VCK190 vs the three Jetsons, normalized to Xavier NX.
pub fn fig9_gpu_comparison(lab: &Lab) -> String {
    let comps = lab.comparisons();
    let gpus = jetson_devices();
    let mut table = Table::new(
        "== Fig. 9: throughput / energy efficiency vs Jetson GPUs (normalized to Xavier NX) ==",
        &[
            "G_n", "thr Xavier", "thr NX", "thr Orin", "thr VCK190", "eff Xavier", "eff NX",
            "eff Orin", "eff VCK190",
        ],
    );
    let mut orin_wins = Vec::new();
    for (w, c) in &comps {
        let Some(ours) = &c.ours_throughput else { continue };
        let nx_thr = gpus[1].throughput(&w.gemm);
        let nx_eff = gpus[1].energy_eff(&w.gemm);
        let row_thr: Vec<f64> = vec![
            gpus[0].throughput(&w.gemm) / nx_thr,
            1.0,
            gpus[2].throughput(&w.gemm) / nx_thr,
            ours.gflops / nx_thr,
        ];
        let eff_ours = c.ours_energy.as_ref().map(|d| d.energy_eff).unwrap_or(ours.energy_eff);
        let row_eff: Vec<f64> = vec![
            gpus[0].energy_eff(&w.gemm) / nx_eff,
            1.0,
            gpus[2].energy_eff(&w.gemm) / nx_eff,
            eff_ours / nx_eff,
        ];
        orin_wins.push((w.id.clone(), ours.gflops / gpus[2].throughput(&w.gemm)));
        table.row(vec![
            w.id.clone(),
            format!("{:.2}", row_thr[0]),
            "1.00".into(),
            format!("{:.2}", row_thr[2]),
            format!("{:.2}", row_thr[3]),
            format!("{:.2}", row_eff[0]),
            "1.00".into(),
            format!("{:.2}", row_eff[2]),
            format!("{:.2}", row_eff[3]),
        ]);
    }
    let best = orin_wins
        .iter()
        .filter(|w| w.1.is_finite())
        .max_by(|a, b| a.1.total_cmp(&b.1))
        .cloned()
        .unwrap_or(("-".into(), 0.0));
    format!(
        "{}paper: Jetsons win on memory-bound G1-G8 (BW 2.33-8x), gap closes for \
         compute-bound G9-G13; G12 VCK190 beats AGX Orin by 2.3x.\n\
         here: best VCK190-vs-Orin throughput ratio = {:.2}x on {}\n",
        table.render(),
        best.1,
        best.0
    )
}

/// Fig. 10 — Pareto fronts: ARIES vs Ours vs actual, with hypervolume.
pub fn fig10_pareto_fronts(lab: &Lab) -> String {
    let picks = ["G2", "G4", "G6", "G8", "G10"];
    let engine = lab.engine();
    let sim = VersalSim::new(&lab.cfg);
    let ex = ExhaustiveExplorer::new(sim.clone());
    let mut out = String::new();
    out.push_str("== Fig. 10: Pareto fronts (measured GFLOP/s x GFLOP/s/W) ==\n");
    let mut hv_ratios = Vec::new();
    for id in picks {
        let w = crate::workloads::eval_workload(id).unwrap();
        let g = w.gemm;
        let actual = ex.true_front(&g);
        // Ours: predicted Pareto front, then measured.
        let ours_pts: Vec<(f64, f64)> = match engine.explore(&g) {
            Err(_) => vec![],
            Ok(r) => {
                let pts: Vec<(f64, f64)> =
                    crate::dse::epsilon_pareto(&r.feasible, 0.04, 60)
                        .iter()
                        .filter_map(|c| {
                            sim.evaluate(&g, &c.tiling, BufferPlacement::UramFirst)
                                .ok()
                                .map(|m| (m.gflops, m.energy_eff))
                        })
                        .collect();
                pareto_front_max(&pts)
            }
        };
        // ARIES: per-AIE-count analytically-best designs, measured.
        let aries_pts = aries_front(lab, &g);

        let scale = (
            actual.iter().map(|p| p.0).fold(1e-9, f64::max),
            actual.iter().map(|p| p.1).fold(1e-9, f64::max),
        );
        let hv_actual = measured_hypervolume(&actual, scale);
        let hv_ours = measured_hypervolume(&ours_pts, scale);
        let hv_aries = measured_hypervolume(&aries_pts, scale);
        if hv_aries > 0.0 && hv_ours > 0.0 {
            hv_ratios.push(hv_ours / hv_aries);
        }
        let mut pts: Vec<(f64, f64, char)> =
            actual.iter().map(|&(x, y)| (x, y, '.')).collect();
        pts.extend(aries_pts.iter().map(|&(x, y)| (x, y, 'a')));
        pts.extend(ours_pts.iter().map(|&(x, y)| (x, y, 'o')));
        out.push_str(&scatter_plot(
            &format!(
                "{id} {}   .=actual front  a=ARIES  o=Ours   HV: actual {:.3} ours {:.3} aries {:.3}",
                g.label(),
                hv_actual,
                hv_ours,
                hv_aries
            ),
            &pts,
            64,
            12,
            "GFLOP/s",
            "GFLOP/s/W",
        ));
    }
    if hv_ratios.is_empty() {
        out.push_str("hypervolume ratio: n/a (no comparable fronts)\n");
    } else {
        out.push_str(&format!(
            "geomean hypervolume ratio Ours/ARIES: {:.2}x (paper: 2.18x, up to 3.84x); max {:.2}x\n",
            geomean(&hv_ratios),
            hv_ratios.iter().copied().fold(0.0, f64::max)
        ));
    }
    out
}

/// ARIES's "front": its analytically-best design per distinct AIE count,
/// measured on the simulator, reduced to the non-dominated set.
pub fn aries_front(lab: &Lab, g: &Gemm) -> Vec<(f64, f64)> {
    use std::collections::HashMap;
    let policy = AriesPolicy::new(&lab.cfg.board);
    let limits = crate::tiling::TilingLimits::from_board(&lab.cfg.board);
    let sim = VersalSim::new(&lab.cfg);
    let cands = crate::tiling::enumerate_candidates(g, lab.cfg.board.micro_tile, &limits);
    let mut best_per_aie: HashMap<usize, (f64, crate::tiling::Tiling)> = HashMap::new();
    for t in cands {
        let res = policy.model.resources(&t, BufferPlacement::UramFirst);
        if res.max_utilization(&lab.cfg.board) > policy.util_cap {
            continue;
        }
        if let Some(thr) = policy.model.throughput(g, &t) {
            let e = best_per_aie.entry(t.n_aie()).or_insert((0.0, t));
            if thr > e.0 {
                *e = (thr, t);
            }
        }
    }
    let pts: Vec<(f64, f64)> = best_per_aie
        .values()
        .filter_map(|(_, t)| {
            sim.evaluate(g, t, BufferPlacement::UramFirst)
                .ok()
                .map(|m| (m.gflops, m.energy_eff))
        })
        .collect();
    pareto_front_max(&pts)
}

/// Model-quality summary: 𝓟/𝓡 MAPEs, ρ-latency correlation, DSE cost.
pub fn model_quality(lab: &Lab) -> String {
    let cfg = &lab.cfg;
    let (train, test) = lab.dataset.split_random(cfg.train.test_fraction, 123);
    let model = Predictors::train(&train, cfg, FeatureSet::SetIAndII);

    let p_truth: Vec<f64> = test.points.iter().map(|p| p.measurement.power_w).collect();
    let p_pred: Vec<f64> = test
        .points
        .iter()
        .map(|p| model.predict(&p.gemm, &p.tiling).power_w)
        .collect();

    // Resource MAPE over the 5 outputs (skip zero-truth entries).
    let mut r_truth = Vec::new();
    let mut r_pred = Vec::new();
    for p in &test.points {
        let truth = p.measurement.resources.as_percent_vec(&cfg.board);
        let pred = model.predict(&p.gemm, &p.tiling).resources_pct;
        for j in 0..5 {
            if truth[j] > 0.5 {
                r_truth.push(truth[j]);
                r_pred.push(pred[j]);
            }
        }
    }

    let rho: Vec<f64> = lab
        .dataset
        .points
        .iter()
        .map(|p| (p.gemm.flops() / p.tiling.n_aie() as f64).ln())
        .collect();
    let lat: Vec<f64> = lab
        .dataset
        .points
        .iter()
        .map(|p| p.measurement.latency_s.ln())
        .collect();

    // DSE wall-clock on the largest eval workload.
    let engine = lab.engine();
    let g = eval_workloads().last().unwrap().gemm;
    let start = std::time::Instant::now();
    let dse = engine.explore(&g).ok();
    let dse_s = start.elapsed().as_secs_f64();

    format!(
        "== Model quality summary ==\n\
         dataset: {} designs, {} workloads\n\
         power model MAPE:    {:.2}%   (paper: 7.05%)\n\
         resource model MAPE: {:.2}%   (paper: 6.05%)\n\
         Pearson r (ln rho, ln latency): {:.3}   (paper: 0.81)\n\
         DSE wall-clock on {}: {:.3} s over {} candidates (paper: < 2 s)\n",
        lab.dataset.len(),
        lab.dataset.workload_ids().len(),
        mape(&p_truth, &p_pred),
        mape(&r_truth, &r_pred),
        pearson(&rho, &lat),
        g.label(),
        dse_s,
        dse.map(|r| r.n_candidates).unwrap_or(0)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::dataset::Dataset;
    use crate::workloads::training_workloads;

    fn quick_lab() -> Lab {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 10;
        cfg.dataset.bottom_k = 6;
        cfg.dataset.random_k = 40;
        cfg.train.n_trees = 60;
        cfg.train.learning_rate = 0.2;
        let wl: Vec<_> = training_workloads().into_iter().take(5).collect();
        let ds = Dataset::generate(&cfg, &wl);
        let predictors = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
        Lab::in_memory(cfg, ds, predictors)
    }

    #[test]
    fn fig1_renders_with_gaps() {
        let lab = quick_lab();
        let s = fig1_tiling_impact(&lab);
        assert!(s.contains("Fig. 1"));
        assert!(s.contains("best-throughput design"));
        assert!(s.contains("less energy-efficient"));
    }

    #[test]
    fn fig3_renders_buckets() {
        let lab = quick_lab();
        let s = fig3_power_vs_aies(&lab);
        assert!(s.contains("Fig. 3"));
        assert!(s.contains("P median"));
        // At least 4 populated buckets.
        assert!(s.lines().filter(|l| l.starts_with('|')).count() >= 5);
    }

    #[test]
    fn table2_contains_all_devices() {
        let s = table2_devices();
        for name in ["AGX Xavier", "Xavier NX", "AGX Orin", "Versal VCK190"] {
            assert!(s.contains(name), "missing {name}");
        }
        assert!(s.contains("8000"));
    }

    #[test]
    fn fig7_reports_three_models() {
        let lab = quick_lab();
        let s = fig7_prediction_error(&lab);
        assert!(s.contains("analytical"));
        assert!(s.contains("Set-I&II"));
        assert!(s.contains("unknown"));
    }

    #[test]
    fn model_quality_renders() {
        let lab = quick_lab();
        let s = model_quality(&lab);
        assert!(s.contains("power model MAPE"));
        assert!(s.contains("DSE wall-clock"));
    }

    #[test]
    fn aries_front_nonempty_and_nondominated() {
        let lab = quick_lab();
        let front = aries_front(&lab, &Gemm::new(224, 768, 768));
        assert!(!front.is_empty());
        for (i, &(x1, y1)) in front.iter().enumerate() {
            for (j, &(x2, y2)) in front.iter().enumerate() {
                if i != j {
                    assert!(!(x2 >= x1 && y2 >= y1 && (x2 > x1 || y2 > y1)));
                }
            }
        }
    }
}
