"""L1 — Pallas tiled GEMM kernel: the AIE micro-kernel analogue.

The paper fixes a 32x32x32 FP32 micro-kernel per AI Engine (~90% of peak)
and parallelizes it via tiling factors ``P_d`` (AIE array) and ``B_d``
(PL reuse buffers).  On the TPU-idiom side this becomes a Pallas kernel:

* the 32x32x32 micro-kernel is a Pallas *block* computing
  ``acc += A_blk @ B_blk`` (an MXU-shaped tile),
* AIE local scratchpads map to VMEM block refs sized by ``BlockSpec``,
* the PL's HBM(DDR)->PL->AIE streaming schedule maps to the BlockSpec
  index maps over the grid, and
* the PL partial-sum collection maps to output revisiting over the K grid
  axis (zero-init at k==0, accumulate in place).

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls, and correctness (vs ``ref.py``) is the build-time signal.
Real-TPU performance is *estimated* from the VMEM footprint / MXU
utilization helpers at the bottom (see DESIGN.md section 6).
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The paper's fixed per-AIE workload (section IV-A.1): each AI Engine
# processes a 32x32x32 tile, chosen for high micro-kernel efficiency.
MICRO_M = 32
MICRO_N = 32
MICRO_K = 32


def _gemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """Grid step: one micro-kernel invocation (one AIE tile).

    Accumulates into ``o_ref`` across the K grid axis — the Pallas
    realization of the PL partial-sum collection path.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]
    b = b_ref[...]
    o_ref[...] += jnp.dot(a, b, preferred_element_type=o_ref.dtype)


def tiled_gemm(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = MICRO_M,
    block_n: int = MICRO_N,
    block_k: int = MICRO_K,
    interpret: bool = True,
) -> jax.Array:
    """Tiled GEMM ``C = A @ B`` via a Pallas grid of micro-kernel blocks.

    Dimensions must be multiples of the block sizes (the coordinator pads
    to 32-aligned tiles before dispatch, exactly as the paper pads
    workloads to the AIE tile).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: A is {a.shape}, B is {b.shape}")
    if m % block_m or n % block_n or k % block_k:
        raise ValueError(
            f"GEMM {m}x{n}x{k} not divisible by blocks "
            f"({block_m},{block_n},{block_k})"
        )
    grid = (m // block_m, n // block_n, k // block_k)
    kernel = functools.partial(_gemm_kernel, n_k=grid[2])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), a.dtype),
        interpret=interpret,
    )(a, b)


def micro_gemm(a: jax.Array, b: jax.Array, *, interpret: bool = True) -> jax.Array:
    """The bare 32x32x32 AIE micro-kernel (single grid step)."""
    if a.shape != (MICRO_M, MICRO_K) or b.shape != (MICRO_K, MICRO_N):
        raise ValueError(f"micro_gemm expects 32x32x32, got {a.shape} @ {b.shape}")
    return tiled_gemm(a, b, interpret=interpret)


# ---------------------------------------------------------------------------
# Static performance estimators (no hardware timing under interpret=True).
# ---------------------------------------------------------------------------


def vmem_footprint_bytes(
    block_m: int, block_n: int, block_k: int, dtype_bytes: int = 4
) -> int:
    """Resident VMEM bytes for one grid step: A-block + B-block + C-block.

    The TPU analogue of the AIE's 32 KB local scratchpad budget; used by
    the perf pass to pick block shapes that stay inside VMEM while
    maximizing arithmetic intensity.
    """
    return dtype_bytes * (
        block_m * block_k + block_k * block_n + block_m * block_n
    )


def mxu_utilization(block_m: int, block_n: int, block_k: int, mxu: int = 128) -> float:
    """Fraction of MXU lanes a block matmul keeps busy (128x128 systolic
    array): blocks below the MXU edge waste lanes, multiples use them fully."""

    def frac(d: int) -> float:
        return min(d, mxu) / mxu if d % mxu else 1.0

    return frac(block_m) * frac(block_n)


def arithmetic_intensity(
    block_m: int, block_n: int, block_k: int, dtype_bytes: int = 4
) -> float:
    """FLOPs per HBM byte moved for one grid step (C revisited in VMEM)."""
    flops = 2.0 * block_m * block_n * block_k
    bytes_moved = dtype_bytes * (block_m * block_k + block_k * block_n)
    return flops / bytes_moved


def grid_shape(m: int, n: int, k: int, bm: int, bn: int, bk: int) -> Tuple[int, int, int]:
    if m % bm or n % bn or k % bk:
        raise ValueError("dims must divide blocks")
    return (m // bm, n // bn, k // bk)
