//! END-TO-END driver (DESIGN.md §8, §11): serve a small transformer's
//! forward passes as whole-model **graph jobs** through the full stack
//! — socket daemon, wire protocol v4, coordinator DAG planner, and the
//! executor's residency arena.
//!
//! Each forward pass is ONE job: a DAG of the block's GEMMs chained
//! across layers (`GemmGraph::transformer`). The daemon plans the DAG
//! with one DSE per distinct shape (identical layers share plans),
//! executes it in topo order with intermediates resident in the
//! executor-owned arena — activations never round-trip through this
//! client — and streams back graph-level rollups: energy, average
//! power, GFLOPS/W, and critical-path vs summed latency.
//!
//! The trace is Qwen2.5-0.5B-shaped (hidden 896, FFN 4864): one prefill
//! pass (batched sequence, throughput objective) and a run of decode
//! steps (energy objective — the paper's edge scenario). Results are
//! recorded in EXPERIMENTS.md.
//!
//! Run with: `make artifacts && cargo run --release --example serve_llm`

use std::time::{Duration, Instant};

use versal_gemm::config::Config;
use versal_gemm::coordinator::GraphInput;
use versal_gemm::dse::Objective;
use versal_gemm::report::Lab;
use versal_gemm::server::client::Client;
use versal_gemm::server::daemon::{Daemon, DaemonOptions};
use versal_gemm::server::protocol::{GraphSpec, WireGraphResult};
use versal_gemm::server::Endpoint;
use versal_gemm::util::rng::Rng;
use versal_gemm::workloads::graph::GemmGraph;
use versal_gemm::workloads::models::qwen25_05b;

/// Transformer layers per forward pass. Two is enough to prove the
/// plan-sharing claim (layer 1's shapes repeat layer 0's exactly) while
/// keeping the CPU-backend matmuls affordable.
const N_LAYERS: usize = 2;
const DECODE_STEPS: usize = 8;

/// Build one forward pass as a wire graph spec: the layered DAG plus a
/// deterministic external buffer for every client-fed operand slot.
fn forward_pass(id: u64, seq: usize, objective: Objective, rng: &mut Rng) -> GraphSpec {
    let graph = GemmGraph::transformer(&qwen25_05b(), seq, N_LAYERS);
    let mut inputs = Vec::new();
    for (idx, slot) in graph.external_slots() {
        let data: Vec<f32> = (0..graph.slot_elems(idx, slot))
            .map(|_| rng.normal() as f32 * 0.1)
            .collect();
        inputs.push(GraphInput::new(&graph.nodes[idx].name, slot, data));
    }
    let mut spec = GraphSpec::from_graph(id, &graph, objective, inputs);
    spec.validate = true;
    spec
}

fn print_pass(name: &str, r: &WireGraphResult, wall: Duration) {
    println!(
        "{:<10} {:>5} nodes {:>9.1} {:>10} {:>9.2} {:>9.2} {:>9.3} {:>9.2} {:>10}",
        name,
        r.n_nodes,
        r.plan_time_us as f64 / 1e3,
        format!(
            "{}{}",
            r.plans_shared,
            if r.graph_cache_hit { "+dag" } else { "" }
        ),
        r.exec_sum_us.unwrap_or(0) as f64 / 1e3,
        r.exec_critical_us.unwrap_or(0) as f64 / 1e3,
        r.energy_j.unwrap_or(0.0),
        r.gflops_per_w.unwrap_or(0.0),
        format!("{:.2}s", wall.as_secs_f64()),
    );
}

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;

    // Boot the real daemon on a Unix socket and talk to it exactly the
    // way an external client would — no in-process shortcuts.
    let state_dir = std::env::temp_dir().join(format!("serve-llm-{}", std::process::id()));
    std::fs::create_dir_all(&state_dir)?;
    let endpoint = Endpoint::Unix(state_dir.join("daemon.sock"));
    let mut opts = DaemonOptions::new(endpoint.clone(), state_dir.clone());
    opts.artifacts = Some("artifacts".into());
    let daemon = Daemon::start(&cfg, lab.engine(), opts)?;
    let handle = std::thread::spawn(move || daemon.run());
    let mut client = Client::connect_retry(&endpoint, Duration::from_secs(30))?;

    println!(
        "== serve_llm: {} forward passes as graph jobs (Qwen2.5-0.5B-shaped, {} layers) ==",
        1 + DECODE_STEPS,
        N_LAYERS
    );
    println!(
        "{:<10} {:>11} {:>9} {:>10} {:>9} {:>9} {:>9} {:>9} {:>10}",
        "pass", "", "plan ms", "shared", "sum ms", "crit ms", "J", "GFLOPS/W", "wall"
    );

    let mut rng = Rng::new(0x57EE1);
    let mut energy_total = 0.0;
    let mut prefill_energy = 0.0;

    // Prefill: seq = 64, throughput objective.
    let started = Instant::now();
    client.submit_graph(&forward_pass(0, 64, Objective::Throughput, &mut rng))?;
    let r = client.next_graph_result()?;
    anyhow::ensure!(r.ok(), "prefill failed: {:?}", r.error);
    anyhow::ensure!(r.plans_shared > 0, "identical layers did not share a plan");
    print_pass("prefill", &r, started.elapsed());
    energy_total += r.energy_j.unwrap_or(0.0);
    prefill_energy += r.energy_j.unwrap_or(0.0);

    // Decode: seq = 32 batch of token positions, energy objective.
    for step in 0..DECODE_STEPS {
        let started = Instant::now();
        let id = 1 + step as u64;
        client.submit_graph(&forward_pass(id, 32, Objective::EnergyEfficiency, &mut rng))?;
        let r = client.next_graph_result()?;
        anyhow::ensure!(r.ok(), "decode{step} failed: {:?}", r.error);
        if step > 0 {
            anyhow::ensure!(
                r.graph_cache_hit,
                "repeat decode DAG missed the graph-level plan cache"
            );
        }
        print_pass(&format!("decode{step}"), &r, started.elapsed());
        energy_total += r.energy_j.unwrap_or(0.0);
    }

    let stats = client.stats()?;
    println!("\n== summary ==");
    println!(
        "graph jobs served:      {:.0} ({:.0} nodes executed; backend {})",
        stats.get("graph_jobs").unwrap_or(0.0),
        stats.get("graph_nodes_executed").unwrap_or(0.0),
        stats.backend
    );
    println!(
        "plan sharing:           {:.0} node plans shared across identical layers, \
         {:.0} DSE runs total",
        stats.get("plans_shared").unwrap_or(0.0),
        stats.get("cache_misses").unwrap_or(0.0)
    );
    println!(
        "peak resident:          {:.1} KiB of intermediates held daemon-side \
         (zero client round-trips)",
        stats.get("resident_bytes_peak").unwrap_or(0.0) / 1024.0
    );
    println!(
        "executed energy:        {energy_total:.3} J total — {prefill_energy:.3} J prefill, \
         {:.3} J per decode step",
        (energy_total - prefill_energy) / DECODE_STEPS as f64
    );

    client.shutdown()?;
    handle
        .join()
        .map_err(|_| anyhow::anyhow!("daemon thread panicked"))??;
    let _ = std::fs::remove_dir_all(&state_dir);
    Ok(())
}
