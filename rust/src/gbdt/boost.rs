//! Gradient boosting over regression trees (squared loss).
//!
//! Equivalent in spirit to the paper's XGBoost setup: shrinkage, row
//! subsampling, column subsampling per split, L2 leaf regularization,
//! and optional early stopping on a validation split.

use crate::config::TrainConfig;
use crate::gbdt::forest::CompiledForest;
use crate::gbdt::tree::{BinnedMatrix, FeatureMatrix, RegressionTree, TreeParams};
use crate::util::json::{arr, num, obj, Json};
use crate::util::rng::Rng;

/// A fitted GBDT regressor.
#[derive(Debug, Clone, PartialEq)]
pub struct Gbdt {
    pub base: f64,
    pub learning_rate: f64,
    pub trees: Vec<RegressionTree>,
}

impl Gbdt {
    /// Fit with the given hyper-parameters. If `valid` is provided,
    /// training stops once validation MSE fails to improve for
    /// `patience` rounds (keeping the best prefix). Bins `x` once and
    /// delegates to [`Gbdt::fit_with_bins`]; callers fitting several
    /// models on the same matrix should bin once themselves.
    pub fn fit(
        x: &FeatureMatrix,
        y: &[f64],
        cfg: &TrainConfig,
        valid: Option<(&FeatureMatrix, &[f64])>,
        rng: &mut Rng,
    ) -> Gbdt {
        let binned = BinnedMatrix::build(x);
        Gbdt::fit_with_bins(x, &binned, y, cfg, valid, rng)
    }

    /// Fit against a shared pre-binned view of `x` (histogram split
    /// finding; see [`BinnedMatrix`]).
    pub fn fit_with_bins(
        x: &FeatureMatrix,
        binned: &BinnedMatrix,
        y: &[f64],
        cfg: &TrainConfig,
        valid: Option<(&FeatureMatrix, &[f64])>,
        rng: &mut Rng,
    ) -> Gbdt {
        assert_eq!(x.n_rows, y.len());
        assert!(x.n_rows > 0);
        let base = y.iter().sum::<f64>() / y.len() as f64;
        let params = TreeParams {
            max_depth: cfg.max_depth,
            min_samples_leaf: cfg.min_samples_leaf,
            lambda: cfg.lambda,
            colsample: cfg.colsample,
        };
        let mut model = Gbdt {
            base,
            learning_rate: cfg.learning_rate,
            trees: Vec::with_capacity(cfg.n_trees),
        };

        // Current predictions on train (and optional validation) set.
        let mut pred: Vec<f64> = vec![base; x.n_rows];
        let mut vpred: Vec<f64> = valid.map(|(vx, _)| vec![base; vx.n_rows]).unwrap_or_default();
        let mut best_vmse = f64::INFINITY;
        let mut best_len = 0usize;
        let patience = 25usize;

        let n_sub = ((x.n_rows as f64 * cfg.subsample).round() as usize).clamp(1, x.n_rows);
        let mut residuals = vec![0.0; x.n_rows];
        for round in 0..cfg.n_trees {
            for i in 0..x.n_rows {
                residuals[i] = y[i] - pred[i];
            }
            let indices = if n_sub == x.n_rows {
                (0..x.n_rows).collect::<Vec<_>>()
            } else {
                rng.sample_indices(x.n_rows, n_sub)
            };
            let tree = RegressionTree::fit_binned(x, binned, &residuals, &indices, &params, rng);
            for i in 0..x.n_rows {
                pred[i] += cfg.learning_rate * tree.predict_one(x.row(i));
            }
            model.trees.push(tree);

            if let Some((vx, vy)) = valid {
                let tree = model.trees.last().unwrap();
                let mut vmse = 0.0;
                for i in 0..vx.n_rows {
                    vpred[i] += cfg.learning_rate * tree.predict_one(vx.row(i));
                    let e = vy[i] - vpred[i];
                    vmse += e * e;
                }
                vmse /= vx.n_rows as f64;
                if vmse < best_vmse - 1e-12 {
                    best_vmse = vmse;
                    best_len = model.trees.len();
                } else if model.trees.len() - best_len >= patience {
                    model.trees.truncate(best_len);
                    break;
                }
            }
            let _ = round;
        }
        if valid.is_some() && best_len > 0 {
            model.trees.truncate(best_len);
        }
        model
    }

    #[inline]
    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut acc = self.base;
        for t in &self.trees {
            acc += self.learning_rate * t.predict_one(row);
        }
        acc
    }

    /// Per-row reference path (the equivalence oracle for the compiled
    /// forest); batch callers should prefer [`Gbdt::predict_batch`].
    pub fn predict(&self, x: &FeatureMatrix) -> Vec<f64> {
        (0..x.n_rows).map(|i| self.predict_one(x.row(i))).collect()
    }

    /// Batched prediction through the compiled-forest engine: flatten
    /// the trees into one arena (O(nodes), negligible next to a fit)
    /// and traverse row-blocked. Bit-identical to [`Gbdt::predict`].
    pub fn predict_batch(&self, x: &FeatureMatrix) -> Vec<f64> {
        CompiledForest::compile_single(self).predict_output(0, x)
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }

    // -- persistence ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("base", num(self.base)),
            ("learning_rate", num(self.learning_rate)),
            ("trees", arr(self.trees.iter().map(|t| t.to_json()))),
        ])
    }

    pub fn from_json(json: &Json) -> anyhow::Result<Gbdt> {
        let trees = json
            .get("trees")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("missing trees"))?
            .iter()
            .map(RegressionTree::from_json)
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Gbdt {
            base: json.req_f64("base")?,
            learning_rate: json.req_f64("learning_rate")?,
            trees,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::r2;

    fn synth(n: usize, f: impl Fn(f64, f64, f64) -> f64, seed: u64) -> (FeatureMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(0.0, 10.0);
            let b = rng.range_f64(0.0, 10.0);
            let c = rng.range_f64(0.0, 10.0);
            rows.push(vec![a, b, c]);
            y.push(f(a, b, c));
        }
        (FeatureMatrix::from_rows(&rows), y)
    }

    fn quick_cfg() -> TrainConfig {
        TrainConfig {
            n_trees: 80,
            max_depth: 4,
            learning_rate: 0.15,
            min_samples_leaf: 2,
            subsample: 0.9,
            colsample: 1.0,
            lambda: 1.0,
            ..TrainConfig::default()
        }
    }

    #[test]
    fn learns_nonlinear_function() {
        let (x, y) = synth(800, |a, b, c| a * b + (c * 1.3).sin() * 5.0, 11);
        let (xt, yt) = synth(200, |a, b, c| a * b + (c * 1.3).sin() * 5.0, 12);
        let mut rng = Rng::new(0);
        let model = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut rng);
        let pred = model.predict(&xt);
        let score = r2(&yt, &pred);
        assert!(score > 0.9, "r2 {score}");
    }

    #[test]
    fn boosting_improves_over_single_tree() {
        let (x, y) = synth(500, |a, b, _| (a - 5.0) * (b - 5.0), 21);
        let (xt, yt) = synth(200, |a, b, _| (a - 5.0) * (b - 5.0), 22);
        let mut rng = Rng::new(1);
        let one = Gbdt::fit(
            &x,
            &y,
            &TrainConfig {
                n_trees: 1,
                learning_rate: 1.0,
                ..quick_cfg()
            },
            None,
            &mut rng,
        );
        let mut rng2 = Rng::new(1);
        let many = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut rng2);
        let r_one = r2(&yt, &one.predict(&xt));
        let r_many = r2(&yt, &many.predict(&xt));
        assert!(r_many > r_one, "{r_many} <= {r_one}");
    }

    #[test]
    fn constant_target_predicts_constant() {
        let (x, _) = synth(50, |_, _, _| 0.0, 31);
        let y = vec![7.5; 50];
        let mut rng = Rng::new(2);
        let model = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut rng);
        for i in 0..x.n_rows {
            assert!((model.predict_one(x.row(i)) - 7.5).abs() < 1e-9);
        }
    }

    #[test]
    fn early_stopping_truncates() {
        let (x, y) = synth(400, |a, _, _| a, 41);
        let (vx, vy) = synth(100, |a, _, _| a, 42);
        let mut rng = Rng::new(3);
        let cfg = TrainConfig {
            n_trees: 400,
            ..quick_cfg()
        };
        let model = Gbdt::fit(&x, &y, &cfg, Some((&vx, &vy)), &mut rng);
        assert!(model.n_trees() < 400, "early stopping never triggered");
    }

    #[test]
    fn deterministic_given_seed() {
        let (x, y) = synth(200, |a, b, c| a + b * c, 51);
        let m1 = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut Rng::new(9));
        let m2 = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut Rng::new(9));
        assert_eq!(m1, m2);
    }

    #[test]
    fn predict_batch_bit_matches_predict() {
        let (x, y) = synth(300, |a, b, c| a * b - c, 71);
        let model = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut Rng::new(6));
        assert_eq!(model.predict_batch(&x), model.predict(&x));
    }

    #[test]
    fn json_roundtrip_preserves_predictions() {
        let (x, y) = synth(150, |a, b, _| a * 2.0 + b, 61);
        let model = Gbdt::fit(&x, &y, &quick_cfg(), None, &mut Rng::new(4));
        let back = Gbdt::from_json(&model.to_json()).unwrap();
        for i in 0..x.n_rows {
            assert_eq!(model.predict_one(x.row(i)), back.predict_one(x.row(i)));
        }
    }
}
