//! Bench: Fig. 6 / Fig. 7 machinery — GBDT training time, single-row
//! prediction latency, and the rendered accuracy tables.
use versal_gemm::config::Config;
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::report::{figures, Lab};
use versal_gemm::util::bench::{bench, once, report, report_throughput};

fn main() -> anyhow::Result<()> {
    let lab = Lab::prepare(Config::default(), "data".into())?;
    println!("== bench: model training / prediction (Fig. 6 / Fig. 7) ==");
    let model = once("train L/P/R bundle (full dataset)", || {
        Predictors::train(&lab.dataset, &lab.cfg, FeatureSet::SetIAndII)
    });
    let p = &lab.dataset.points[0];
    let stats = bench(1000, 100_000, || {
        std::hint::black_box(model.predict(&p.gemm, &p.tiling));
    });
    report("predict one candidate (L+P+R)", &stats);
    report_throughput("prediction throughput", &stats, 1.0, "candidates");
    println!("{}", once("render fig6", || figures::fig6_r2_vs_training_size(&lab)));
    println!("{}", once("render fig7", || figures::fig7_prediction_error(&lab)));
    Ok(())
}
