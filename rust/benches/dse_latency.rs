//! Bench: online-phase DSE wall-clock per workload (paper §V-A: the
//! ML-driven DSE completes in < 2 s per workload). Exercises the
//! streaming path: lazy candidate iterator -> PREDICT_CHUNK-sized
//! batched GBDT predictions -> incremental Pareto front.
use versal_gemm::config::Config;
use versal_gemm::report::Lab;
use versal_gemm::util::bench::{bench, report, report_throughput};
use versal_gemm::workloads::eval_workloads;

fn main() -> anyhow::Result<()> {
    let lab = Lab::prepare(Config::default(), "data".into())?;
    let engine = lab.engine();
    println!(
        "== bench: streaming DSE latency per eval workload (paper: < 2 s; chunk = {}) ==",
        versal_gemm::dse::PREDICT_CHUNK
    );
    let mut worst = 0.0f64;
    for w in eval_workloads() {
        let stats = bench(1, 5, || {
            let r = engine.explore(&w.gemm).unwrap();
            std::hint::black_box(r.n_feasible);
        });
        let r = engine.explore(&w.gemm)?;
        report(&format!("{} {} ({} cands)", w.id, w.gemm.label(), r.n_candidates), &stats);
        report_throughput("  prediction rate", &stats, r.n_candidates as f64, "candidates");
        worst = worst.max(stats.median.as_secs_f64());
        assert!(stats.median.as_secs_f64() < 2.0, "{} DSE exceeded 2 s", w.id);
    }
    println!("worst-case median DSE: {:.3} s — within the paper's 2 s budget", worst);
    Ok(())
}
