//! `versal-gemm` CLI — leader entrypoint for the framework.
//!
//! Subcommands mirror the paper's workflow:
//! * `dataset`  — offline phase: generate the ~6000-design dataset;
//! * `train`    — fit the L/P/R GBDT models (optionally with search);
//! * `dse`      — online phase: Pareto-optimal mapping for one GEMM;
//! * `report`   — regenerate any paper figure/table (see DESIGN.md §8);
//! * `serve`    — boot the coordinator and stream GEMM jobs through the
//!   selected execution backend (PJRT over the AOT Pallas kernels when
//!   artifacts exist, the blocked CPU GEMM otherwise, or the VCK190
//!   simulator via `--backend sim`);
//! * `validate` — numerics check of the PJRT runtime vs the reference;
//! * `lint`     — project-native static analysis of the serving-stack
//!   invariants (see DESIGN.md §5); run before pushing.

use std::path::PathBuf;
use std::time::{Duration, Instant};

use versal_gemm::config::Config;
use versal_gemm::coordinator::{
    Admission, BackendChoice, Coordinator, CoordinatorOptions, CpuProfileChoice, FaultPlan,
};
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::Objective;
use versal_gemm::features::FeatureSet;
use versal_gemm::models::Predictors;
use versal_gemm::report::{render, Lab};
use versal_gemm::runtime::{matmul_ref, max_abs_diff, GemmEngine};
use versal_gemm::server::client::Client;
use versal_gemm::server::daemon::{Daemon, DaemonOptions};
use versal_gemm::server::state::{self, StateFile};
use versal_gemm::server::{demo_job_specs, demo_jobs, safe_rate, Endpoint};
use versal_gemm::util::cli::Args;
use versal_gemm::util::rng::Rng;
use versal_gemm::versal::{BufferPlacement, VersalSim};
use versal_gemm::workloads::{eval_workloads, training_workloads, Gemm};

const USAGE: &str = "\
versal-gemm — energy/performance-optimal GEMM mapping for Versal ACAP

USAGE:
  versal-gemm <subcommand> [options]

SUBCOMMANDS:
  dataset   --out data/dataset.csv             generate the offline-phase dataset
  train     --data-dir data [--search N]       train the L/P/R predictors
  dse       --gemm MxNxK [--objective throughput|energy] [--data-dir data]
  report    <fig1|fig3|fig4|fig6|fig7|fig8|fig9|fig10|table2|table3|model-quality|all>
            [--data-dir data] [--out file]
  serve     run the demo job stream through an in-process coordinator
            (drains + persists the plan cache on SIGINT/SIGTERM), or
            manage the socket daemon via an action:
    serve start    spawn the daemon in the background, wait until ready
    serve run      run the daemon in the foreground (what `start` spawns)
    serve stop     graceful shutdown (drain, persist cache, exit)
    serve status   PID + live stats of the running daemon
    serve submit   push --jobs N demo jobs through the socket client
    serve submit-graph  submit one whole-model forward pass as a single
                   graph job (a DAG of GEMMs; plans are shared across
                   identical layers, intermediates stay daemon-resident)
    serve drain    close admission, finish in-flight, persist the cache
  serve options:
            [--jobs N] [--plan-only] [--artifacts artifacts] [--data-dir data]
            [--model qwen|llama|deit|bert] [--layers N] [--seq M]
                                       graph-job shape (submit-graph only;
                                       defaults: qwen, 2 layers, seq 32)
            [--state-dir DIR]          daemon state/log/socket dir
                                       (default: .versal-gemm)
            [--socket path|tcp://host:port] daemon endpoint
                                       (default: <state-dir>/daemon.sock)
            [--force]                  take over a live daemon (start/run)
            [--quick-lab]              small in-memory dataset/model (CI smoke)
            [--planners N] [--cache-shards N] [--cache-capacity N]
            [--plan-cache file.json|none] persist/warm the plan cache
                                       (daemon default: <state-dir>/plan-cache.json)
            [--max-queue N]            bound on queued + coalesced-parked jobs
            [--admission block|reject] full-queue policy (default: block)
            [--dse-threads N]          width of the process-wide DSE worker pool
                                       (default: PALLAS_DSE_THREADS, else cores)
            [--backend pjrt|cpu|sim|auto] execution backend (default: auto =
                                       PJRT if the artifacts load, else CPU)
            [--cpu-profile generic|l2-small|l2-large|auto] packed-panel kernel
                                       blocking for cpu/sim (default: auto =
                                       probe L2 size once at startup)
            [--job-deadline-ms N]      per-attempt execution deadline; jobs
                                       run watchdog-supervised and time out
                                       with a typed error (0/absent: none)
            [--retry-budget N]         max retries per job on transient
                                       failures (default: 3)
            [--faults SPEC]            deterministic fault injection, e.g.
                                       'err:p=0.2;hang:p=0.05,ms=500;seed:7'
                                       (also via PALLAS_FAULTS; testing only)
            [--timeout SECS]           client-side socket I/O timeout for
                                       status/submit/drain/stop (default: 30,
                                       0 = wait forever)
  validate  [--artifacts artifacts]            PJRT runtime vs reference GEMM
  sweep     --model qwen|llama|deit [--seqs 32,64,..] per-layer mapping sweep
  lint      [--format table|json] [--out report.json] [--baseline file]
            static analysis of the serving-stack invariants (nan-ordering,
            panic-freedom, lock-hygiene, wire-exhaustiveness, stats-parity);
            exits nonzero on unwaived findings
  info                                         board + workload summary

COMMON OPTIONS:
  --config path.toml     override defaults (board/sim/train/dataset sections)
  --data-dir DIR         dataset + model cache directory (default: data)
";

fn main() {
    let args = Args::from_env();
    let code = match run(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

fn run(args: &Args) -> anyhow::Result<()> {
    let cfg = Config::from_args(args)?;
    let data_dir = PathBuf::from(args.opt_or("data-dir", "data"));
    match args.subcommand.as_deref() {
        Some("dataset") => cmd_dataset(args, &cfg),
        Some("train") => cmd_train(args, &cfg, data_dir),
        Some("dse") => cmd_dse(args, &cfg, data_dir),
        Some("report") => cmd_report(args, cfg, data_dir),
        Some("serve") => cmd_serve(args, cfg, data_dir),
        Some("validate") => cmd_validate(args),
        Some("sweep") => cmd_sweep(args, cfg, data_dir),
        Some("lint") => cmd_lint(args),
        Some("info") => cmd_info(&cfg),
        _ => {
            print!("{USAGE}");
            Ok(())
        }
    }
}

fn cmd_dataset(args: &Args, cfg: &Config) -> anyhow::Result<()> {
    let out = PathBuf::from(args.opt_or("out", "data/dataset.csv"));
    eprintln!("generating offline-phase dataset (18 workloads)...");
    let started = std::time::Instant::now();
    let ds = Dataset::generate(cfg, &training_workloads());
    ds.save(cfg, &out)?;
    println!(
        "wrote {} designs across {} workloads to {} in {:.1}s",
        ds.len(),
        ds.workload_ids().len(),
        out.display(),
        started.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_train(args: &Args, cfg: &Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let ds_path = data_dir.join("dataset.csv");
    let ds = if ds_path.exists() {
        Dataset::load(cfg, &ds_path)?
    } else {
        eprintln!("no dataset at {}; generating...", ds_path.display());
        let ds = Dataset::generate(cfg, &training_workloads());
        ds.save(cfg, &ds_path)?;
        ds
    };
    let mut cfg = cfg.clone();
    cfg.train.search_trials = args.opt_usize("search", cfg.train.search_trials)?;
    if cfg.train.search_trials > 0 {
        eprintln!(
            "hyper-parameter search: {} trials (5-fold CV)...",
            cfg.train.search_trials
        );
        let x = ds.feature_matrix(cfg.board.micro_tile, FeatureSet::SetIAndII);
        let y = ds.targets(&cfg).latency_s;
        let (best, score) = versal_gemm::gbdt::cv::search_hyperparams(&x, &y, &cfg.train, true);
        println!(
            "best hyper-params: trees={} depth={} lr={:.3} (CV MAPE {:.2}%, R2 {:.4})",
            best.n_trees, best.max_depth, best.learning_rate, score.mean_mape, score.mean_r2
        );
        cfg.train = best;
    }
    let started = std::time::Instant::now();
    let model = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
    let out = data_dir.join("predictors.json");
    model.save(&out)?;
    println!(
        "trained L/P/R models on {} designs in {:.1}s -> {}",
        ds.len(),
        started.elapsed().as_secs_f64(),
        out.display()
    );
    Ok(())
}

fn cmd_dse(args: &Args, cfg: &Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let (m, n, k) = args
        .opt_gemm_dims("gemm")?
        .ok_or_else(|| anyhow::anyhow!("--gemm MxNxK is required"))?;
    let g = Gemm::new(m, n, k);
    let objective = Objective::parse(args.opt_or("objective", "throughput"))?;
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let engine = lab.engine();
    let r = engine.explore(&g)?;
    let sel = r.select(objective);
    println!(
        "GEMM {} — {} candidates, {} feasible, Pareto front of {} ({} ms)",
        g.label(),
        r.n_candidates,
        r.n_feasible,
        r.pareto.len(),
        r.elapsed.as_millis()
    );
    println!(
        "selected ({}): {}  #AIE={}",
        objective.label(),
        sel.tiling.label(),
        sel.tiling.n_aie()
    );
    println!(
        "predicted: {:.1} GFLOP/s, {:.1} W, {:.2} GFLOP/s/W",
        sel.gflops, sel.prediction.power_w, sel.energy_eff
    );
    let sim = VersalSim::new(cfg);
    match sim.evaluate(&g, &sel.tiling, BufferPlacement::UramFirst) {
        Ok(mea) => println!(
            "simulated: {:.1} GFLOP/s, {:.1} W, {:.2} GFLOP/s/W (latency {:.3} ms)",
            mea.gflops,
            mea.power_w,
            mea.energy_eff,
            mea.latency_s * 1e3
        ),
        Err(e) => println!("simulated: design failed ({e})"),
    }
    println!("\nPareto front (predicted):");
    for c in &r.pareto {
        println!(
            "  {:<28} #AIE={:<4} {:.1} GFLOP/s  {:.2} GFLOP/s/W",
            c.tiling.label(),
            c.tiling.n_aie(),
            c.gflops,
            c.energy_eff
        );
    }
    Ok(())
}

fn cmd_report(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let lab = Lab::prepare(cfg, data_dir)?;
    let text = render(&lab, id)?;
    match args.opt("out") {
        Some(path) => {
            std::fs::write(path, &text)?;
            eprintln!("wrote report to {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

fn cmd_serve(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    match args.positional.first().map(String::as_str) {
        None => serve_inline(args, cfg, data_dir),
        Some("start") => serve_start(args),
        Some("run") => serve_run(args, cfg, data_dir),
        Some("stop") => serve_stop(args),
        Some("status") => serve_status(args),
        Some("submit") => serve_submit(args),
        Some("submit-graph") => serve_submit_graph(args),
        Some("drain") => serve_drain(args),
        Some(other) => anyhow::bail!(
            "unknown serve action `{other}` (start|run|stop|status|submit|\
             submit-graph|drain, or no action for the in-process demo stream)"
        ),
    }
}

/// Daemon state directory and socket endpoint from the common options.
fn serve_paths(args: &Args) -> (PathBuf, Endpoint) {
    let state_dir = PathBuf::from(args.opt_or("state-dir", ".versal-gemm"));
    let endpoint = match args.opt("socket") {
        Some(text) => Endpoint::parse(text),
        None => Endpoint::Unix(state_dir.join("daemon.sock")),
    };
    (state_dir, endpoint)
}

/// Coordinator options shared by the inline path and the daemon.
/// `default_cache` is the plan-cache path used when `--plan-cache` is
/// absent (`--plan-cache none` disables persistence entirely).
fn coordinator_options(
    args: &Args,
    default_cache: Option<PathBuf>,
) -> anyhow::Result<CoordinatorOptions> {
    let defaults = CoordinatorOptions::default();
    let cache_path = match args.opt("plan-cache") {
        Some("none") => None,
        Some(p) => Some(PathBuf::from(p)),
        None => default_cache,
    };
    Ok(CoordinatorOptions {
        n_shards: args.opt_usize("cache-shards", defaults.n_shards)?,
        cache_capacity: args.opt_usize("cache-capacity", defaults.cache_capacity)?,
        cache_path,
        max_queue_depth: args.opt_usize("max-queue", defaults.max_queue_depth)?,
        admission: match args.opt("admission") {
            Some(text) => Admission::parse(text)?,
            None => defaults.admission,
        },
        dse_threads: match args.opt_usize("dse-threads", 0)? {
            0 => None,
            n => Some(n),
        },
        backend: BackendChoice::parse(args.opt_or("backend", "auto"))?,
        cpu_profile: CpuProfileChoice::parse(args.opt_or("cpu-profile", "auto"))?,
        job_deadline_ms: match args.opt_u64("job-deadline-ms", 0)? {
            0 => None,
            ms => Some(ms),
        },
        retry_budget: args.opt_u64("retry-budget", defaults.retry_budget as u64)? as u32,
        faults: match args.opt("faults") {
            Some(spec) => Some(FaultPlan::parse(spec)?),
            None => FaultPlan::from_env()?,
        },
    })
}

/// Client-side socket I/O timeout (`--timeout SECS`; `0` waits forever).
fn client_io_timeout(args: &Args) -> anyhow::Result<Option<Duration>> {
    Ok(match args.opt_u64("timeout", 30)? {
        0 => None,
        s => Some(Duration::from_secs(s)),
    })
}

/// Small in-memory lab (reduced dataset/model) for CI smoke runs —
/// mirrors the `--smoke` configuration of `benches/coordinator_serve`.
fn quick_lab() -> Lab {
    let mut cfg = Config::default();
    cfg.dataset.top_k = 12;
    cfg.dataset.bottom_k = 8;
    cfg.dataset.random_k = 60;
    cfg.train.n_trees = 120;
    cfg.train.learning_rate = 0.15;
    let ds = Dataset::generate(&cfg, &training_workloads());
    let predictors = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
    Lab::in_memory(cfg, ds, predictors)
}

/// `serve` with no action: the demo job stream through an in-process
/// coordinator. SIGINT/SIGTERM route through the drain path — submits
/// stop, in-flight jobs finish, the plan cache persists, and the final
/// summary reflects what actually ran (a second signal cancels hard).
fn serve_inline(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let n_jobs = args.opt_usize("jobs", 24)?;
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let n_planners = args.opt_usize("planners", 2)?;
    let options = coordinator_options(args, None)?;
    let fault_label = options.faults.as_ref().map(|p| p.label());
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let mut coord =
        Coordinator::start_with(&cfg, lab.engine(), Some(artifacts), n_planners, options);

    state::install_signal_handlers();
    let sig0 = state::signals_received();

    // A small LLM-inference-like job stream over the eval workloads.
    let jobs = demo_jobs(n_jobs, false);
    let total = jobs.len();
    let started = Instant::now();
    let mut results = Vec::with_capacity(total);
    let mut interrupted = false;
    for job in jobs {
        if state::signals_received() > sig0 {
            interrupted = true;
            break;
        }
        coord.submit(job);
        while let Some(r) = coord.try_next_result() {
            results.push(r);
        }
    }
    if interrupted {
        eprintln!("serve: interrupted — draining in-flight jobs");
        coord.begin_drain();
    }
    let mut cancelled = false;
    while coord.pending() > 0 {
        if !cancelled && state::signals_received() > sig0 + 1 {
            eprintln!("serve: second signal — cancelling in-flight work");
            coord.shutdown(); // remaining jobs surface as error results
            cancelled = true;
        }
        match coord.try_next_result() {
            Some(r) => results.push(r),
            None => std::thread::sleep(Duration::from_millis(2)),
        }
    }
    let wall = started.elapsed();
    let mut ok = 0usize;
    for r in &results {
        if r.error.is_none() {
            ok += 1;
        } else {
            eprintln!("job {} failed: {:?}", r.id, r.error);
        }
        if let Some(err) = r.validation_err {
            anyhow::ensure!(err < 1e-2, "validation failed on job {}: {err}", r.id);
        }
    }
    let stats = coord.stats();
    println!(
        "served {ok}/{} jobs in {:.2}s via backend `{}` (kernel profile {}, \
         packed-panel {:.2} GFLOP/s) — {:.2} jobs/s, \
         exec throughput {:.2} GFLOP/s, executed energy {:.2} J \
         ({:.2} GFLOPS/W aggregate), \
         cache {} hits / {} misses / {} evictions ({:.0}% hit rate), \
         {} coalesced plans / {} rejected jobs / queue peak {}, \
         p50 plan latency {:.3} ms, dse pool {} threads / stage-2 gate \
         skipped {:.0}% of candidate rows, forest compile {:.1} ms / \
         predict {:.0} rows/s, simulated VCK190 energy {:.1} J",
        results.len(),
        wall.as_secs_f64(),
        coord.backend_name(),
        coord.kernel_profile().unwrap_or("-"),
        stats.cpu_gemm_gflops,
        safe_rate(results.len() as f64, wall.as_secs_f64()),
        stats.executed_gflops(),
        stats.executed_energy_j,
        stats.executed_gflops_per_w,
        stats.cache_hits,
        stats.cache_misses,
        stats.cache_evictions,
        100.0 * stats.cache_hit_rate,
        stats.coalesced_plans,
        stats.rejected_jobs,
        stats.queue_depth_peak,
        stats.plan_p50_ms,
        stats.dse_pool_threads,
        100.0 * stats.gate_skip_rate,
        stats.forest_compile_ms,
        stats.predict_rows_per_s,
        stats.simulated_energy_j
    );
    println!(
        "resilience: {} retries / {} timeouts / {} failovers, \
         {} breaker(s) not closed{}",
        stats.retries_total,
        stats.timeouts_total,
        stats.failovers_total,
        stats.breaker_state,
        match &fault_label {
            Some(l) => format!(", fault plan `{l}` injected {} faults", stats.faults_injected),
            None => String::new(),
        }
    );
    coord.shutdown();
    Ok(())
}

/// Foreground daemon (what `serve start` spawns).
fn serve_run(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    let (state_dir, endpoint) = serve_paths(args);
    let lab = if args.flag("quick-lab") {
        quick_lab()
    } else {
        Lab::prepare(cfg, data_dir)?
    };
    let cfg = lab.cfg.clone();
    let default_cache = state_dir.join("plan-cache.json");
    let mut opts = DaemonOptions::new(endpoint, state_dir);
    opts.coordinator = coordinator_options(args, Some(default_cache))?;
    opts.n_planners = args.opt_usize("planners", 2)?;
    opts.artifacts = Some(PathBuf::from(args.opt_or("artifacts", "artifacts")));
    opts.log_rotate_bytes = args.opt_u64("log-rotate-bytes", 1 << 20)?;
    opts.force = args.flag("force");
    state::install_signal_handlers();
    let daemon = Daemon::start(&cfg, lab.engine(), opts)?;
    let summary = daemon.run()?;
    println!(
        "daemon exit: {} submitted / {} completed / {} failed / {} dropped \
         in {:.1}s ({:.2} jobs/s)",
        summary.jobs_submitted,
        summary.jobs_completed,
        summary.jobs_failed,
        summary.results_dropped,
        summary.uptime.as_secs_f64(),
        safe_rate(summary.jobs_completed as f64, summary.uptime.as_secs_f64())
    );
    Ok(())
}

/// Spawn `serve run` detached (own session, output to daemon.out) and
/// wait until its socket answers a stats request.
fn serve_start(args: &Args) -> anyhow::Result<()> {
    let (state_dir, endpoint) = serve_paths(args);
    let state_path = state_dir.join("daemon.json");
    if let Some(prev) = StateFile::load(&state_path)? {
        if state::pid_alive(prev.pid) && !args.flag("force") {
            anyhow::bail!(
                "daemon already running (pid {} on {}); use `serve stop` or --force",
                prev.pid,
                prev.socket
            );
        }
    }
    std::fs::create_dir_all(&state_dir)?;
    let exe = std::env::current_exe()?;
    let mut cmd = std::process::Command::new(exe);
    cmd.arg("serve").arg("run");
    for (k, v) in &args.options {
        if k == "socket" || k == "state-dir" {
            continue; // re-appended in normalized form below
        }
        cmd.arg(format!("--{k}={v}"));
    }
    for f in &args.flags {
        if f != "foreground" {
            cmd.arg(format!("--{f}"));
        }
    }
    cmd.arg(format!("--state-dir={}", state_dir.display()));
    cmd.arg(format!("--socket={}", endpoint.label()));
    let out = std::fs::File::create(state_dir.join("daemon.out"))?;
    cmd.stdin(std::process::Stdio::null());
    cmd.stdout(out.try_clone()?);
    cmd.stderr(out);
    #[cfg(unix)]
    unsafe {
        use std::os::unix::process::CommandExt;
        // Detach from our session so the daemon survives this shell.
        cmd.pre_exec(|| {
            unsafe { state::sys::setsid() };
            Ok(())
        });
    }
    let mut child = cmd.spawn()?;
    // Startup covers dataset generation + model training on a cold
    // data dir, hence the generous default.
    let timeout = Duration::from_secs(args.opt_u64("start-timeout", 300)?);
    let deadline = Instant::now() + timeout;
    loop {
        if let Some(status) = child.try_wait()? {
            anyhow::bail!(
                "daemon exited during startup ({status}); see {}/daemon.out",
                state_dir.display()
            );
        }
        match Client::connect(&endpoint) {
            Ok(mut c) => {
                let s = c.stats()?;
                println!(
                    "daemon started (pid {}) on {} — state {}",
                    child.id(),
                    endpoint.label(),
                    s.state
                );
                return Ok(());
            }
            Err(e) => {
                if Instant::now() >= deadline {
                    return Err(e.context(format!(
                        "daemon not ready within {}s; see {}/daemon.out",
                        timeout.as_secs(),
                        state_dir.display()
                    )));
                }
                std::thread::sleep(Duration::from_millis(100));
            }
        }
    }
}

/// Graceful stop: SHUTDOWN over the socket (drain + cache persist),
/// SIGTERM as fallback (the daemon drains on signals too), then wait
/// for the PID to exit.
fn serve_stop(args: &Args) -> anyhow::Result<()> {
    let (state_dir, endpoint) = serve_paths(args);
    let state_path = state_dir.join("daemon.json");
    let Some(prev) = StateFile::load(&state_path)? else {
        println!("no daemon: state file {} not found", state_path.display());
        return Ok(());
    };
    if !state::pid_alive(prev.pid) {
        println!("stale daemon state (pid {} is dead); cleaning up", prev.pid);
        StateFile::remove(&state_path);
        if let Endpoint::Unix(p) = &endpoint {
            let _ = std::fs::remove_file(p);
        }
        return Ok(());
    }
    match Client::connect_with(&Endpoint::parse(&prev.socket), client_io_timeout(args)?) {
        Ok(mut c) => {
            let _ = c.shutdown();
        }
        Err(_) => {
            state::terminate(prev.pid);
        }
    }
    let timeout = Duration::from_secs(args.opt_u64("stop-timeout", 120)?);
    let deadline = Instant::now() + timeout;
    while state::pid_alive(prev.pid) {
        anyhow::ensure!(
            Instant::now() < deadline,
            "daemon (pid {}) still alive {}s after shutdown request",
            prev.pid,
            timeout.as_secs()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    println!("daemon (pid {}) stopped", prev.pid);
    Ok(())
}

fn serve_status(args: &Args) -> anyhow::Result<()> {
    let (state_dir, _) = serve_paths(args);
    let state_path = state_dir.join("daemon.json");
    let Some(prev) = StateFile::load(&state_path)? else {
        println!("no daemon (state file {} not found)", state_path.display());
        return Ok(());
    };
    let alive = state::pid_alive(prev.pid);
    println!(
        "daemon pid {} on {} (v{}) — {}",
        prev.pid,
        prev.socket,
        prev.version,
        if alive { "alive" } else { "DEAD (stale state file)" }
    );
    if !alive {
        return Ok(());
    }
    let mut c = Client::connect_with(&Endpoint::parse(&prev.socket), client_io_timeout(args)?)?;
    let s = c.stats()?;
    println!("state {} (up {:.1}s), backend {}", s.state, s.uptime_s, s.backend);
    for (k, v) in &s.fields {
        println!("  {k:<24} {v:.3}");
    }
    Ok(())
}

/// Push the demo job stream through a running daemon's socket.
fn serve_submit(args: &Args) -> anyhow::Result<()> {
    let (_, endpoint) = serve_paths(args);
    let n_jobs = args.opt_usize("jobs", 24)?;
    let plan_only = args.flag("plan-only");
    let mut client =
        Client::connect_retry_with(&endpoint, Duration::from_secs(10), client_io_timeout(args)?)?;
    let specs = demo_job_specs(n_jobs, plan_only);
    let started = Instant::now();
    let results = client.submit_burst(&specs)?;
    let wall = started.elapsed();
    let mut ok = 0usize;
    for r in &results {
        match &r.error {
            None => ok += 1,
            Some(e) => eprintln!("job {} failed: {e}", r.id),
        }
        if let Some(err) = r.validation_err {
            anyhow::ensure!(err < 1e-2, "validation failed on job {}: {err}", r.id);
        }
    }
    let energy: f64 = results.iter().filter_map(|r| r.energy_j).sum();
    let s = client.stats()?;
    println!(
        "submitted {} jobs over {}: {ok} ok / {} failed in {:.2}s \
         ({:.2} jobs/s), executed energy {:.2} J; daemon state {}, \
         {:.0} lifetime completed, {:.0}% cache hit rate",
        results.len(),
        endpoint.label(),
        results.len() - ok,
        wall.as_secs_f64(),
        safe_rate(results.len() as f64, wall.as_secs_f64()),
        energy,
        s.state,
        s.get("jobs_completed").unwrap_or(0.0),
        100.0 * s.get("cache_hit_rate").unwrap_or(0.0)
    );
    anyhow::ensure!(ok == results.len(), "{} jobs failed", results.len() - ok);
    Ok(())
}

/// Submit one whole-model forward pass as a single graph job over the
/// socket: the daemon plans the DAG (one DSE shared across identical
/// layers), executes it in topo order with intermediates resident in
/// the executor's arena, and streams back graph-level rollups only.
fn serve_submit_graph(args: &Args) -> anyhow::Result<()> {
    use versal_gemm::coordinator::GraphInput;
    use versal_gemm::server::protocol::GraphSpec;
    use versal_gemm::workloads::graph::GemmGraph;
    use versal_gemm::workloads::models::{bert_base, deit_base, llama3_1b, qwen25_05b};

    let (_, endpoint) = serve_paths(args);
    let model = args.opt_or("model", "qwen");
    let spec = match model {
        "qwen" => qwen25_05b(),
        "llama" => llama3_1b(),
        "deit" => deit_base(),
        "bert" => bert_base(),
        other => anyhow::bail!("unknown --model `{other}` (qwen|llama|deit|bert)"),
    };
    let layers = args.opt_usize("layers", 2)?.max(1);
    let seq = args.opt_usize("seq", 32)?.max(1);
    let objective = Objective::parse(args.opt_or("objective", "throughput"))?;
    let plan_only = args.flag("plan-only");
    let graph = GemmGraph::transformer(&spec, seq, layers);

    let mut inputs = Vec::new();
    if !plan_only {
        let mut rng = Rng::new(0xDA6);
        for (idx, slot) in graph.external_slots() {
            let data: Vec<f32> = (0..graph.slot_elems(idx, slot))
                .map(|_| rng.range_f64(-0.5, 0.5) as f32)
                .collect();
            inputs.push(GraphInput::new(&graph.nodes[idx].name, slot, data));
        }
    }
    let wire_spec = GraphSpec::from_graph(1, &graph, objective, inputs);

    let mut client =
        Client::connect_retry_with(&endpoint, Duration::from_secs(10), client_io_timeout(args)?)?;
    let started = Instant::now();
    client.submit_graph(&wire_spec)?;
    let r = client.next_graph_result()?;
    let wall = started.elapsed();
    if let Some(e) = &r.error {
        anyhow::bail!("graph job failed: {e}");
    }
    let s = client.stats()?;
    println!(
        "graph `{}` x{layers} layers (seq {seq}): {} nodes in {:.2}s over {}\n\
         plan {:.1} ms ({} plans shared{}), exec sum {:.1} ms / critical path {:.1} ms\n\
         energy {:.3} J, avg power {:.1} W, {:.2} GFLOPS/W, peak resident {} KiB\n\
         daemon lifetime: {:.0} graph jobs, {:.0} graph nodes executed, {:.0} plans shared",
        model,
        r.n_nodes,
        wall.as_secs_f64(),
        endpoint.label(),
        r.plan_time_us as f64 / 1e3,
        r.plans_shared,
        if r.graph_cache_hit { ", whole-DAG cache hit" } else { "" },
        r.exec_sum_us.unwrap_or(0) as f64 / 1e3,
        r.exec_critical_us.unwrap_or(0) as f64 / 1e3,
        r.energy_j.unwrap_or(0.0),
        r.avg_power_w.unwrap_or(0.0),
        r.gflops_per_w.unwrap_or(0.0),
        r.resident_bytes_peak / 1024,
        s.get("graph_jobs").unwrap_or(0.0),
        s.get("graph_nodes_executed").unwrap_or(0.0),
        s.get("plans_shared").unwrap_or(0.0),
    );
    Ok(())
}

fn serve_drain(args: &Args) -> anyhow::Result<()> {
    let (_, endpoint) = serve_paths(args);
    let mut client = Client::connect_with(&endpoint, client_io_timeout(args)?)?;
    let s = client.drain()?;
    println!(
        "drained: state {} after {:.1}s — {:.0} completed / {:.0} failed, \
         {:.2} jobs/s lifetime, executed energy {:.2} J, {:.0}% cache hit rate",
        s.state,
        s.uptime_s,
        s.get("jobs_completed").unwrap_or(0.0),
        s.get("jobs_failed").unwrap_or(0.0),
        safe_rate(s.get("jobs_completed").unwrap_or(0.0), s.uptime_s),
        s.get("executed_energy_j").unwrap_or(0.0),
        100.0 * s.get("cache_hit_rate").unwrap_or(0.0)
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> anyhow::Result<()> {
    let artifacts = PathBuf::from(args.opt_or("artifacts", "artifacts"));
    let engine = GemmEngine::load(&artifacts)?;
    println!("platform: {}", engine.platform());
    let mut rng = Rng::new(7);
    for (m, n, k) in [
        (32, 32, 32),
        (64, 64, 64),
        (128, 128, 128),
        (100, 200, 96),
        (32, 896, 896),
    ] {
        let a: Vec<f32> = (0..m * k).map(|_| rng.normal() as f32).collect();
        let b: Vec<f32> = (0..k * n).map(|_| rng.normal() as f32).collect();
        let got = engine.gemm(&a, &b, m, n, k)?;
        let want = matmul_ref(&a, &b, m, n, k);
        let err = max_abs_diff(&got, &want);
        println!("gemm {m}x{n}x{k}: max abs err {err:.2e}");
        anyhow::ensure!(err < 1e-2, "numerics check failed for {m}x{n}x{k}");
    }
    println!(
        "runtime validation OK ({} kernel invocations)",
        engine.invocations.get()
    );
    Ok(())
}

fn cmd_sweep(args: &Args, cfg: Config, data_dir: PathBuf) -> anyhow::Result<()> {
    use versal_gemm::workloads::models::{deit_base, llama3_1b, qwen25_05b};
    let spec = match args.opt_or("model", "qwen") {
        "qwen" => qwen25_05b(),
        "llama" => llama3_1b(),
        "deit" => deit_base(),
        other => anyhow::bail!("unknown model `{other}` (qwen|llama|deit)"),
    };
    let seqs: Vec<usize> = args
        .opt_or("seqs", "32,64,128,512")
        .split(',')
        .map(|v| v.parse().map_err(|_| anyhow::anyhow!("bad seq `{v}`")))
        .collect::<anyhow::Result<_>>()?;
    let lab = Lab::prepare(cfg.clone(), data_dir)?;
    let engine = lab.engine();
    let sim = VersalSim::new(&cfg);
    println!(
        "== {}: per-layer mappings across sequence lengths ==",
        spec.name
    );
    println!(
        "{:<14} {:>5} {:>18} {:>26} {:>10} {:>9} {:>9}",
        "layer", "seq", "gemm", "mapping", "GFLOP/s", "W", "GF/s/W"
    );
    for &seq in &seqs {
        for (name, g) in spec.working_set(seq, false) {
            let r = engine.explore(&g)?;
            let Some((sel, m)) =
                versal_gemm::dse::best_buildable(&r, &sim, &g, Objective::EnergyEfficiency)
            else {
                println!("{name:<14} {seq:>5} {:>18} (no buildable design)", g.label());
                continue;
            };
            println!(
                "{:<14} {:>5} {:>18} {:>26} {:>10.1} {:>9.1} {:>9.2}",
                name,
                seq,
                g.label(),
                sel.tiling.label(),
                m.gflops,
                m.power_w,
                m.energy_eff
            );
        }
    }
    Ok(())
}

/// Run the project lint rules over the repo (see DESIGN.md §5). Always
/// prints the selected format; `--out` additionally writes the JSON
/// report (the CI artifact). Exits nonzero when any finding is neither
/// waived nor baselined.
fn cmd_lint(args: &Args) -> anyhow::Result<()> {
    use versal_gemm::lint::{self, report as lint_report, Baseline};
    let root = PathBuf::from(args.opt_or("root", "."));
    let baseline_path = root.join(args.opt_or("baseline", "lint-baseline.json"));
    let baseline = Baseline::load(&baseline_path)?;
    let report = lint::run_at(&root, &baseline)?;
    let json = lint_report::render_json(&report);
    if let Some(path) = args.opt("out") {
        std::fs::write(path, &json)?;
        eprintln!("wrote lint report to {path}");
    }
    match args.opt_or("format", "table") {
        "json" => println!("{json}"),
        _ => print!("{}", lint_report::render_table(&report)),
    }
    let failing = report.count_unwaived();
    anyhow::ensure!(
        failing == 0,
        "lint: {failing} unwaived finding(s) — fix them, waive with \
         `// lint:allow(rule-id) reason`, or baseline"
    );
    Ok(())
}

fn cmd_info(cfg: &Config) -> anyhow::Result<()> {
    println!(
        "board: {} — {} AIEs @ {:.2} GHz ({} GFLOP/s peak), DDR {:.1} GB/s",
        cfg.board.name,
        cfg.board.aie_total,
        cfg.board.aie_clock_hz / 1e9,
        cfg.board.peak_gflops(),
        cfg.board.ddr_peak_bps / 1e9
    );
    println!("\ntraining workloads (offline phase):");
    for w in training_workloads() {
        println!("  {:<14} {:<12} {}", w.id, w.source, w.gemm.label());
    }
    println!("\nevaluation workloads G1..G13 (by increasing FLOPs):");
    for w in eval_workloads() {
        println!(
            "  {:<4} {:<22} {:<18} {:.2} GFLOP, AI {:.1}",
            w.id,
            w.source,
            w.gemm.label(),
            w.gemm.flops() / 1e9,
            w.gemm.arithmetic_intensity()
        );
    }
    Ok(())
}
