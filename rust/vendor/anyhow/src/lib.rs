//! Vendored, minimal `anyhow`-compatible error crate.
//!
//! The offline crate set has no registry access, so the subset of the
//! real `anyhow` API this repository uses is reimplemented here:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros,
//! and the [`Context`] extension trait. Errors are stored as a message
//! plus an optional source chain rendered into the message eagerly —
//! enough for a CLI/server that only ever Displays its errors.

use std::fmt;

/// A type-erased error: a rendered message (like `anyhow::Error`, it
/// deliberately does NOT implement `std::error::Error` so the blanket
/// `From<E: std::error::Error>` conversion below stays coherent).
pub struct Error {
    msg: String,
}

impl Error {
    /// Construct from anything printable (what `anyhow!` expands to).
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error {
            msg: message.to_string(),
        }
    }

    /// Prepend a context line, matching `anyhow`'s `{context}: {cause}`
    /// single-line rendering of the chain.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `anyhow::Result<T>` — `Result` with a defaulted error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<String> {
        let e = std::fs::read_to_string("/definitely/not/a/path")?;
        Ok(e)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert!(io_fail().is_err());
    }

    #[test]
    fn macros_and_display() {
        let e = anyhow!("bad value `{}`", 7);
        assert_eq!(e.to_string(), "bad value `7`");
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "too big: {x}");
            if x == 3 {
                bail!("three is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(2).unwrap(), 2);
        assert!(inner(3).is_err());
        assert!(inner(11).unwrap_err().to_string().contains("too big"));
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::Other,
            "inner cause",
        ));
        let e = r.with_context(|| "outer step").unwrap_err();
        let s = e.to_string();
        assert!(s.starts_with("outer step"), "{s}");
        assert!(s.contains("inner cause"));
        let none: Option<u8> = None;
        assert!(none.context("missing").is_err());
    }
}
