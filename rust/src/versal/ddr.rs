//! DDR traffic and bandwidth-efficiency model.
//!
//! The VCK190's single DDR4 channel peaks at 25.6 GB/s (Table II), but
//! achieved bandwidth depends strongly on the access pattern the tiling
//! induces: short row segments mean short bursts, and `B_K == 1`
//! (no K-reuse) thrashes the DRAM row buffer. These effects are the
//! physical reason PL reuse buffers matter, and a major source of the
//! analytical models' error (they assume a fixed efficiency).

use crate::config::{BoardConfig, SimConfig};
use crate::tiling::Tiling;
use crate::workloads::Gemm;

/// Total DDR traffic (bytes) for the whole GEMM under tiling `t`:
/// A and B tiles stream once per level-3 iteration; each C tile is
/// written back once after its K-loop completes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DdrTraffic {
    pub a_bytes: f64,
    pub b_bytes: f64,
    pub c_bytes: f64,
}

impl DdrTraffic {
    pub fn total(&self) -> f64 {
        self.a_bytes + self.b_bytes + self.c_bytes
    }
}

pub fn traffic(g: &Gemm, t: &Tiling, micro: usize) -> Option<DdrTraffic> {
    let (t_m, t_n, t_k) = t.l3_iters(g, micro)?;
    let (l2m, l2n, l2k) = t.l2_tile(micro);
    let iters = (t_m * t_n * t_k) as f64;
    Some(DdrTraffic {
        a_bytes: iters * (4 * l2m * l2k) as f64,
        b_bytes: iters * (4 * l2k * l2n) as f64,
        c_bytes: (t_m * t_n) as f64 * (4 * l2m * l2n) as f64,
    })
}

/// Burst efficiency for reads whose innermost contiguous run is
/// `run_bytes`: `run / (run + overhead)`, floored — DMA engines coalesce
/// strided rows to some degree.
pub fn burst_efficiency(run_bytes: f64, sim: &SimConfig) -> f64 {
    (run_bytes / (run_bytes + sim.ddr_overhead_bytes)).max(0.30)
}

/// Seconds of DDR time for the whole GEMM. Row-major layouts: A is MxK
/// (runs of the K-tile), B is KxN (runs of the N-tile), C is MxN.
pub fn ddr_time(g: &Gemm, t: &Tiling, board: &BoardConfig, sim: &SimConfig) -> Option<f64> {
    let micro = board.micro_tile;
    let traf = traffic(g, t, micro)?;
    let (l2m, l2n, l2k) = t.l2_tile(micro);
    let _ = l2m;
    let eff_a = burst_efficiency((4 * l2k) as f64, sim);
    let eff_b = burst_efficiency((4 * l2n) as f64, sim);
    let eff_c = burst_efficiency((4 * l2n) as f64, sim);
    // Row-buffer thrash when there is no K reuse at all.
    let rowbuf = if t.b_k == 1 { sim.ddr_rowbuf_penalty } else { 1.0 };
    let secs = (traf.a_bytes / eff_a + traf.b_bytes / eff_b + traf.c_bytes / eff_c)
        / (board.ddr_peak_bps * rowbuf);
    Some(secs)
}

/// Average achieved DDR bandwidth (bytes/s) if the GEMM runs in
/// `latency_s` — feeds the power model.
pub fn achieved_bandwidth(g: &Gemm, t: &Tiling, micro: usize, latency_s: f64) -> f64 {
    traffic(g, t, micro).map(|tr| tr.total() / latency_s).unwrap_or(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn defaults() -> (BoardConfig, SimConfig) {
        (BoardConfig::default(), SimConfig::default())
    }

    #[test]
    fn traffic_counts_reuse() {
        let g = Gemm::new(1024, 1024, 1024); // tiles 32^3
        // No reuse: every tile streams for every iteration.
        let none = Tiling::new((1, 1, 1), (1, 1, 1));
        let tr_none = traffic(&g, &none, 32).unwrap();
        // Full K in buffer: A and B each stream once per (i,j).
        let k_reuse = Tiling::new((1, 1, 1), (1, 1, 32));
        let tr_k = traffic(&g, &k_reuse, 32).unwrap();
        assert!(tr_none.a_bytes > tr_k.a_bytes * 0.9);
        assert_eq!(tr_none.c_bytes, tr_k.c_bytes); // C written once either way
        // More B_N reuse cuts A traffic (A tile reused across N).
        let n_reuse = Tiling::new((1, 1, 1), (1, 32, 1));
        let tr_n = traffic(&g, &n_reuse, 32).unwrap();
        assert!(tr_n.a_bytes < tr_none.a_bytes);
    }

    #[test]
    fn burst_efficiency_monotone() {
        let (_, s) = defaults();
        let e_small = burst_efficiency(128.0, &s);
        let e_big = burst_efficiency(8192.0, &s);
        assert!(e_small < e_big);
        assert!(e_big <= 1.0);
        assert!(e_small >= 0.30);
    }

    #[test]
    fn reuse_reduces_ddr_time() {
        let (b, s) = defaults();
        let g = Gemm::new(1024, 1024, 1024);
        let none = ddr_time(&g, &Tiling::new((2, 2, 2), (1, 1, 1)), &b, &s).unwrap();
        let reuse = ddr_time(&g, &Tiling::new((2, 2, 2), (2, 4, 4)), &b, &s).unwrap();
        assert!(reuse < none, "reuse {reuse} none {none}");
    }

    #[test]
    fn invalid_tiling_is_none() {
        let (b, s) = defaults();
        let g = Gemm::new(96, 96, 96); // tiles 3,3,3
        assert!(ddr_time(&g, &Tiling::new((2, 1, 1), (1, 1, 1)), &b, &s).is_none());
    }

    #[test]
    fn achieved_bw_bounded_by_traffic() {
        let g = Gemm::new(512, 512, 512);
        let t = Tiling::new((2, 2, 2), (2, 2, 2));
        let bw = achieved_bandwidth(&g, &t, 32, 1.0);
        assert!((bw - traffic(&g, &t, 32).unwrap().total()).abs() < 1e-6);
    }
}
