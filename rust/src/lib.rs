//! # versal-gemm
//!
//! Reproduction of *"Optimizing GEMM for Energy and Performance on
//! Versal ACAP Architectures"* (Papalamprou et al., CS.AR 2025) as a
//! three-layer Rust + JAX + Pallas stack:
//!
//! * **L1** (`python/compile/kernels/`) — Pallas 32×32×32 GEMM
//!   micro-kernel, the AIE kernel analogue, AOT-lowered to HLO text;
//! * **L2** (`python/compile/model.py`) — JAX tiled-GEMM graphs around
//!   the kernel, one artifact per tile variant;
//! * **L3** (this crate) — the paper's framework: VCK190 simulator
//!   substrate, feature engineering, from-scratch GBDT models,
//!   analytical baselines (CHARM/ARIES), ML-driven DSE with Pareto
//!   selection, Jetson GPU comparators, pluggable execution backends
//!   (PJRT over the AOT kernels, an always-available blocked CPU GEMM,
//!   and a simulator-stamped variant) with per-job energy accounting,
//!   and a serving coordinator.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the
//! DSE→coordinator planning-path diagram (bounded admission,
//! single-flight plan coalescing, and the sharded plan cache), the
//! execution-backend layer and its energy formula (§3), the serving
//! daemon and its wire protocol (§4), the project lint pass and the
//! invariants it enforces (§5: `cargo run -- lint`, the [`lint`]
//! module), the compiled forest-inference engine (§6: the arena layout
//! and row-blocked traversal behind `Predictors::predict_rows`), and
//! the per-figure/table experiment index.

pub mod analytical;
pub mod coordinator;
pub mod config;
pub mod dataset;
pub mod dse;
pub mod features;
pub mod gbdt;
pub mod gpu;
pub mod lint;
pub mod metrics;
pub mod models;
pub mod report;
pub mod runtime;
pub mod server;
pub mod tiling;
pub mod util;
pub mod versal;
pub mod workloads;

/// Library version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
