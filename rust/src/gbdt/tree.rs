//! Regression trees with histogram-based split search — the weak learner
//! of the gradient-boosted ensemble (paper §IV-A.3: GBDT chosen because
//! the features are bounded by the tiling-parameter ranges [30], [31]).
//!
//! Split finding works on a [`BinnedMatrix`]: every feature column is
//! quantized once per ensemble fit into at most [`MAX_BINS`] bins whose
//! cut points are midpoints between distinct sorted values (quantile-
//! thinned beyond `MAX_BINS` distinct values). Below that cap the cuts
//! can realize every partition the old exact-greedy sort-and-scan
//! could — though interior nodes pick thresholds from the global cut
//! set rather than recomputing node-local midpoints, so fitted trees
//! are not bitwise comparable with pre-histogram models. Each node
//! scans O(n + bins) per feature instead of sorting O(n log n), and the
//! NaN-unsafe `partial_cmp().unwrap()` sort is gone: binning orders
//! values with `f64::total_cmp` and routes NaN to the highest bin, the
//! same side (`right`) a NaN takes at prediction time.

use crate::util::json::{arr, num, obj, Json};
use crate::util::rng::Rng;

/// Row-major feature matrix view used across the GBDT stack.
#[derive(Debug, Clone, Default)]
pub struct FeatureMatrix {
    pub data: Vec<f64>,
    pub n_rows: usize,
    pub n_cols: usize,
}

impl FeatureMatrix {
    pub fn from_rows(rows: &[Vec<f64>]) -> FeatureMatrix {
        if rows.is_empty() {
            return FeatureMatrix::default();
        }
        let n_cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * n_cols);
        for r in rows {
            assert_eq!(r.len(), n_cols, "ragged feature rows");
            data.extend_from_slice(r);
        }
        FeatureMatrix {
            data,
            n_rows: rows.len(),
            n_cols,
        }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.n_cols..(i + 1) * self.n_cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.n_cols + j]
    }
}

/// Maximum histogram bins per feature (8-bit bin codes).
pub const MAX_BINS: usize = 256;

/// Pre-binned view of a [`FeatureMatrix`] for histogram split finding.
///
/// Built once per ensemble fit and shared across every tree and output
/// ([`crate::models::Predictors::train`] bins a dataset exactly once for
/// all 7 models). Cut points are deterministic functions of the data —
/// midpoints between distinct consecutive sorted values, thinned to
/// even quantile ranks when a column has more than [`MAX_BINS`]
/// distinct values — so fitted thresholds are identical across runs.
#[derive(Debug, Clone, Default)]
pub struct BinnedMatrix {
    /// Per-cell bin code, row-major (`n_rows x n_cols`).
    codes: Vec<u8>,
    /// Ascending candidate thresholds per feature. Splitting at cut `t`
    /// sends every row with `code <= t` left — by construction this is
    /// exactly the `value <= cuts[t]` predicate the fitted tree applies
    /// at prediction time (NaN compares false, lands in the top bin).
    cuts: Vec<Vec<f64>>,
    n_rows: usize,
    n_cols: usize,
}

impl BinnedMatrix {
    pub fn build(x: &FeatureMatrix) -> BinnedMatrix {
        let mut cuts: Vec<Vec<f64>> = Vec::with_capacity(x.n_cols);
        for j in 0..x.n_cols {
            let mut vals: Vec<f64> = (0..x.n_rows)
                .map(|i| x.get(i, j))
                .filter(|v| !v.is_nan())
                .collect();
            vals.sort_by(f64::total_cmp);
            vals.dedup();
            let mut c: Vec<f64> = if vals.len() <= MAX_BINS {
                // Exact mode: one cut between every pair of distinct
                // values — every partition exact greedy could make.
                vals.windows(2).map(|w| 0.5 * (w[0] + w[1])).collect()
            } else {
                // Quantile mode: MAX_BINS - 1 cuts at even ranks.
                (1..MAX_BINS)
                    .map(|k| {
                        let idx = k * vals.len() / MAX_BINS;
                        0.5 * (vals[idx - 1] + vals[idx])
                    })
                    .collect()
            };
            c.dedup();
            cuts.push(c);
        }
        let mut codes = Vec::with_capacity(x.n_rows * x.n_cols);
        for i in 0..x.n_rows {
            for (j, &v) in x.row(i).iter().enumerate() {
                // Number of leading cuts `v` falls strictly right of;
                // `!(v <= c)` (not `v > c`) so NaN passes every cut and
                // lands in the top bin — the side it takes at inference.
                let code = cuts[j].partition_point(|&c| !(v <= c));
                debug_assert!(code < MAX_BINS);
                codes.push(code as u8);
            }
        }
        BinnedMatrix {
            codes,
            cuts,
            n_rows: x.n_rows,
            n_cols: x.n_cols,
        }
    }

    #[inline]
    fn code(&self, i: usize, j: usize) -> usize {
        self.codes[i * self.n_cols + j] as usize
    }

    /// Candidate thresholds for feature `j` (ascending).
    pub fn cuts(&self, j: usize) -> &[f64] {
        &self.cuts[j]
    }

    pub fn n_rows(&self) -> usize {
        self.n_rows
    }
}

/// Hyper-parameters for a single tree fit.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: usize,
    pub min_samples_leaf: usize,
    /// L2 regularization added to the denominator of leaf values.
    pub lambda: f64,
    /// Fraction of features considered per split.
    pub colsample: f64,
}

#[derive(Debug, Clone, PartialEq)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// Compact traversal node (24 bytes, contiguous): `feature == u32::MAX`
/// marks a leaf whose value is in `threshold`. Built once after fitting;
/// gives ~1.5-2x faster prediction than matching on the boxed enum
/// (see EXPERIMENTS.md §Perf).
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) struct FlatNode {
    pub(crate) feature: u32,
    pub(crate) left: u32,
    pub(crate) right: u32,
    pub(crate) threshold: f64,
}

pub(crate) const LEAF: u32 = u32::MAX;

/// A fitted regression tree (flat node arena, root at index 0).
#[derive(Debug, Clone, PartialEq)]
pub struct RegressionTree {
    nodes: Vec<Node>,
    flat: Vec<FlatNode>,
}

impl RegressionTree {
    /// Fit on the sample subset `indices` against `targets` (residuals).
    /// Bins `x` internally; ensemble fits should bin once and use
    /// [`RegressionTree::fit_binned`] instead.
    pub fn fit(
        x: &FeatureMatrix,
        targets: &[f64],
        indices: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> RegressionTree {
        let binned = BinnedMatrix::build(x);
        RegressionTree::fit_binned(x, &binned, targets, indices, params, rng)
    }

    /// Fit against a pre-binned view of `x` (histogram split finding).
    pub fn fit_binned(
        x: &FeatureMatrix,
        binned: &BinnedMatrix,
        targets: &[f64],
        indices: &[usize],
        params: &TreeParams,
        rng: &mut Rng,
    ) -> RegressionTree {
        assert_eq!(x.n_rows, targets.len());
        assert_eq!(x.n_rows, binned.n_rows);
        assert!(!indices.is_empty());
        let mut tree = RegressionTree {
            nodes: Vec::new(),
            flat: Vec::new(),
        };
        let mut idx = indices.to_vec();
        let mut hist = Histogram::default();
        tree.build(x, binned, targets, &mut idx, 0, params, rng, &mut hist);
        tree.rebuild_flat();
        tree
    }

    /// Read-only view of the compact node arena (forest compilation).
    pub(crate) fn flat_nodes(&self) -> &[FlatNode] {
        &self.flat
    }

    fn rebuild_flat(&mut self) {
        self.flat = self
            .nodes
            .iter()
            .map(|n| match n {
                Node::Leaf { value } => FlatNode {
                    feature: LEAF,
                    left: 0,
                    right: 0,
                    threshold: *value,
                },
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => FlatNode {
                    feature: *feature as u32,
                    left: *left as u32,
                    right: *right as u32,
                    threshold: *threshold,
                },
            })
            .collect();
    }

    /// Recursively build; `indices` is reordered in place so children see
    /// contiguous slices (no per-node allocation of index vectors), and
    /// `hist` is one reused bin-accumulator for the whole tree.
    #[allow(clippy::too_many_arguments)]
    fn build(
        &mut self,
        x: &FeatureMatrix,
        binned: &BinnedMatrix,
        y: &[f64],
        indices: &mut [usize],
        depth: usize,
        params: &TreeParams,
        rng: &mut Rng,
        hist: &mut Histogram,
    ) -> usize {
        let node_id = self.nodes.len();
        let n = indices.len();
        let sum: f64 = indices.iter().map(|&i| y[i]).sum();
        let leaf_value = sum / (n as f64 + params.lambda);

        if depth >= params.max_depth || n < 2 * params.min_samples_leaf {
            self.nodes.push(Node::Leaf { value: leaf_value });
            return node_id;
        }

        match best_split(binned, y, indices, params, rng, hist) {
            None => {
                self.nodes.push(Node::Leaf { value: leaf_value });
                node_id
            }
            Some(split) => {
                // Partition indices by the split predicate.
                let mid = partition(x, indices, split.feature, split.threshold);
                debug_assert!(mid >= params.min_samples_leaf);
                debug_assert!(n - mid >= params.min_samples_leaf);
                self.nodes.push(Node::Split {
                    feature: split.feature,
                    threshold: split.threshold,
                    left: 0,
                    right: 0,
                });
                // Split borrows end here; recurse then patch child ids.
                let (left_slice, right_slice) = indices.split_at_mut(mid);
                let left_id =
                    self.build(x, binned, y, left_slice, depth + 1, params, rng, hist);
                let right_id =
                    self.build(x, binned, y, right_slice, depth + 1, params, rng, hist);
                if let Node::Split { left, right, .. } = &mut self.nodes[node_id] {
                    *left = left_id;
                    *right = right_id;
                }
                node_id
            }
        }
    }

    #[inline]
    pub fn predict_one(&self, row: &[f64]) -> f64 {
        let mut node = 0usize;
        loop {
            // SAFETY-free fast path over the compact arena.
            let n = &self.flat[node];
            if n.feature == LEAF {
                return n.threshold;
            }
            node = if row[n.feature as usize] <= n.threshold {
                n.left as usize
            } else {
                n.right as usize
            };
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> usize {
        fn rec(nodes: &[Node], id: usize) -> usize {
            match &nodes[id] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => 1 + rec(nodes, *left).max(rec(nodes, *right)),
            }
        }
        rec(&self.nodes, 0)
    }

    // -- persistence ---------------------------------------------------

    pub fn to_json(&self) -> Json {
        arr(self.nodes.iter().map(|n| match n {
            Node::Leaf { value } => obj(vec![("v", num(*value))]),
            Node::Split {
                feature,
                threshold,
                left,
                right,
            } => obj(vec![
                ("f", num(*feature as f64)),
                ("t", num(*threshold)),
                ("l", num(*left as f64)),
                ("r", num(*right as f64)),
            ]),
        }))
    }

    pub fn from_json(json: &Json) -> anyhow::Result<RegressionTree> {
        let items = json
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("tree json must be an array"))?;
        let mut nodes = Vec::with_capacity(items.len());
        for it in items {
            if let Some(v) = it.get("v") {
                nodes.push(Node::Leaf {
                    value: v.as_f64().ok_or_else(|| anyhow::anyhow!("bad leaf"))?,
                });
            } else {
                nodes.push(Node::Split {
                    feature: it.req_usize("f")?,
                    threshold: it.req_f64("t")?,
                    left: it.req_usize("l")?,
                    right: it.req_usize("r")?,
                });
            }
        }
        if nodes.is_empty() {
            anyhow::bail!("empty tree");
        }
        let mut tree = RegressionTree {
            nodes,
            flat: Vec::new(),
        };
        tree.rebuild_flat();
        Ok(tree)
    }
}

struct SplitCandidate {
    feature: usize,
    threshold: f64,
}

/// Reused per-bin accumulators for one node's split search.
#[derive(Debug)]
struct Histogram {
    cnt: [u32; MAX_BINS],
    sum: [f64; MAX_BINS],
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram {
            cnt: [0; MAX_BINS],
            sum: [0.0; MAX_BINS],
        }
    }
}

/// Histogram split: for each (sampled) feature, accumulate per-bin
/// count/target sums over the node's rows in O(n), then scan the bin
/// boundaries for the maximal SSE reduction. Where a column has fewer
/// than [`MAX_BINS`] distinct values the global cut set can realize
/// every partition the old exact-greedy sort-and-scan considered
/// (thresholds come from the shared cuts rather than node-local
/// midpoints), without the per-node O(n log n) sort or its NaN panic.
fn best_split(
    binned: &BinnedMatrix,
    y: &[f64],
    indices: &[usize],
    params: &TreeParams,
    rng: &mut Rng,
    hist: &mut Histogram,
) -> Option<SplitCandidate> {
    let n = indices.len();
    let total_sum: f64 = indices.iter().map(|&i| y[i]).sum();
    let total_sq: f64 = indices.iter().map(|&i| y[i] * y[i]).sum();
    let parent_sse = total_sq - total_sum * total_sum / n as f64;
    if parent_sse <= 1e-12 {
        return None; // node is pure
    }

    let n_feat = binned.n_cols;
    let n_try = ((n_feat as f64 * params.colsample).ceil() as usize).clamp(1, n_feat);
    let feat_order = rng.sample_indices(n_feat, n_try);

    let mut best: Option<(f64, SplitCandidate)> = None;
    for feature in feat_order {
        let cuts = binned.cuts(feature);
        if cuts.is_empty() {
            continue; // constant column: nothing to split on
        }
        let n_bins = cuts.len() + 1;
        hist.cnt[..n_bins].fill(0);
        hist.sum[..n_bins].fill(0.0);
        for &i in indices {
            let b = binned.code(i, feature);
            hist.cnt[b] += 1;
            hist.sum[b] += y[i];
        }
        let mut left_sum = 0.0;
        let mut left_n = 0usize;
        for (t, &threshold) in cuts.iter().enumerate() {
            left_n += hist.cnt[t] as usize;
            left_sum += hist.sum[t];
            if left_n == 0 {
                continue; // no rows this low in this node
            }
            let right_n = n - left_n;
            if right_n == 0 {
                break; // no rows above this cut in this node
            }
            if left_n < params.min_samples_leaf || right_n < params.min_samples_leaf {
                continue;
            }
            let right_sum = total_sum - left_sum;
            // SSE reduction = sum_l^2/n_l + sum_r^2/n_r - sum^2/n.
            let gain = left_sum * left_sum / left_n as f64
                + right_sum * right_sum / right_n as f64
                - total_sum * total_sum / n as f64;
            if gain > best.as_ref().map(|(g, _)| *g).unwrap_or(1e-12) {
                best = Some((gain, SplitCandidate { feature, threshold }));
            }
        }
    }
    best.map(|(_, c)| c)
}

/// In-place partition of `indices` by `x[., feature] <= threshold`;
/// returns the boundary.
fn partition(x: &FeatureMatrix, indices: &mut [usize], feature: usize, threshold: f64) -> usize {
    let mut lo = 0usize;
    let mut hi = indices.len();
    while lo < hi {
        if x.get(indices[lo], feature) <= threshold {
            lo += 1;
        } else {
            hi -= 1;
            indices.swap(lo, hi);
        }
    }
    lo
}

#[cfg(test)]
mod tests {
    use super::*;

    fn params() -> TreeParams {
        TreeParams {
            max_depth: 6,
            min_samples_leaf: 1,
            lambda: 0.0,
            colsample: 1.0,
        }
    }

    fn grid_xy(f: impl Fn(f64, f64) -> f64) -> (FeatureMatrix, Vec<f64>) {
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                let (a, b) = (i as f64, j as f64);
                rows.push(vec![a, b]);
                y.push(f(a, b));
            }
        }
        (FeatureMatrix::from_rows(&rows), y)
    }

    #[test]
    fn fits_step_function_exactly() {
        let (x, y) = grid_xy(|a, _| if a < 10.0 { -1.0 } else { 1.0 });
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let mut rng = Rng::new(1);
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut rng);
        for i in 0..x.n_rows {
            assert!((tree.predict_one(x.row(i)) - y[i]).abs() < 1e-9);
        }
        assert!(tree.depth() <= 2);
    }

    #[test]
    fn fits_axis_aligned_interaction() {
        let (x, y) = grid_xy(|a, b| {
            if a < 10.0 && b < 5.0 {
                3.0
            } else if a < 10.0 {
                1.0
            } else {
                -2.0
            }
        });
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let mut rng = Rng::new(2);
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut rng);
        let sse: f64 = (0..x.n_rows)
            .map(|i| (tree.predict_one(x.row(i)) - y[i]).powi(2))
            .sum();
        assert!(sse < 1e-9, "sse {sse}");
    }

    #[test]
    fn pure_node_becomes_leaf() {
        let x = FeatureMatrix::from_rows(&[vec![1.0], vec![2.0], vec![3.0]]);
        let y = vec![5.0, 5.0, 5.0];
        let idx = vec![0, 1, 2];
        let mut rng = Rng::new(3);
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut rng);
        assert_eq!(tree.n_nodes(), 1);
        assert!((tree.predict_one(&[9.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn respects_min_samples_leaf() {
        let (x, y) = grid_xy(|a, b| a + b);
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let p = TreeParams {
            min_samples_leaf: 50,
            ..params()
        };
        let mut rng = Rng::new(4);
        let tree = RegressionTree::fit(&x, &y, &idx, &p, &mut rng);
        // With 400 samples and min leaf 50, at most 8 leaves.
        assert!(tree.n_nodes() <= 15);
    }

    #[test]
    fn respects_max_depth() {
        let (x, y) = grid_xy(|a, b| (a * 7.0 + b * 13.0).sin());
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let p = TreeParams {
            max_depth: 3,
            ..params()
        };
        let mut rng = Rng::new(5);
        let tree = RegressionTree::fit(&x, &y, &idx, &p, &mut rng);
        assert!(tree.depth() <= 3);
    }

    #[test]
    fn lambda_shrinks_leaves() {
        let x = FeatureMatrix::from_rows(&[vec![0.0], vec![1.0]]);
        let y = vec![10.0, 10.0];
        let idx = vec![0, 1];
        let mut rng = Rng::new(6);
        let p = TreeParams {
            lambda: 2.0,
            ..params()
        };
        let tree = RegressionTree::fit(&x, &y, &idx, &p, &mut rng);
        // Leaf value = 20 / (2 + 2) = 5 (shrunk from the mean of 10).
        assert!((tree.predict_one(&[0.5]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn json_roundtrip() {
        let (x, y) = grid_xy(|a, b| a * 2.0 - b);
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let mut rng = Rng::new(7);
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut rng);
        let json = tree.to_json();
        let back = RegressionTree::from_json(&json).unwrap();
        assert_eq!(tree, back);
        for i in (0..x.n_rows).step_by(17) {
            assert_eq!(tree.predict_one(x.row(i)), back.predict_one(x.row(i)));
        }
    }

    #[test]
    fn nan_features_do_not_panic() {
        // Regression: the old exact-greedy search sorted feature values
        // with `partial_cmp().unwrap()` and panicked on NaN. Binning
        // orders with total_cmp and routes NaN to the highest bin.
        let x = FeatureMatrix::from_rows(&[
            vec![1.0, 4.0],
            vec![2.0, f64::NAN],
            vec![3.0, 2.0],
            vec![f64::NAN, 1.0],
            vec![5.0, 3.0],
            vec![6.0, f64::NAN],
        ]);
        let y = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let mut rng = Rng::new(8);
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut rng);
        // Every prediction is a finite leaf value, NaN rows included
        // (they deterministically take the `right` branch).
        for i in 0..x.n_rows {
            assert!(tree.predict_one(x.row(i)).is_finite());
        }
        assert!(tree.predict_one(&[f64::NAN, f64::NAN]).is_finite());
    }

    #[test]
    fn binning_matches_exact_thresholds_on_small_columns() {
        // Fewer distinct values than MAX_BINS: cuts are exactly the
        // midpoints the exact-greedy search used as thresholds.
        let x = FeatureMatrix::from_rows(&[
            vec![3.0],
            vec![1.0],
            vec![3.0],
            vec![7.0],
            vec![1.0],
        ]);
        let b = BinnedMatrix::build(&x);
        assert_eq!(b.cuts(0), &[2.0, 5.0]);
        // Codes follow the `v <= cut` predicate used at inference.
        let codes: Vec<usize> = (0..x.n_rows).map(|i| b.code(i, 0)).collect();
        assert_eq!(codes, vec![1, 0, 1, 2, 0]);
    }

    #[test]
    fn binning_caps_wide_columns_at_max_bins() {
        let rows: Vec<Vec<f64>> = (0..1000).map(|i| vec![i as f64 * 1.37]).collect();
        let x = FeatureMatrix::from_rows(&rows);
        let b = BinnedMatrix::build(&x);
        assert!(b.cuts(0).len() <= MAX_BINS - 1);
        assert!(b.cuts(0).len() >= MAX_BINS / 2, "cuts {}", b.cuts(0).len());
        // Cuts are strictly ascending; codes are monotone in the value.
        for w in b.cuts(0).windows(2) {
            assert!(w[0] < w[1]);
        }
        let mut prev = 0usize;
        for i in 0..x.n_rows {
            let c = b.code(i, 0);
            assert!(c >= prev);
            prev = c;
        }
        // A tree fit on the quantized column still models the trend.
        let y: Vec<f64> = (0..1000).map(|i| (i as f64) * 0.5).collect();
        let idx: Vec<usize> = (0..1000).collect();
        let tree = RegressionTree::fit(&x, &y, &idx, &params(), &mut Rng::new(9));
        let sse: f64 = (0..x.n_rows)
            .map(|i| (tree.predict_one(x.row(i)) - y[i]).powi(2))
            .sum::<f64>()
            / x.n_rows as f64;
        assert!(sse < 100.0, "mean sse {sse}");
    }

    #[test]
    fn fit_binned_matches_fit() {
        let (x, y) = grid_xy(|a, b| a * 2.0 - b * b);
        let idx: Vec<usize> = (0..x.n_rows).collect();
        let binned = BinnedMatrix::build(&x);
        let t1 = RegressionTree::fit(&x, &y, &idx, &params(), &mut Rng::new(10));
        let t2 = RegressionTree::fit_binned(&x, &binned, &y, &idx, &params(), &mut Rng::new(10));
        assert_eq!(t1, t2);
    }

    #[test]
    fn partition_is_stable_under_predicate() {
        let x = FeatureMatrix::from_rows(&[
            vec![5.0],
            vec![1.0],
            vec![3.0],
            vec![8.0],
            vec![2.0],
        ]);
        let mut idx = vec![0, 1, 2, 3, 4];
        let mid = partition(&x, &mut idx, 0, 3.0);
        assert_eq!(mid, 3);
        for &i in &idx[..mid] {
            assert!(x.get(i, 0) <= 3.0);
        }
        for &i in &idx[mid..] {
            assert!(x.get(i, 0) > 3.0);
        }
    }
}
