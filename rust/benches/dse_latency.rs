//! Bench: online-phase DSE wall-clock per workload (paper §V-A: the
//! ML-driven DSE completes in < 2 s per workload). Exercises the
//! streaming path: lazy candidate iterator -> PREDICT_CHUNK-sized
//! batched GBDT predictions -> incremental Pareto front.
//!
//! Section 1 isolates the model layer: `CompiledForest::predict_rows`
//! (one SoA arena, row-blocked traversal) vs the legacy per-tree walk
//! on the same trained bundle and the same feature rows, asserting the
//! >= 2x predictions-per-second acceptance floor plus bit-identical
//! outputs.
//!
//! Section 3 measures the two-stage resource gate: full 7-output
//! prediction vs stage-1 (5 R outputs + fits()) gating with stage-2
//! L/P trees on survivors only, asserting >= 1.2x candidate throughput
//! (and bit-identical survivor predictions). Section 4 runs 4
//! explorations *concurrently* through the shared process-wide DSE
//! pool, asserting the worker high-water mark never exceeds the pool
//! width (the seed spawned up to 4 x 8 transient threads).
//!
//! `--smoke` runs a cheap release-mode pass for CI: a reduced in-memory
//! dataset/model, fewer iterations, the first two workloads, and
//! report-only timing (shared runners are too noisy to hard-gate a
//! measured ratio; the bit-identical output asserts and the pool
//! thread-count bound are the smoke gates). Smoke also writes
//! `BENCH_dse.json` — aggregate candidates/s and the stage-2 gate skip
//! rate — for CI's perf-trajectory artifact.
use std::time::Instant;

use versal_gemm::config::Config;
use versal_gemm::dataset::Dataset;
use versal_gemm::dse::DsePool;
use versal_gemm::features::{featurize, FeatureSet};
use versal_gemm::models::Predictors;
use versal_gemm::report::Lab;
use versal_gemm::tiling::enumerate_candidates;
use versal_gemm::util::bench::{bench, report, report_throughput};
use versal_gemm::util::json::{num, obj, s};
use versal_gemm::workloads::{eval_workloads, training_workloads, Gemm};

fn main() -> anyhow::Result<()> {
    let smoke = std::env::args().any(|a| a == "--smoke");
    let lab = if smoke {
        // Fast in-memory lab: no disk cache, reduced offline budget.
        let mut cfg = Config::default();
        cfg.dataset.top_k = 12;
        cfg.dataset.bottom_k = 8;
        cfg.dataset.random_k = 60;
        cfg.train.n_trees = 120;
        cfg.train.learning_rate = 0.15;
        let ds = Dataset::generate(&cfg, &training_workloads());
        let predictors = Predictors::train(&ds, &cfg, FeatureSet::SetIAndII);
        Lab::in_memory(cfg, ds, predictors)
    } else {
        Lab::prepare(Config::default(), "data".into())?
    };
    let engine = lab.engine();

    // ---- 1. forest engine vs legacy per-tree traversal -----------------
    let predictors = &engine.predictors;
    let n_feat = predictors.feature_set.len();
    let g = Gemm::new(512, 1024, 768);
    let cands = enumerate_candidates(&g, engine.micro, &engine.limits);
    let mut rows: Vec<f64> = Vec::with_capacity(cands.len() * n_feat);
    for t in &cands {
        let full = featurize(&g, t, engine.micro);
        rows.extend_from_slice(&full[..n_feat]);
    }
    let fm = predictors.forest_metrics();
    println!(
        "== bench: forest inference engine ({} outputs, {} trees, {} nodes; \
         compile {:.2} ms) ==",
        fm.n_outputs, fm.n_trees, fm.n_nodes, fm.compile_ms
    );
    let iters = if smoke { 3 } else { 9 };
    let mut legacy_preds = Vec::new();
    let legacy = bench(1, iters, || {
        predictors.predict_rows_legacy(&rows, n_feat, &mut legacy_preds);
        std::hint::black_box(legacy_preds.len());
    });
    let mut forest_preds = Vec::new();
    let forest = bench(1, iters, || {
        predictors.predict_rows(&rows, n_feat, &mut forest_preds);
        std::hint::black_box(forest_preds.len());
    });
    assert_eq!(
        forest_preds, legacy_preds,
        "forest predictions diverged from the legacy path"
    );
    report(&format!("legacy per-tree ({} rows)", cands.len()), &legacy);
    report_throughput("  legacy rate", &legacy, cands.len() as f64, "rows");
    report(&format!("compiled forest ({} rows)", cands.len()), &forest);
    report_throughput("  forest rate", &forest, cands.len() as f64, "rows");
    let speedup = legacy.median.as_secs_f64() / forest.median.as_secs_f64();
    if smoke {
        // Report-only on CI runners: shared vCPUs make measured ratios
        // too noisy to hard-gate. The bit-identical output assert above
        // is the smoke gate; the 2x floor is enforced by the full bench.
        println!("forest speedup: {speedup:.2}x (smoke mode: informational)");
    } else {
        println!("forest speedup: {speedup:.2}x (acceptance floor: 2x)");
        assert!(
            speedup >= 2.0,
            "forest path only {speedup:.2}x over legacy (floor 2x)"
        );
    }

    // ---- 2. end-to-end streaming DSE latency per workload ---------------
    println!(
        "\n== bench: streaming DSE latency per eval workload (paper: < 2 s; chunk = {}) ==",
        versal_gemm::dse::PREDICT_CHUNK
    );
    let workloads = eval_workloads();
    let workloads = if smoke { &workloads[..2] } else { &workloads[..] };
    let mut worst = 0.0f64;
    for w in workloads {
        let stats = bench(1, if smoke { 2 } else { 5 }, || {
            let r = engine.explore(&w.gemm).unwrap();
            std::hint::black_box(r.n_feasible);
        });
        let r = engine.explore(&w.gemm)?;
        report(&format!("{} {} ({} cands)", w.id, w.gemm.label(), r.n_candidates), &stats);
        report_throughput("  prediction rate", &stats, r.n_candidates as f64, "candidates");
        worst = worst.max(stats.median.as_secs_f64());
        if !smoke {
            assert!(stats.median.as_secs_f64() < 2.0, "{} DSE exceeded 2 s", w.id);
        }
    }
    println!("worst-case median DSE: {worst:.3} s — within the paper's 2 s budget");

    // ---- 3. two-stage resource-gated prediction --------------------------
    // Full 7-output prediction vs stage-1 resource gating (5 R outputs +
    // fits(), in-place compaction) with stage-2 L/P trees on survivors
    // only, over the largest eval candidate space. The clone of the row
    // buffer each iteration is charged to the gated side (it compacts in
    // place), so the measured ratio is conservative.
    println!("\n== bench: two-stage resource-gated prediction (stage 1: 5 R outputs + fits; stage 2: L/P on survivors) ==");
    let g_big = Gemm::new(1024, 4864, 896);
    let big_cands = enumerate_candidates(&g_big, engine.micro, &engine.limits);
    let mut big_rows: Vec<f64> = Vec::with_capacity(big_cands.len() * n_feat);
    for t in &big_cands {
        let full = featurize(&g_big, t, engine.micro);
        big_rows.extend_from_slice(&full[..n_feat]);
    }
    let margin = engine.resource_margin_pct;
    let mut full_preds = Vec::new();
    let full_stats = bench(1, iters, || {
        predictors.predict_rows(&big_rows, n_feat, &mut full_preds);
        std::hint::black_box(full_preds.len());
    });
    let (mut surv, mut gated_preds) = (Vec::new(), Vec::new());
    let mut gated_rows: Vec<f64> = Vec::with_capacity(big_rows.len());
    let gated_stats = bench(1, iters, || {
        gated_rows.clear();
        gated_rows.extend_from_slice(&big_rows);
        predictors.predict_rows_gated(&mut gated_rows, n_feat, margin, &mut surv, &mut gated_preds);
        std::hint::black_box(surv.len());
    });
    // Equivalence gate (both modes): survivors are exactly the fits()
    // passers of the full path, with bit-identical predictions.
    let mut si = 0usize;
    for (i, p) in full_preds.iter().enumerate() {
        if p.fits(margin) {
            assert_eq!(surv[si] as usize, i, "survivor index drifted");
            assert_eq!(gated_preds[si], *p, "gated prediction diverged at row {i}");
            si += 1;
        }
    }
    assert_eq!(si, surv.len(), "gated path admitted a non-fitting row");
    let skip = 1.0 - surv.len() as f64 / big_cands.len() as f64;
    report(&format!("full 7-output ({} rows)", big_cands.len()), &full_stats);
    report_throughput("  full rate", &full_stats, big_cands.len() as f64, "candidates");
    report(&format!("gated two-stage ({:.0}% rows skip stage 2)", 100.0 * skip), &gated_stats);
    report_throughput("  gated rate", &gated_stats, big_cands.len() as f64, "candidates");
    let gate_speedup = full_stats.median.as_secs_f64() / gated_stats.median.as_secs_f64();
    if smoke {
        println!("gated-path speedup: {gate_speedup:.2}x (smoke mode: informational)");
    } else {
        println!("gated-path speedup: {gate_speedup:.2}x (acceptance floor: 1.2x)");
        assert!(
            gate_speedup >= 1.2,
            "gated path only {gate_speedup:.2}x over full prediction (floor 1.2x, skip rate {:.1}%)",
            100.0 * skip
        );
    }

    // ---- 4. concurrent explorations through the shared DSE pool ----------
    // 4 simultaneous explorations: the seed spawned min(cores, 8) scoped
    // threads *each* (up to 32 transient threads); the shared pool bounds
    // DSE work to pool-size workers no matter the concurrency.
    println!("\n== bench: 4 concurrent explorations through the shared DSE pool ==");
    let pool = DsePool::global();
    let concurrent = [
        Gemm::new(512, 1024, 768),
        Gemm::new(224, 3072, 768),
        Gemm::new(256, 2048, 512),
        Gemm::new(32, 4864, 896),
    ];
    let started = Instant::now();
    let engine_ref = &engine;
    let outcomes: Vec<(usize, usize, f64)> = std::thread::scope(|s| {
        let handles: Vec<_> = concurrent
            .iter()
            .map(|g| {
                s.spawn(move || {
                    let r = engine_ref.explore(g).expect("concurrent explore failed");
                    (r.n_candidates, r.n_gated, r.elapsed.as_secs_f64())
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().expect("concurrent explore panicked"))
            .collect()
    });
    let wall = started.elapsed();
    let total_cands: usize = outcomes.iter().map(|o| o.0).sum();
    let total_gated: usize = outcomes.iter().map(|o| o.1).sum();
    let sum_latency: f64 = outcomes.iter().map(|o| o.2).sum();
    println!(
        "4 explorations: {} candidates in {:.3} s wall ({:.0} candidates/s aggregate; \
         per-exploration latencies sum to {:.3} s)",
        total_cands,
        wall.as_secs_f64(),
        total_cands as f64 / wall.as_secs_f64(),
        sum_latency
    );
    println!(
        "stage-2 skip fraction: {:.1}% of {} candidate rows",
        100.0 * total_gated as f64 / total_cands as f64,
        total_cands
    );
    println!(
        "dse pool: {} threads, peak concurrently active {}; peak threads doing DSE \
         work anywhere in the process: {} (seed: up to {} transient threads)",
        pool.n_threads(),
        pool.peak_active(),
        versal_gemm::dse::active_dse_workers_peak(),
        4 * 8
    );
    // Thread-count bound holds in both modes — it is structural, not a
    // timing measurement. `active_dse_workers_peak` counts stream turns
    // on whatever thread runs them, so unlike the pool's self-bounded
    // counter it would catch a regression back to per-exploration
    // thread spawning.
    assert!(
        versal_gemm::dse::active_dse_workers_peak() <= pool.n_threads(),
        "DSE work oversubscribed: {} threads ran turns concurrently > pool width {}",
        versal_gemm::dse::active_dse_workers_peak(),
        pool.n_threads()
    );
    assert!(pool.peak_active() <= pool.n_threads());

    if smoke {
        // Perf trajectory (ROADMAP): persist the smoke numbers so every
        // CI run leaves a diffable DSE throughput snapshot at the repo
        // root, next to BENCH_serve.json / BENCH_gemm.json.
        let snapshot = obj(vec![
            ("bench", s("dse_latency")),
            ("mode", s("smoke")),
            ("candidates_per_s", num(total_cands as f64 / wall.as_secs_f64().max(1e-12))),
            ("gate_skip_rate", num(total_gated as f64 / (total_cands as f64).max(1.0))),
            ("total_candidates", num(total_cands as f64)),
            ("pool_threads", num(pool.n_threads() as f64)),
            ("forest_speedup", num(speedup)),
            ("gated_speedup", num(gate_speedup)),
            ("worst_dse_median_s", num(worst)),
        ]);
        std::fs::write("BENCH_dse.json", snapshot.to_string_pretty())?;
        println!("\nwrote BENCH_dse.json (aggregate candidate throughput + gate skip rate)");
    }
    Ok(())
}
