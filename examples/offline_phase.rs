//! The paper's offline phase, end to end (§IV-A): analytically-guided
//! sampling of the design space, "on-board" measurement of ~6000
//! designs across the 18 training workloads, GBDT training with the
//! 80/20 + 5-fold protocol, and a model-quality summary.
//!
//! Run with: `cargo run --release --example offline_phase`

use versal_gemm::config::Config;
use versal_gemm::dataset::Dataset;
use versal_gemm::features::FeatureSet;
use versal_gemm::gbdt::cv::cross_validate;
use versal_gemm::metrics::{mape, pearson};
use versal_gemm::models::Predictors;
use versal_gemm::workloads::training_workloads;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();

    // 1. Design-space coverage + on-board profiling (simulated board).
    let t0 = std::time::Instant::now();
    let ds = Dataset::generate(&cfg, &training_workloads());
    println!(
        "offline phase: {} designs across {} workloads in {:.2}s \
         (the real flow took 40+ days of board time)",
        ds.len(),
        ds.workload_ids().len(),
        t0.elapsed().as_secs_f64()
    );
    std::fs::create_dir_all("data")?;
    ds.save(&cfg, std::path::Path::new("data/dataset.csv"))?;

    // 2. 5-fold CV on the latency model (log target), both feature sets.
    let y = ds.targets(&cfg).latency_s;
    for set in [FeatureSet::SetI, FeatureSet::SetIAndII] {
        let x = ds.feature_matrix(cfg.board.micro_tile, set);
        let score = cross_validate(&x, &y, &cfg.train, true, 5);
        println!(
            "5-fold CV latency model [{}]: R2 {:.4}, MAPE {:.2}%",
            set.label(),
            score.mean_r2,
            score.mean_mape
        );
    }

    // 3. Train the full bundle and hold out 20% for the headline check.
    let (train, test) = ds.split_random(cfg.train.test_fraction, 7);
    let model = Predictors::train(&train, &cfg, FeatureSet::SetIAndII);
    model.save(std::path::Path::new("data/predictors.json"))?;

    let lat_truth: Vec<f64> = test.points.iter().map(|p| p.measurement.latency_s).collect();
    let lat_pred: Vec<f64> = test
        .points
        .iter()
        .map(|p| model.predict(&p.gemm, &p.tiling).latency_s)
        .collect();
    let pow_truth: Vec<f64> = test.points.iter().map(|p| p.measurement.power_w).collect();
    let pow_pred: Vec<f64> = test
        .points
        .iter()
        .map(|p| model.predict(&p.gemm, &p.tiling).power_w)
        .collect();
    println!("held-out latency MAPE: {:.2}%", mape(&lat_truth, &lat_pred));
    println!("held-out power MAPE:   {:.2}% (paper: 7.05%)", mape(&pow_truth, &pow_pred));

    // 4. The paper's rho correlation claim (§IV-A.3, r = 0.81).
    let rho: Vec<f64> = ds
        .points
        .iter()
        .map(|p| (p.gemm.flops() / p.tiling.n_aie() as f64).ln())
        .collect();
    let lat: Vec<f64> = ds.points.iter().map(|p| p.measurement.latency_s.ln()).collect();
    println!("Pearson r(ln rho, ln latency): {:.3} (paper: 0.81)", pearson(&rho, &lat));

    // 5. BEAM-style telemetry for one measured design (paper section V:
    //    60 s power capture via the System Controller).
    use versal_gemm::versal::telemetry::BeamSession;
    let sample = &ds.points[ds.len() / 2];
    let trace = BeamSession::default().trace(&sample.measurement, 42);
    println!(
        "BEAM trace for {} {}: {} samples over {:.0} s — steady {:.2} W \
         (measurement {:.2} W), peak {:.2} W, energy {:.1} J",
        sample.workload_id,
        sample.tiling.label(),
        trace.samples.len(),
        trace.duration_s(),
        trace.steady_mean(),
        sample.measurement.power_w,
        trace.max(),
        trace.energy_j()
    );
    println!("\nwrote data/dataset.csv and data/predictors.json");
    Ok(())
}
