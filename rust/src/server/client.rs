//! Client library for the serving daemon — the layer the `serve
//! submit|stats|drain|stop` subcommands (and the CI smoke job) sit on.
//!
//! The client side is deliberately blocking: one request/response (or
//! one pipelined burst) per call, against a daemon that never blocks on
//! writes (it queues frames per connection), so "write the whole burst,
//! then read all results" cannot deadlock.

use std::io::{Read, Write};
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::util::backoff;

use super::protocol::{
    encode_frame, encode_submit, encode_submit_graph, Frame, FrameReader, GraphSpec, JobSpec,
    WireGraphResult, WireResult, WireStats,
};
use super::{Endpoint, NetStream};

/// Default bound on any single blocking socket read/write. Generous
/// enough for a full drain of a deep queue, small enough that a wedged
/// daemon surfaces as a typed timeout instead of a hung CLI.
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(30);

/// First connect-retry delay; doubles per attempt up to the cap.
const CONNECT_RETRY_BASE: Duration = Duration::from_millis(25);
const CONNECT_RETRY_CAP: Duration = Duration::from_millis(400);

pub struct Client {
    stream: NetStream,
    reader: FrameReader,
    /// Applied to every socket read; `None` blocks forever.
    io_timeout: Option<Duration>,
}

impl Client {
    pub fn connect(ep: &Endpoint) -> anyhow::Result<Client> {
        Client::connect_with(ep, Some(DEFAULT_IO_TIMEOUT))
    }

    /// Connect with an explicit per-operation I/O timeout (the
    /// `--timeout` flag; `None` disables the bound).
    pub fn connect_with(ep: &Endpoint, io_timeout: Option<Duration>) -> anyhow::Result<Client> {
        let stream = NetStream::connect(ep)
            .with_context(|| format!("connecting to daemon at {}", ep.label()))?;
        stream
            .set_read_timeout(io_timeout)
            .context("setting socket read timeout")?;
        stream
            .set_write_timeout(io_timeout)
            .context("setting socket write timeout")?;
        Ok(Client {
            stream,
            reader: FrameReader::new(),
            io_timeout,
        })
    }

    /// Connect, retrying with capped exponential backoff until
    /// `timeout` — for `serve start` waiting on a freshly spawned
    /// daemon to bind its socket.
    pub fn connect_retry(ep: &Endpoint, timeout: Duration) -> anyhow::Result<Client> {
        Client::connect_retry_with(ep, timeout, Some(DEFAULT_IO_TIMEOUT))
    }

    /// [`Self::connect_retry`] with an explicit per-operation I/O
    /// timeout for the connected client.
    pub fn connect_retry_with(
        ep: &Endpoint,
        timeout: Duration,
        io_timeout: Option<Duration>,
    ) -> anyhow::Result<Client> {
        let deadline = Instant::now() + timeout;
        let mut delay = CONNECT_RETRY_BASE;
        loop {
            match Client::connect_with(ep, io_timeout) {
                Ok(c) => return Ok(c),
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(e.context(format!(
                            "daemon did not come up within {:.1}s",
                            timeout.as_secs_f64()
                        )));
                    }
                    backoff::pause(delay.min(deadline.saturating_duration_since(Instant::now())));
                    delay = (delay * 2).min(CONNECT_RETRY_CAP);
                }
            }
        }
    }

    pub fn send(&mut self, frame: &Frame) -> anyhow::Result<()> {
        self.stream.write_all(&encode_frame(frame))?;
        Ok(())
    }

    /// Submit one job (encoded straight from the borrowed spec, so
    /// operand buffers are not cloned).
    pub fn submit(&mut self, spec: &JobSpec) -> anyhow::Result<()> {
        self.stream.write_all(&encode_submit(spec))?;
        Ok(())
    }

    /// Submit one whole-model graph job (encoded straight from the
    /// borrowed spec, so input buffers are not cloned).
    pub fn submit_graph(&mut self, spec: &GraphSpec) -> anyhow::Result<()> {
        self.stream.write_all(&encode_submit_graph(spec))?;
        Ok(())
    }

    /// Blocking read of the next frame; `None` on clean EOF. A read
    /// that exceeds the I/O timeout fails with a typed timeout error
    /// instead of hanging the CLI on a wedged daemon.
    pub fn recv_opt(&mut self) -> anyhow::Result<Option<Frame>> {
        let mut buf = [0u8; 16 << 10];
        loop {
            if let Some(frame) = self.reader.next_frame()? {
                return Ok(Some(frame));
            }
            match self.stream.read(&mut buf) {
                Ok(0) => {
                    // EOF with a partial frame buffered means truncation.
                    anyhow::ensure!(
                        self.reader.buffered() == 0,
                        "connection closed mid-frame ({} bytes buffered)",
                        self.reader.buffered()
                    );
                    return Ok(None);
                }
                Ok(n) => self.reader.push(&buf[..n]),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                // Unix sockets report an expired SO_RCVTIMEO as
                // WouldBlock, TCP as TimedOut; both mean the same here.
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    let bound = self
                        .io_timeout
                        .map(|d| format!("{:.1}s", d.as_secs_f64()))
                        .unwrap_or_else(|| "?".to_string());
                    anyhow::bail!(
                        "timed out waiting for the daemon (no frame within {bound}; \
                         raise --timeout for long drains)"
                    );
                }
                Err(e) => return Err(e.into()),
            }
        }
    }

    fn recv(&mut self) -> anyhow::Result<Frame> {
        self.recv_opt()?
            .ok_or_else(|| anyhow::anyhow!("daemon closed the connection"))
    }

    /// Next job result, skipping unrelated frames; daemon-reported
    /// protocol errors become `Err`.
    pub fn next_result(&mut self) -> anyhow::Result<WireResult> {
        loop {
            match self.recv()? {
                Frame::Result(r) => return Ok(r),
                Frame::Error { job_id, message } => {
                    anyhow::bail!("daemon error (job {job_id}): {message}")
                }
                _ => continue, // stray Stats/Drained/Ack from earlier requests
            }
        }
    }

    /// Next graph result, skipping unrelated frames (per-job Result
    /// frames included); daemon-reported protocol errors become `Err`.
    pub fn next_graph_result(&mut self) -> anyhow::Result<WireGraphResult> {
        loop {
            match self.recv()? {
                Frame::GraphResult(r) => return Ok(r),
                Frame::Error { job_id, message } => {
                    anyhow::bail!("daemon error (job {job_id}): {message}")
                }
                _ => continue,
            }
        }
    }

    /// Pipeline a burst: write every SUBMIT, then collect exactly one
    /// result per spec (any completion order).
    pub fn submit_burst(&mut self, specs: &[JobSpec]) -> anyhow::Result<Vec<WireResult>> {
        for spec in specs {
            self.submit(spec)?;
        }
        let mut out = Vec::with_capacity(specs.len());
        for _ in 0..specs.len() {
            out.push(self.next_result()?);
        }
        out.sort_by_key(|r| r.id);
        Ok(out)
    }

    pub fn stats(&mut self) -> anyhow::Result<WireStats> {
        self.send(&Frame::StatsReq)?;
        loop {
            match self.recv()? {
                Frame::Stats(s) => return Ok(s),
                Frame::Error { message, .. } => anyhow::bail!("daemon error: {message}"),
                _ => continue,
            }
        }
    }

    /// Ask the daemon to drain; blocks until it reports quiescence
    /// (straggler Result frames for our own jobs are passed over).
    pub fn drain(&mut self) -> anyhow::Result<WireStats> {
        self.send(&Frame::Drain)?;
        loop {
            match self.recv()? {
                Frame::Drained(s) => return Ok(s),
                Frame::Error { message, .. } => anyhow::bail!("daemon error: {message}"),
                _ => continue,
            }
        }
    }

    /// Drain, then stop the daemon. `Ack` and EOF both count as success
    /// (the daemon may exit before our final read).
    pub fn shutdown(&mut self) -> anyhow::Result<()> {
        self.send(&Frame::Shutdown)?;
        loop {
            match self.recv_opt() {
                Ok(Some(Frame::Ack)) | Ok(None) => return Ok(()),
                Ok(Some(Frame::Error { message, .. })) => {
                    anyhow::bail!("daemon error: {message}")
                }
                Ok(Some(_)) => continue,
                // Connection reset while the daemon exits is success too.
                Err(_) => return Ok(()),
            }
        }
    }
}
