//! Executor-owned operand arena for graph jobs: node outputs stay
//! resident on the daemon side, refcounted by their downstream
//! consumers, and are freed the moment the last consumer has read them
//! — intermediates never round-trip through the client (DESIGN.md §11).
//!
//! The arena is deliberately simple: one optional slot per graph node,
//! indexed by node index, plus live/peak byte accounting the
//! coordinator surfaces as `resident_bytes_peak`. It is owned by the
//! single executor thread, so no interior locking is needed.

/// One resident node output.
struct ArenaSlot {
    data: Vec<f32>,
    /// Reads left before the buffer is dropped.
    consumers_left: usize,
}

/// Refcounted residency arena for one graph job's intermediates.
#[derive(Default)]
pub struct OperandArena {
    slots: Vec<Option<ArenaSlot>>,
    live_bytes: u64,
    peak_bytes: u64,
}

impl OperandArena {
    /// An arena with one (empty) slot per graph node.
    pub fn new(n_nodes: usize) -> OperandArena {
        let mut slots = Vec::with_capacity(n_nodes);
        slots.resize_with(n_nodes, || None);
        OperandArena {
            slots,
            live_bytes: 0,
            peak_bytes: 0,
        }
    }

    /// Park a node's output with its consumer refcount. A zero count
    /// drops the buffer immediately (dead-end node nobody reads).
    pub fn publish(&mut self, idx: usize, data: Vec<f32>, consumers: usize) {
        if idx >= self.slots.len() || consumers == 0 {
            return;
        }
        self.live_bytes += 4 * data.len() as u64;
        self.peak_bytes = self.peak_bytes.max(self.live_bytes);
        self.slots[idx] = Some(ArenaSlot {
            data,
            consumers_left: consumers,
        });
    }

    /// Borrow a resident output (does not consume a refcount).
    pub fn get(&self, idx: usize) -> Option<&[f32]> {
        self.slots
            .get(idx)
            .and_then(|s| s.as_ref())
            .map(|s| s.data.as_slice())
    }

    /// Record that one consumer has finished reading `idx`; the buffer
    /// is freed when the last consumer checks in.
    pub fn consume(&mut self, idx: usize) {
        let Some(slot) = self.slots.get_mut(idx).and_then(|s| s.as_mut()) else {
            return;
        };
        slot.consumers_left = slot.consumers_left.saturating_sub(1);
        if slot.consumers_left == 0 {
            let freed = 4 * slot.data.len() as u64;
            self.slots[idx] = None;
            self.live_bytes = self.live_bytes.saturating_sub(freed);
        }
    }

    /// Remove and return a resident buffer regardless of refcount (used
    /// to hand kept outputs back to an in-process caller).
    pub fn take(&mut self, idx: usize) -> Option<Vec<f32>> {
        let slot = self.slots.get_mut(idx)?.take()?;
        self.live_bytes = self.live_bytes.saturating_sub(4 * slot.data.len() as u64);
        Some(slot.data)
    }

    /// Bytes currently resident.
    pub fn live_bytes(&self) -> u64 {
        self.live_bytes
    }

    /// High-water mark of resident bytes over the arena's lifetime.
    pub fn peak_bytes(&self) -> u64 {
        self.peak_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diamond_refcount_frees_at_last_consumer() {
        // root output read by two consumers (a diamond's fan-out).
        let mut arena = OperandArena::new(4);
        arena.publish(0, vec![1.0; 64], 2);
        assert_eq!(arena.live_bytes(), 256);
        assert!(arena.get(0).is_some());
        arena.consume(0);
        // First consumer done: still resident for the second.
        assert!(arena.get(0).is_some(), "freed before last consumer");
        assert_eq!(arena.live_bytes(), 256);
        arena.consume(0);
        // Last consumer done: freed.
        assert!(arena.get(0).is_none(), "not freed at last consumer");
        assert_eq!(arena.live_bytes(), 0);
        assert_eq!(arena.peak_bytes(), 256);
    }

    #[test]
    fn peak_tracks_concurrent_residency() {
        let mut arena = OperandArena::new(3);
        arena.publish(0, vec![0.0; 16], 1);
        arena.publish(1, vec![0.0; 32], 1);
        assert_eq!(arena.peak_bytes(), 4 * 48);
        arena.consume(0);
        arena.consume(1);
        arena.publish(2, vec![0.0; 8], 1);
        // Peak is sticky even after frees.
        assert_eq!(arena.live_bytes(), 32);
        assert_eq!(arena.peak_bytes(), 4 * 48);
    }

    #[test]
    fn zero_consumer_publish_is_dropped_and_take_clears() {
        let mut arena = OperandArena::new(2);
        arena.publish(0, vec![0.0; 8], 0);
        assert!(arena.get(0).is_none());
        assert_eq!(arena.live_bytes(), 0);
        arena.publish(1, vec![3.0; 4], 5);
        assert_eq!(arena.take(1), Some(vec![3.0; 4]));
        assert!(arena.get(1).is_none());
        assert_eq!(arena.live_bytes(), 0);
        // Out-of-range and double-take are no-ops, never panics.
        assert_eq!(arena.take(1), None);
        arena.consume(7);
        arena.publish(9, vec![0.0; 4], 1);
        assert_eq!(arena.take(9), None);
    }
}
