//! L3 serving coordinator — the run-time face of the framework.
//!
//! The paper's online phase emits one mapping per workload; a deployed
//! system must serve *streams* of GEMM jobs (the LLM/ViT working sets of
//! §V-A). This module is that service:
//!
//! ```text
//!   submit(GemmJob) ──► planner pool (streaming DSE)
//!                         │   ▲
//!                         ▼   │ per-(gemm, objective) plans
//!                     sharded LRU plan cache (N-way, persistable)
//!                         │ plan-only jobs return here
//!                         ▼
//!                     executor thread (owns the PJRT GemmEngine)
//!                         │ dynamic batching: drains the queue, groups
//!                         │ jobs by artifact variant to reuse compiled
//!                         │ executables and tile buffers
//!                         ▼
//!                     JobResult (mapping + predicted + simulated Versal
//!                     metrics + real execution time + validation)
//! ```
//!
//! Planners are pure-CPU and run in parallel; they contend only on the
//! plan-cache *shard* their key hashes to (see [`cache`]), not on one
//! global map lock as the seed did. The cache evicts LRU per shard,
//! reports hit/miss/eviction counters plus the p50 plan latency through
//! [`CoordinatorStats`], and can persist to disk so a restarted
//! coordinator warms from the previous process's plans
//! ([`CoordinatorOptions::cache_path`], `serve --plan-cache`).
//!
//! The executor is a single thread because PJRT handles are not
//! `Send`-safe across arbitrary threads (it is created *inside* its
//! thread). Python never appears. Serve-path failures (planner pool
//! gone, DSE errors, missing artifacts) surface as `JobResult::error`,
//! never as panics.

pub mod cache;

use std::collections::VecDeque;
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, SendError, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::config::Config;
use crate::coordinator::cache::{PlanKey, ShardedPlanCache};
use crate::dse::{DseEngine, Objective};
use crate::models::Prediction;
use crate::runtime::{matmul_ref, max_abs_diff, GemmEngine};
use crate::tiling::Tiling;
use crate::util::lock_unpoisoned;
use crate::versal::reconfig::ReconfigModel;
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::Gemm;

/// One GEMM request. Data-less jobs are "plan-only" (mapping + predicted
/// + simulated metrics, no execution).
#[derive(Debug, Clone)]
pub struct GemmJob {
    pub id: u64,
    pub gemm: Gemm,
    pub objective: Objective,
    pub a: Option<Vec<f32>>,
    pub b: Option<Vec<f32>>,
    /// Validate the PJRT result against the Rust reference GEMM.
    pub validate: bool,
}

impl GemmJob {
    pub fn plan_only(id: u64, gemm: Gemm, objective: Objective) -> GemmJob {
        GemmJob {
            id,
            gemm,
            objective,
            a: None,
            b: None,
            validate: false,
        }
    }

    pub fn with_data(
        id: u64,
        gemm: Gemm,
        objective: Objective,
        a: Vec<f32>,
        b: Vec<f32>,
    ) -> GemmJob {
        GemmJob {
            id,
            gemm,
            objective,
            a: Some(a),
            b: Some(b),
            validate: false,
        }
    }
}

/// The chosen mapping with its predicted and simulated-board metrics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Plan {
    pub tiling: Tiling,
    pub predicted: Prediction,
    pub simulated: Measurement,
}

/// Completed job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub id: u64,
    pub gemm: Gemm,
    pub objective: Objective,
    pub plan: Option<Plan>,
    pub plan_time: Duration,
    pub cache_hit: bool,
    /// Wall-clock of the PJRT execution (None for plan-only jobs or when
    /// no artifact engine is available).
    pub exec_time: Option<Duration>,
    /// max|c - c_ref| when validation was requested.
    pub validation_err: Option<f32>,
    pub c: Option<Vec<f32>>,
    pub error: Option<String>,
}

impl JobResult {
    pub fn executed_gflops(&self) -> Option<f64> {
        self.exec_time
            .map(|t| self.gemm.flops() / t.as_secs_f64() / 1e9)
    }
}

/// Aggregate service counters.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CoordinatorStats {
    pub jobs_completed: u64,
    pub jobs_failed: u64,
    pub cache_hits: u64,
    pub cache_misses: u64,
    /// Plans dropped by per-shard LRU eviction.
    pub cache_evictions: u64,
    /// `cache_hits / (cache_hits + cache_misses)`, 0.0 before traffic.
    pub cache_hit_rate: f64,
    /// Median planner latency (cache hits and misses together, ms).
    pub plan_p50_ms: f64,
    pub executed_jobs: u64,
    pub executed_flops: f64,
    pub exec_time_s: f64,
    /// Energy the selected mappings would draw on the VCK190 (J).
    pub simulated_energy_j: f64,
    /// Mapping switches the batch order incurred, and their simulated
    /// partial-reconfiguration cost on the VCK190.
    pub reconfigs: u64,
    pub simulated_reconfig_s: f64,
    /// One-time cost of compiling the GBDT bundle into the forest
    /// arena (0 until the engine's first prediction compiles it).
    pub forest_compile_ms: f64,
    /// Forest-inference throughput (feature rows per second of engine
    /// busy time; per-thread, not summed across concurrent planners) —
    /// the DSE hot-path health signal.
    pub predict_rows_per_s: f64,
}

impl CoordinatorStats {
    pub fn executed_gflops(&self) -> f64 {
        if self.exec_time_s > 0.0 {
            self.executed_flops / self.exec_time_s / 1e9
        } else {
            0.0
        }
    }
}

/// Tunables of the planning hot path.
#[derive(Debug, Clone)]
pub struct CoordinatorOptions {
    /// Plan-cache shard count (lock-contention granularity).
    pub n_shards: usize,
    /// Total plan-cache entry budget (split across shards, LRU per shard).
    pub cache_capacity: usize,
    /// When set: warm the cache from this JSON file at start (if present)
    /// and persist back on shutdown.
    pub cache_path: Option<PathBuf>,
}

impl Default for CoordinatorOptions {
    fn default() -> Self {
        CoordinatorOptions {
            n_shards: 8,
            cache_capacity: 1024,
            cache_path: None,
        }
    }
}

/// Bounded reservoir of recent plan latencies for the p50 readout.
#[derive(Debug, Default)]
struct PlanLatencies {
    samples_ms: Vec<f64>,
    cursor: usize,
}

const MAX_PLAN_SAMPLES: usize = 16_384;

impl PlanLatencies {
    fn push(&mut self, ms: f64) {
        if self.samples_ms.len() < MAX_PLAN_SAMPLES {
            self.samples_ms.push(ms);
        } else {
            self.samples_ms[self.cursor] = ms;
            self.cursor = (self.cursor + 1) % MAX_PLAN_SAMPLES;
        }
    }

    fn p50_ms(&self) -> f64 {
        if self.samples_ms.is_empty() {
            0.0
        } else {
            crate::metrics::median(&self.samples_ms)
        }
    }
}

struct PlannedJob {
    job: GemmJob,
    result: JobResult,
}

enum ExecMsg {
    Job(Box<PlannedJob>),
}

/// The serving coordinator.
pub struct Coordinator {
    job_tx: Option<Sender<GemmJob>>,
    result_rx: Receiver<JobResult>,
    planners: Vec<std::thread::JoinHandle<()>>,
    executor: Option<std::thread::JoinHandle<()>>,
    stats: Arc<Mutex<CoordinatorStats>>,
    cache: Arc<ShardedPlanCache>,
    /// Shared with the planner pool; `stats()` reads the predictor
    /// bundle's forest compile/throughput counters from here.
    dse: Arc<DseEngine>,
    plan_lat: Arc<Mutex<PlanLatencies>>,
    cache_path: Option<PathBuf>,
    /// Jobs rejected at submit time (pool gone / already shut down);
    /// drained ahead of channel results so every submit yields a result.
    rejected: VecDeque<JobResult>,
    pending: u64,
}

impl Coordinator {
    /// Start the service with default cache options. `artifacts_dir =
    /// None` runs in plan-only mode (jobs with data are refused politely
    /// in the result).
    pub fn start(
        cfg: &Config,
        engine: DseEngine,
        artifacts_dir: Option<PathBuf>,
        n_planners: usize,
    ) -> Coordinator {
        Coordinator::start_with(cfg, engine, artifacts_dir, n_planners, CoordinatorOptions::default())
    }

    /// Start the service with explicit plan-cache options.
    pub fn start_with(
        cfg: &Config,
        engine: DseEngine,
        artifacts_dir: Option<PathBuf>,
        n_planners: usize,
        options: CoordinatorOptions,
    ) -> Coordinator {
        let (job_tx, job_rx) = channel::<GemmJob>();
        let (exec_tx, exec_rx) = channel::<ExecMsg>();
        let (result_tx, result_rx) = channel::<JobResult>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let stats = Arc::new(Mutex::new(CoordinatorStats::default()));
        let plan_lat = Arc::new(Mutex::new(PlanLatencies::default()));

        let dse = Arc::new(engine);
        let sim = Arc::new(VersalSim::new(cfg));
        let cache = Arc::new(match &options.cache_path {
            Some(path) if path.exists() => {
                match ShardedPlanCache::load(path, options.n_shards, options.cache_capacity) {
                    Ok(c) => {
                        eprintln!(
                            "coordinator: warmed plan cache with {} plans from {}",
                            c.len(),
                            path.display()
                        );
                        c
                    }
                    Err(e) => {
                        eprintln!("coordinator: ignoring plan cache {}: {e}", path.display());
                        ShardedPlanCache::new(options.n_shards, options.cache_capacity)
                    }
                }
            }
            _ => ShardedPlanCache::new(options.n_shards, options.cache_capacity),
        });

        // --- planner pool -------------------------------------------------
        let mut planners = Vec::new();
        for _ in 0..n_planners.max(1) {
            let job_rx = Arc::clone(&job_rx);
            let exec_tx = exec_tx.clone();
            let result_tx = result_tx.clone();
            let dse = Arc::clone(&dse);
            let sim = Arc::clone(&sim);
            let cache = Arc::clone(&cache);
            let stats = Arc::clone(&stats);
            let plan_lat = Arc::clone(&plan_lat);
            planners.push(std::thread::spawn(move || loop {
                let job = {
                    let guard = lock_unpoisoned(&job_rx);
                    guard.recv()
                };
                let job = match job {
                    Ok(j) => j,
                    Err(_) => break, // all senders dropped: shutdown
                };
                let planned = plan_job(&dse, &sim, &cache, &stats, &plan_lat, job);
                let has_data = planned.job.a.is_some() && planned.job.b.is_some();
                if has_data && planned.result.error.is_none() {
                    let _ = exec_tx.send(ExecMsg::Job(Box::new(planned)));
                } else {
                    let _ = result_tx.send(planned.result);
                }
            }));
        }
        drop(exec_tx); // executor sees Shutdown or channel close

        // --- executor thread ----------------------------------------------
        let exec_stats = Arc::clone(&stats);
        let board = cfg.board.clone();
        let executor = std::thread::spawn(move || {
            let reconfig = ReconfigModel::default();
            let mut current_mapping: Option<Tiling> = None;
            // The PJRT engine lives entirely inside this thread.
            let engine = artifacts_dir.and_then(|dir| match GemmEngine::load(&dir) {
                Ok(e) => Some(e),
                Err(err) => {
                    eprintln!("coordinator: no artifact engine ({err}); executing is disabled");
                    None
                }
            });
            // Dynamic batching: drain whatever is queued, group by the
            // artifact variant the picker selects, then execute.
            let mut queue: Vec<Box<PlannedJob>> = Vec::new();
            loop {
                if queue.is_empty() {
                    match exec_rx.recv() {
                        Ok(ExecMsg::Job(j)) => queue.push(j),
                        Err(_) => break, // planners gone: shutdown
                    }
                }
                while let Ok(ExecMsg::Job(j)) = exec_rx.try_recv() {
                    queue.push(j);
                }
                // Reconfiguration-aware batching: order the drained batch
                // so jobs sharing a VCK190 mapping run back-to-back (free
                // switches), then by artifact variant for executable reuse.
                queue.sort_by_key(|p| {
                    let tiling = p.result.plan.map(|pl| pl.tiling);
                    let variant = engine.as_ref().map(|eng| {
                        crate::runtime::pick_variant(
                            &eng.manifest.variants,
                            p.job.gemm.m,
                            p.job.gemm.n,
                            p.job.gemm.k,
                        )
                    });
                    (tiling.map(|t| (t.p_m, t.p_n, t.p_k, t.b_m, t.b_n, t.b_k)), variant)
                });
                for mut planned in queue.drain(..) {
                    // Account the simulated board-side mapping switch.
                    if let Some(plan) = planned.result.plan {
                        if current_mapping != Some(plan.tiling) {
                            let cost = reconfig.switch_time(
                                current_mapping.as_ref(),
                                &plan.tiling,
                                &board,
                            );
                            let mut s = lock_unpoisoned(&exec_stats);
                            s.reconfigs += 1;
                            s.simulated_reconfig_s += cost;
                            drop(s);
                            current_mapping = Some(plan.tiling);
                        }
                    }
                    execute_job(engine.as_ref(), &exec_stats, &mut planned);
                    let _ = result_tx.send(planned.result);
                }
            }
        });

        Coordinator {
            job_tx: Some(job_tx),
            result_rx,
            planners,
            executor: Some(executor),
            stats,
            cache,
            dse,
            plan_lat,
            cache_path: options.cache_path,
            rejected: VecDeque::new(),
            pending: 0,
        }
    }

    /// Enqueue a job. Never panics: if the coordinator is shut down or
    /// the planner pool is gone, a `JobResult` carrying the error is
    /// queued instead (surfaced by `next_result`/`run_batch`).
    pub fn submit(&mut self, job: GemmJob) {
        self.pending += 1;
        let refused = match &self.job_tx {
            Some(tx) => match tx.send(job) {
                Ok(()) => None,
                Err(SendError(job)) => Some((job, "planner pool unavailable")),
            },
            None => Some((job, "coordinator already shut down")),
        };
        if let Some((job, why)) = refused {
            lock_unpoisoned(&self.stats).jobs_failed += 1;
            self.rejected.push_back(JobResult {
                id: job.id,
                gemm: job.gemm,
                objective: job.objective,
                plan: None,
                plan_time: Duration::default(),
                cache_hit: false,
                exec_time: None,
                validation_err: None,
                c: None,
                error: Some(why.to_string()),
            });
        }
    }

    /// Wait for the next completed job.
    pub fn next_result(&mut self) -> Option<JobResult> {
        if self.pending == 0 {
            return None;
        }
        if let Some(r) = self.rejected.pop_front() {
            self.pending -= 1;
            return Some(r);
        }
        match self.result_rx.recv() {
            Ok(r) => {
                self.pending -= 1;
                Some(r)
            }
            Err(_) => None,
        }
    }

    /// Submit a batch and wait for all results (ordered by job id).
    pub fn run_batch(&mut self, jobs: Vec<GemmJob>) -> Vec<JobResult> {
        let n = jobs.len();
        for j in jobs {
            self.submit(j);
        }
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            match self.next_result() {
                Some(r) => out.push(r),
                None => break,
            }
        }
        out.sort_by_key(|r| r.id);
        out
    }

    pub fn stats(&self) -> CoordinatorStats {
        let mut s = *lock_unpoisoned(&self.stats);
        let cs = self.cache.stats();
        s.cache_evictions = cs.evictions;
        let lookups = s.cache_hits + s.cache_misses;
        s.cache_hit_rate = if lookups > 0 {
            s.cache_hits as f64 / lookups as f64
        } else {
            0.0
        };
        s.plan_p50_ms = lock_unpoisoned(&self.plan_lat).p50_ms();
        let fm = self.dse.predictors.forest_metrics();
        s.forest_compile_ms = fm.compile_ms;
        s.predict_rows_per_s = fm.rows_per_s();
        s
    }

    /// Direct view of the plan cache (tests, benches, diagnostics).
    pub fn plan_cache(&self) -> &ShardedPlanCache {
        &self.cache
    }

    /// Graceful shutdown: waits for in-flight work, then persists the
    /// plan cache when a path was configured.
    pub fn shutdown(&mut self) {
        if let Some(tx) = self.job_tx.take() {
            drop(tx);
        }
        for h in self.planners.drain(..) {
            let _ = h.join();
        }
        if let Some(h) = self.executor.take() {
            let _ = h.join();
        }
        if let Some(path) = self.cache_path.take() {
            match self.cache.save(&path) {
                Ok(()) => eprintln!(
                    "coordinator: persisted {} cached plans to {}",
                    self.cache.len(),
                    path.display()
                ),
                Err(e) => eprintln!("coordinator: failed to persist plan cache: {e}"),
            }
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn plan_job(
    dse: &DseEngine,
    sim: &VersalSim,
    cache: &ShardedPlanCache,
    stats: &Mutex<CoordinatorStats>,
    plan_lat: &Mutex<PlanLatencies>,
    job: GemmJob,
) -> PlannedJob {
    let started = Instant::now();
    let key = PlanKey::new(job.gemm, job.objective);
    let cached = cache.get(&key);
    let (plan, cache_hit, error) = match cached {
        Some(p) => (Some(p), true, None),
        None => match dse.explore(&job.gemm) {
            Err(e) => (None, false, Some(e.to_string())),
            Ok(r) => {
                // Walk the ranked list until a design actually builds
                // (absorbs resource-model error, like re-running codegen).
                let built = r.ranked(job.objective).into_iter().take(64).find_map(|c| {
                    sim.evaluate(&job.gemm, &c.tiling, BufferPlacement::UramFirst)
                        .ok()
                        .map(|m| Plan {
                            tiling: c.tiling,
                            predicted: c.prediction,
                            simulated: m,
                        })
                });
                match built {
                    None => (None, false, Some("no buildable design".to_string())),
                    Some(plan) => {
                        cache.insert(key, plan);
                        (Some(plan), false, None)
                    }
                }
            }
        },
    };
    let plan_time = started.elapsed();
    lock_unpoisoned(plan_lat).push(plan_time.as_secs_f64() * 1e3);
    {
        let mut s = lock_unpoisoned(stats);
        if cache_hit {
            s.cache_hits += 1;
        } else {
            s.cache_misses += 1;
        }
        if error.is_some() {
            s.jobs_failed += 1;
        } else {
            s.jobs_completed += 1;
            if let Some(p) = plan {
                s.simulated_energy_j += p.simulated.latency_s * p.simulated.power_w;
            }
        }
    }
    let result = JobResult {
        id: job.id,
        gemm: job.gemm,
        objective: job.objective,
        plan,
        plan_time,
        cache_hit,
        exec_time: None,
        validation_err: None,
        c: None,
        error,
    };
    PlannedJob { job, result }
}

fn execute_job(engine: Option<&GemmEngine>, stats: &Mutex<CoordinatorStats>, planned: &mut PlannedJob) {
    let job = &planned.job;
    let (a, b) = match (&job.a, &job.b) {
        (Some(a), Some(b)) => (a, b),
        _ => return,
    };
    let g = job.gemm;
    let Some(engine) = engine else {
        planned.result.error = Some("no artifact engine (run `make artifacts`)".into());
        return;
    };
    if a.len() != g.m * g.k || b.len() != g.k * g.n {
        planned.result.error = Some("operand size mismatch".into());
        return;
    }
    let started = Instant::now();
    match engine.gemm(a, b, g.m, g.n, g.k) {
        Err(e) => planned.result.error = Some(e.to_string()),
        Ok(c) => {
            let elapsed = started.elapsed();
            planned.result.exec_time = Some(elapsed);
            if job.validate {
                let want = matmul_ref(a, b, g.m, g.n, g.k);
                planned.result.validation_err = Some(max_abs_diff(&c, &want));
            }
            planned.result.c = Some(c);
            let mut s = lock_unpoisoned(stats);
            s.executed_jobs += 1;
            s.executed_flops += g.flops();
            s.exec_time_s += elapsed.as_secs_f64();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::Dataset;
    use crate::features::FeatureSet;
    use crate::models::Predictors;
    use crate::workloads::training_workloads;

    fn quick_cfg() -> Config {
        let mut cfg = Config::default();
        cfg.dataset.top_k = 10;
        cfg.dataset.bottom_k = 6;
        cfg.dataset.random_k = 30;
        cfg.train.n_trees = 60;
        cfg.train.learning_rate = 0.2;
        cfg
    }

    fn dse_engine(cfg: &Config) -> DseEngine {
        let wl: Vec<_> = training_workloads().into_iter().take(4).collect();
        let ds = Dataset::generate(cfg, &wl);
        DseEngine::new(Predictors::train(&ds, cfg, FeatureSet::SetIAndII), &cfg.board)
    }

    fn coordinator(cfg: &Config) -> Coordinator {
        Coordinator::start(cfg, dse_engine(cfg), None, 2)
    }

    #[test]
    fn plan_only_jobs_complete() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let jobs: Vec<GemmJob> = (0..6)
            .map(|i| {
                GemmJob::plan_only(
                    i,
                    Gemm::new(256 * (1 + (i as usize % 3)), 1024, 512),
                    if i % 2 == 0 {
                        Objective::Throughput
                    } else {
                        Objective::EnergyEfficiency
                    },
                )
            })
            .collect();
        let results = coord.run_batch(jobs);
        assert_eq!(results.len(), 6);
        for r in &results {
            assert!(r.error.is_none(), "job {} failed: {:?}", r.id, r.error);
            let plan = r.plan.expect("plan");
            assert!(plan.simulated.gflops > 0.0);
            assert!(r.exec_time.is_none());
        }
        // Ids are returned sorted by run_batch.
        let ids: Vec<u64> = results.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4, 5]);
    }

    #[test]
    fn dse_cache_hits_on_repeat_jobs() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let g = Gemm::new(512, 1024, 512);
        let jobs: Vec<GemmJob> = (0..8)
            .map(|i| GemmJob::plan_only(i, g, Objective::Throughput))
            .collect();
        let results = coord.run_batch(jobs);
        assert_eq!(results.len(), 8);
        let stats = coord.stats();
        assert!(stats.cache_hits >= 6, "cache hits {}", stats.cache_hits);
        assert!(stats.cache_misses >= 1);
        assert!(stats.cache_hit_rate > 0.5, "hit rate {}", stats.cache_hit_rate);
        assert!(stats.plan_p50_ms >= 0.0);
        // Cached plans are identical.
        let t0 = results[0].plan.unwrap().tiling;
        assert!(results.iter().all(|r| r.plan.unwrap().tiling == t0));
    }

    #[test]
    fn warm_plans_are_much_faster_than_cold() {
        // Acceptance: a cache-hit plan for a repeated (Gemm, Objective)
        // is >= 5x faster than the cold DSE plan (in practice ~1000x).
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let g = Gemm::new(512, 1024, 512);
        let cold = coord.run_batch(vec![GemmJob::plan_only(0, g, Objective::Throughput)]);
        assert!(!cold[0].cache_hit);
        let warm = coord.run_batch(
            (1..5)
                .map(|i| GemmJob::plan_only(i, g, Objective::Throughput))
                .collect(),
        );
        let cold_s = cold[0].plan_time.as_secs_f64();
        let warm_s = warm
            .iter()
            .map(|r| {
                assert!(r.cache_hit, "repeat job missed the cache");
                r.plan_time.as_secs_f64()
            })
            .fold(f64::INFINITY, f64::min);
        assert!(
            cold_s >= warm_s * 5.0,
            "cold {cold_s:.6}s not >= 5x warm {warm_s:.6}s"
        );
    }

    #[test]
    fn objectives_produce_potentially_different_plans() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let g = Gemm::new(224, 3072, 768);
        let results = coord.run_batch(vec![
            GemmJob::plan_only(0, g, Objective::Throughput),
            GemmJob::plan_only(1, g, Objective::EnergyEfficiency),
        ]);
        let p0 = results[0].plan.unwrap();
        let p1 = results[1].plan.unwrap();
        // Energy plan must not use more AIEs than 2x throughput plan
        // (typically fewer; equality allowed).
        assert!(p1.tiling.n_aie() <= p0.tiling.n_aie().max(1) * 2);
        assert_eq!(coord.stats().cache_misses, 2);
    }

    #[test]
    fn data_jobs_without_engine_report_error() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let g = Gemm::new(64, 64, 64);
        let a = vec![1f32; 64 * 64];
        let b = vec![1f32; 64 * 64];
        let results = coord.run_batch(vec![GemmJob::with_data(
            0,
            g,
            Objective::Throughput,
            a,
            b,
        )]);
        assert_eq!(results.len(), 1);
        assert!(results[0].error.as_deref().unwrap_or("").contains("artifact"));
    }

    #[test]
    fn shutdown_is_idempotent() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        coord.shutdown();
        coord.shutdown();
        assert_eq!(coord.next_result().is_none(), true);
    }

    #[test]
    fn submit_after_shutdown_surfaces_error_result() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        coord.shutdown();
        coord.submit(GemmJob::plan_only(7, Gemm::new(128, 256, 128), Objective::Throughput));
        let r = coord.next_result().expect("rejected job still yields a result");
        assert_eq!(r.id, 7);
        assert!(r.error.as_deref().unwrap_or("").contains("shut down"));
        assert!(coord.next_result().is_none());
        assert!(coord.stats().jobs_failed >= 1);
    }

    #[test]
    fn stats_accumulate() {
        let cfg = quick_cfg();
        let mut coord = coordinator(&cfg);
        let g = Gemm::new(256, 512, 512);
        let _ = coord.run_batch(vec![
            GemmJob::plan_only(0, g, Objective::Throughput),
            GemmJob::plan_only(1, g, Objective::Throughput),
        ]);
        let s = coord.stats();
        assert_eq!(s.jobs_completed, 2);
        assert!(s.simulated_energy_j > 0.0);
        // The forest engine compiled once and served the DSE chunks.
        assert!(s.forest_compile_ms > 0.0, "forest never compiled");
        assert!(s.predict_rows_per_s > 0.0, "no forest throughput recorded");
    }

    #[test]
    fn tiny_cache_evicts_and_reports() {
        let cfg = quick_cfg();
        let opts = CoordinatorOptions {
            n_shards: 1,
            cache_capacity: 1,
            cache_path: None,
        };
        let mut coord = Coordinator::start_with(&cfg, dse_engine(&cfg), None, 2, opts);
        let shapes = [
            Gemm::new(128, 256, 128),
            Gemm::new(256, 512, 256),
            Gemm::new(128, 512, 128),
        ];
        let jobs: Vec<GemmJob> = shapes
            .iter()
            .enumerate()
            .map(|(i, g)| GemmJob::plan_only(i as u64, *g, Objective::Throughput))
            .collect();
        let results = coord.run_batch(jobs);
        assert_eq!(results.len(), 3);
        let s = coord.stats();
        assert!(s.cache_evictions >= 1, "evictions {}", s.cache_evictions);
        assert!(coord.plan_cache().len() <= 1);
    }

    #[test]
    fn plan_cache_persists_across_restarts() {
        let cfg = quick_cfg();
        let dir = std::env::temp_dir().join("versal_gemm_coord_cache_test");
        let _ = std::fs::remove_dir_all(&dir);
        let path = dir.join("plans.json");
        let opts = CoordinatorOptions {
            cache_path: Some(path.clone()),
            ..CoordinatorOptions::default()
        };
        let engine = dse_engine(&cfg);
        let g = Gemm::new(512, 1024, 512);

        let mut first = Coordinator::start_with(&cfg, engine.clone(), None, 2, opts.clone());
        let r1 = first.run_batch(vec![GemmJob::plan_only(0, g, Objective::Throughput)]);
        assert!(r1[0].error.is_none());
        first.shutdown();
        assert!(path.exists(), "shutdown did not persist the cache");

        let mut second = Coordinator::start_with(&cfg, engine, None, 2, opts);
        let r2 = second.run_batch(vec![GemmJob::plan_only(0, g, Objective::Throughput)]);
        assert!(r2[0].cache_hit, "restarted coordinator did not warm from disk");
        assert_eq!(r1[0].plan.unwrap().tiling, r2[0].plan.unwrap().tiling);
        assert_eq!(second.stats().cache_hits, 1);
        let _ = std::fs::remove_dir_all(dir);
    }
}
