"""Pure-jnp oracle for the Pallas tiled GEMM kernel.

The CORE build-time correctness signal: every kernel variant must be
allclose to this reference before it is AOT-lowered into an artifact.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def gemm_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    """C = A @ B with f32 accumulation, matching the kernel's contract."""
    return jnp.dot(a, b, preferred_element_type=a.dtype)


def tiled_gemm_ref(a: jax.Array, b: jax.Array, block_k: int) -> jax.Array:
    """Reference that mimics the kernel's K-blocked accumulation order.

    Useful for tight tolerance checks: floating-point GEMM is not
    associative, so accumulating in the same K-block order as the kernel
    gives bit-closer results than one fused dot.
    """
    m, k = a.shape
    _, n = b.shape
    acc = jnp.zeros((m, n), dtype=a.dtype)
    for k0 in range(0, k, block_k):
        acc = acc + jnp.dot(
            a[:, k0 : k0 + block_k],
            b[k0 : k0 + block_k, :],
            preferred_element_type=a.dtype,
        )
    return acc
