//! Cross-validation and hyper-parameter search (paper §IV-A.3: 80/20
//! split, 5-fold CV, Bayesian optimization via Optuna — here a
//! deterministic random search over the same space, which is what
//! Optuna's TPE degenerates to at small trial counts).

use crate::config::TrainConfig;
use crate::gbdt::boost::Gbdt;
use crate::gbdt::tree::FeatureMatrix;
use crate::metrics::{mape, r2};
use crate::util::rng::Rng;

/// Deterministic k-fold index split.
pub fn kfold_indices(n: usize, folds: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    assert!(folds >= 2 && n >= folds);
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let mut out = vec![Vec::new(); folds];
    for (i, v) in idx.into_iter().enumerate() {
        out[i % folds].push(v);
    }
    out
}

/// Gather rows by index into a new matrix/target pair.
pub fn gather(x: &FeatureMatrix, y: &[f64], idx: &[usize]) -> (FeatureMatrix, Vec<f64>) {
    let rows: Vec<Vec<f64>> = idx.iter().map(|&i| x.row(i).to_vec()).collect();
    let targets: Vec<f64> = idx.iter().map(|&i| y[i]).collect();
    (FeatureMatrix::from_rows(&rows), targets)
}

/// CV result for one hyper-parameter setting.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CvScore {
    pub mean_r2: f64,
    pub mean_mape: f64,
}

/// k-fold CV of a GBDT on `(x, y)`. If `log_target` the model is fit on
/// `ln(y)` and evaluated after `exp` (the paper's latency transform).
pub fn cross_validate(
    x: &FeatureMatrix,
    y: &[f64],
    cfg: &TrainConfig,
    log_target: bool,
    seed: u64,
) -> CvScore {
    let folds = kfold_indices(x.n_rows, cfg.cv_folds, &mut Rng::new(seed));
    let mut r2s = Vec::new();
    let mut mapes = Vec::new();
    for f in 0..folds.len() {
        let test_idx = &folds[f];
        let train_idx: Vec<usize> = folds
            .iter()
            .enumerate()
            .filter(|(i, _)| *i != f)
            .flat_map(|(_, v)| v.iter().copied())
            .collect();
        let (xt, yt_raw) = gather(x, y, &train_idx);
        let (xv, yv) = gather(x, y, test_idx);
        let yt: Vec<f64> = if log_target {
            yt_raw.iter().map(|v| v.ln()).collect()
        } else {
            yt_raw
        };
        let model = Gbdt::fit(&xt, &yt, cfg, None, &mut Rng::new(cfg.seed ^ f as u64));
        // Fold scoring goes through the batched forest path (compile is
        // O(nodes), trivial next to the fold's fit).
        let mut pred = model.predict_batch(&xv);
        if log_target {
            for p in &mut pred {
                *p = p.exp();
            }
        }
        r2s.push(r2(&yv, &pred));
        mapes.push(mape(&yv, &pred));
    }
    CvScore {
        mean_r2: r2s.iter().sum::<f64>() / r2s.len() as f64,
        mean_mape: mapes.iter().sum::<f64>() / mapes.len() as f64,
    }
}

/// Random hyper-parameter search minimizing CV MAPE; returns the best
/// config (search space mirrors the paper's Optuna ranges).
pub fn search_hyperparams(
    x: &FeatureMatrix,
    y: &[f64],
    base: &TrainConfig,
    log_target: bool,
) -> (TrainConfig, CvScore) {
    let mut rng = Rng::new(base.seed ^ 0x5EA5C);
    let mut best_cfg = base.clone();
    let mut best = cross_validate(x, y, base, log_target, base.seed);
    for trial in 0..base.search_trials {
        let cand = TrainConfig {
            n_trees: rng.range_usize(100, 400),
            max_depth: rng.range_usize(4, 9),
            learning_rate: rng.range_f64(0.03, 0.2),
            min_samples_leaf: rng.range_usize(2, 10),
            subsample: rng.range_f64(0.6, 1.0),
            colsample: rng.range_f64(0.6, 1.0),
            lambda: rng.range_f64(0.1, 5.0),
            seed: base.seed ^ (trial as u64 + 1),
            ..base.clone()
        };
        let score = cross_validate(x, y, &cand, log_target, base.seed);
        if score.mean_mape < best.mean_mape {
            best = score;
            best_cfg = cand;
        }
    }
    (best_cfg, best)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(n: usize, seed: u64) -> (FeatureMatrix, Vec<f64>) {
        let mut rng = Rng::new(seed);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..n {
            let a = rng.range_f64(1.0, 10.0);
            let b = rng.range_f64(1.0, 10.0);
            rows.push(vec![a, b]);
            y.push(a * b + 1.0);
        }
        (FeatureMatrix::from_rows(&rows), y)
    }

    #[test]
    fn kfold_partitions_everything() {
        let folds = kfold_indices(103, 5, &mut Rng::new(1));
        assert_eq!(folds.len(), 5);
        let mut all: Vec<usize> = folds.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..103).collect::<Vec<_>>());
        // Balanced within 1.
        let sizes: Vec<usize> = folds.iter().map(Vec::len).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
    }

    #[test]
    fn cv_scores_reasonable_model() {
        let (x, y) = synth(400, 7);
        let cfg = TrainConfig {
            n_trees: 60,
            learning_rate: 0.2,
            cv_folds: 4,
            ..TrainConfig::default()
        };
        let score = cross_validate(&x, &y, &cfg, false, 3);
        assert!(score.mean_r2 > 0.9, "r2 {}", score.mean_r2);
        assert!(score.mean_mape < 15.0, "mape {}", score.mean_mape);
    }

    #[test]
    fn log_target_helps_multiplicative_data() {
        let mut rng = Rng::new(9);
        let mut rows = Vec::new();
        let mut y = Vec::new();
        for _ in 0..400 {
            let a = rng.range_f64(0.0, 8.0);
            rows.push(vec![a]);
            y.push((a * 1.5).exp()); // spans many decades
        }
        let x = FeatureMatrix::from_rows(&rows);
        let cfg = TrainConfig {
            n_trees: 80,
            learning_rate: 0.2,
            cv_folds: 4,
            ..TrainConfig::default()
        };
        let raw = cross_validate(&x, &y, &cfg, false, 1);
        let logd = cross_validate(&x, &y, &cfg, true, 1);
        assert!(
            logd.mean_mape < raw.mean_mape,
            "log {} raw {}",
            logd.mean_mape,
            raw.mean_mape
        );
    }

    #[test]
    fn search_improves_or_keeps_baseline() {
        let (x, y) = synth(200, 13);
        let base = TrainConfig {
            n_trees: 20,
            max_depth: 2,
            learning_rate: 0.05,
            search_trials: 4,
            cv_folds: 3,
            ..TrainConfig::default()
        };
        let baseline = cross_validate(&x, &y, &base, false, base.seed);
        let (_, best) = search_hyperparams(&x, &y, &base, false);
        assert!(best.mean_mape <= baseline.mean_mape + 1e-9);
    }
}
