//! ASCII table / series rendering for the `report` module.
//!
//! Every paper figure and table is regenerated as text: tables render with
//! aligned columns, figures render as labeled series (and, where useful,
//! a coarse scatter plot) so the *shape* of each result is visible in a
//! terminal and diffable in EXPERIMENTS.md.

/// A simple column-aligned table.
#[derive(Debug, Default, Clone)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table `{}`",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, cell) in row.iter().enumerate() {
                widths[i] = widths[i].max(cell.chars().count());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for i in 0..ncol {
                let cell = cells.get(i).map(String::as_str).unwrap_or("");
                let pad = widths[i] - cell.chars().count();
                s.push(' ');
                s.push_str(cell);
                s.push_str(&" ".repeat(pad + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("{}\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with a sensible number of digits for report cells.
pub fn fnum(x: f64) -> String {
    if !x.is_finite() {
        return format!("{x}");
    }
    let a = x.abs();
    if a >= 1000.0 {
        format!("{x:.0}")
    } else if a >= 100.0 {
        format!("{x:.1}")
    } else if a >= 1.0 {
        format!("{x:.2}")
    } else if a == 0.0 {
        "0".to_string()
    } else {
        format!("{x:.4}")
    }
}

/// Render an xy scatter as a coarse character grid (for Fig. 1 / Fig. 10
/// style frontier plots). Points are given as (x, y, glyph).
pub fn scatter_plot(
    title: &str,
    points: &[(f64, f64, char)],
    width: usize,
    height: usize,
    xlabel: &str,
    ylabel: &str,
) -> String {
    let mut out = format!("{title}\n");
    if points.is_empty() {
        out.push_str("  (no points)\n");
        return out;
    }
    let (mut xmin, mut xmax) = (f64::INFINITY, f64::NEG_INFINITY);
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &(x, y, _) in points {
        xmin = xmin.min(x);
        xmax = xmax.max(x);
        ymin = ymin.min(y);
        ymax = ymax.max(y);
    }
    if (xmax - xmin).abs() < 1e-12 {
        xmax = xmin + 1.0;
    }
    if (ymax - ymin).abs() < 1e-12 {
        ymax = ymin + 1.0;
    }
    let mut grid = vec![vec![' '; width]; height];
    for &(x, y, glyph) in points {
        let cx = (((x - xmin) / (xmax - xmin)) * (width - 1) as f64).round() as usize;
        let cy = (((y - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
        let row = height - 1 - cy.min(height - 1);
        let col = cx.min(width - 1);
        // Later points overwrite earlier ones only if the cell is blank or
        // a "background" dot, so highlighted glyphs stay visible.
        if grid[row][col] == ' ' || glyph != '.' {
            grid[row][col] = glyph;
        }
    }
    out.push_str(&format!("  {ylabel} ({:.3} .. {:.3})\n", ymin, ymax));
    for row in grid {
        out.push_str("  |");
        out.push_str(&row.into_iter().collect::<String>());
        out.push('\n');
    }
    out.push_str("  +");
    out.push_str(&"-".repeat(width));
    out.push('\n');
    out.push_str(&format!("   {xlabel} ({:.3} .. {:.3})\n", xmin, xmax));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(vec!["a".into(), "1".into()]);
        t.row(vec!["long-name".into(), "2.5".into()]);
        let s = t.render();
        assert!(s.contains("| name      | value |"));
        assert!(s.contains("| long-name | 2.5   |"));
        // All separator lines are identical.
        let seps: Vec<&str> = s.lines().filter(|l| l.starts_with('+')).collect();
        assert_eq!(seps.len(), 3);
        assert!(seps.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn fnum_ranges() {
        assert_eq!(fnum(12345.6), "12346");
        assert_eq!(fnum(123.45), "123.5");
        assert_eq!(fnum(1.234), "1.23");
        assert_eq!(fnum(0.01234), "0.0123");
        assert_eq!(fnum(0.0), "0");
    }

    #[test]
    fn scatter_contains_glyphs() {
        let pts = vec![(0.0, 0.0, '.'), (1.0, 1.0, '*'), (0.5, 0.5, 'o')];
        let s = scatter_plot("t", &pts, 20, 8, "x", "y");
        assert!(s.contains('*'));
        assert!(s.contains('o'));
        assert!(s.contains("x (0.000 .. 1.000)"));
    }

    #[test]
    fn scatter_empty() {
        let s = scatter_plot("t", &[], 10, 4, "x", "y");
        assert!(s.contains("no points"));
    }
}
