//! Bench: Fig. 4 — exhaustive energy/throughput trade-off analysis
//! across all 13 eval workloads.
use versal_gemm::config::Config;
use versal_gemm::report::{figures, Lab};
use versal_gemm::util::bench::once;

fn main() -> anyhow::Result<()> {
    let lab = Lab::prepare(Config::default(), "data".into())?;
    let fig = once("fig4: exhaustive tradeoffs G1..G13", || figures::fig4_tradeoffs(&lab));
    println!("{fig}");
    Ok(())
}
