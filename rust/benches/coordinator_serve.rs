//! Bench: coordinator serving throughput (plan-only path: streaming DSE
//! + sharded plan cache + channels), the L3 router hot path.
use versal_gemm::config::Config;
use versal_gemm::coordinator::{Coordinator, CoordinatorOptions, GemmJob};
use versal_gemm::dse::Objective;
use versal_gemm::report::Lab;
use versal_gemm::util::bench::once;
use versal_gemm::workloads::Gemm;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let lab = Lab::prepare(cfg.clone(), "data".into())?;
    println!("== bench: coordinator plan-only serving (sharded plan cache) ==");
    let options = CoordinatorOptions::default();
    println!(
        "cache: {} shards, {} total capacity",
        options.n_shards, options.cache_capacity
    );
    let mut coord = Coordinator::start_with(&cfg, lab.engine(), None, 4, options);
    let shapes = [
        Gemm::new(512, 1024, 512),
        Gemm::new(224, 3072, 768),
        Gemm::new(32, 4864, 896),
        Gemm::new(2048, 2048, 2048),
    ];
    // Cold: 8 distinct (shape, objective) plans; warm: 192 cached jobs.
    let jobs: Vec<GemmJob> = (0..200u64)
        .map(|i| {
            GemmJob::plan_only(
                i,
                shapes[(i % 4) as usize],
                if i % 2 == 0 { Objective::Throughput } else { Objective::EnergyEfficiency },
            )
        })
        .collect();
    let results = once("serve 200 plan jobs (8 unique plans)", || coord.run_batch(jobs));
    assert_eq!(results.len(), 200);
    let stats = coord.stats();
    println!(
        "cache: {} hits / {} misses / {} evictions ({:.0}% hit rate); failed {}",
        stats.cache_hits,
        stats.cache_misses,
        stats.cache_evictions,
        100.0 * stats.cache_hit_rate,
        stats.jobs_failed
    );
    println!(
        "forest: compiled in {:.2} ms, {:.0} rows/s per planner thread",
        stats.forest_compile_ms, stats.predict_rows_per_s
    );
    let cold: Vec<f64> = results
        .iter()
        .filter(|r| !r.cache_hit)
        .map(|r| r.plan_time.as_secs_f64())
        .collect();
    let warm: Vec<f64> = results
        .iter()
        .filter(|r| r.cache_hit)
        .map(|r| r.plan_time.as_secs_f64())
        .collect();
    let cold_med = versal_gemm::metrics::median(&cold);
    let warm_med = versal_gemm::metrics::median(&warm);
    println!(
        "plan latency: cold median {:.2} ms over {} jobs, warm median {:.1} us over {} jobs \
         (p50 overall {:.3} ms)",
        cold_med * 1e3,
        cold.len(),
        warm_med * 1e6,
        warm.len(),
        stats.plan_p50_ms
    );
    // Acceptance: a warm (cache-hit) plan is >= 5x faster than cold.
    assert!(
        cold_med >= warm_med * 5.0,
        "warm plans not >=5x faster: cold {cold_med:.6}s warm {warm_med:.6}s"
    );
    println!(
        "speedup warm vs cold: {:.0}x (acceptance floor: 5x)",
        cold_med / warm_med.max(1e-12)
    );
    Ok(())
}
