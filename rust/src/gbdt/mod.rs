//! From-scratch Gradient Boosted Decision Trees (the paper's model class,
//! §IV-A.3): histogram-split regression trees, squared-loss boosting with
//! shrinkage and row/column subsampling, a multi-output wrapper for the
//! resource model, k-fold CV + hyper-parameter search, and a compiled
//! forest-inference engine ([`forest::CompiledForest`]) that flattens
//! whole model bundles into one node arena for row-blocked traversal.

pub mod baselines;
pub mod boost;
pub mod cv;
pub mod forest;
pub mod multi;
pub mod tree;

pub use boost::Gbdt;
pub use forest::{CompiledForest, ForestMetrics, ROW_BLOCK};
pub use multi::MultiGbdt;
pub use tree::{BinnedMatrix, FeatureMatrix, RegressionTree, TreeParams, MAX_BINS};
