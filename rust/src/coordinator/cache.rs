//! N-way sharded, LRU-evicting plan cache — the coordinator's memory of
//! the online phase.
//!
//! The seed coordinator serialized every planner on one
//! `Mutex<HashMap>`; under heavy plan-only traffic the lock, not the
//! DSE, became the bottleneck once plans were warm. This cache shards
//! the key space `hash(Gemm, Objective) % N` so concurrent planners
//! contend only when they race the *same* shard, bounds memory with
//! per-shard LRU eviction, counts hits/misses/evictions (folded into
//! `CoordinatorStats`), and persists to JSON via `util::json` so a
//! restarted coordinator warms from disk (`--plan-cache` in `serve`).

use std::collections::HashMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::coordinator::Plan;
use crate::dse::Objective;
use crate::models::Prediction;
use crate::tiling::Tiling;
use crate::util::json::{arr, num, obj, Json};
use crate::util::lock_unpoisoned;
use crate::util::rng::fnv1a;
use crate::versal::{Measurement, Resources};
use crate::workloads::Gemm;

/// Stable objective <-> tag mapping used by cache keys and persistence.
pub fn objective_tag(o: Objective) -> u8 {
    match o {
        Objective::Throughput => 0,
        Objective::EnergyEfficiency => 1,
    }
}

pub fn objective_from_tag(tag: u8) -> Option<Objective> {
    match tag {
        0 => Some(Objective::Throughput),
        1 => Some(Objective::EnergyEfficiency),
        _ => None,
    }
}

/// Cache key: one plan per `(workload, objective)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub gemm: Gemm,
    pub objective_tag: u8,
}

impl PlanKey {
    pub fn new(gemm: Gemm, objective: Objective) -> PlanKey {
        PlanKey {
            gemm,
            objective_tag: objective_tag(objective),
        }
    }

    /// Deterministic 64-bit key hash (FNV-1a over the dims + tag), so
    /// shard placement is stable across runs and processes.
    fn hash64(&self) -> u64 {
        let mut bytes = [0u8; 32];
        let fields = [
            self.gemm.m as u64,
            self.gemm.n as u64,
            self.gemm.k as u64,
            self.objective_tag as u64,
        ];
        for (i, f) in fields.iter().enumerate() {
            bytes[i * 8..(i + 1) * 8].copy_from_slice(&f.to_le_bytes());
        }
        fnv1a(&bytes)
    }
}

/// Aggregate cache counters.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub entries: usize,
}

#[derive(Debug)]
struct Entry {
    plan: Plan,
    last_used: u64,
}

#[derive(Debug, Default)]
struct Shard {
    map: HashMap<PlanKey, Entry>,
    /// Monotonic per-shard recency clock (bumped on every access).
    tick: u64,
}

/// The sharded plan cache. All methods take `&self`; interior shard
/// locks are poison-proof so a panicking planner cannot wedge the pool.
#[derive(Debug)]
pub struct ShardedPlanCache {
    shards: Vec<Mutex<Shard>>,
    capacity_per_shard: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    evictions: AtomicU64,
}

impl ShardedPlanCache {
    /// `capacity` is the TOTAL entry budget — an upper bound, never
    /// exceeded. It is split evenly over `n_shards`; the shard count is
    /// clamped to the capacity so tiny budgets cannot inflate (8 shards
    /// with capacity 4 become 4 shards of 1, not 8 entries).
    pub fn new(n_shards: usize, capacity: usize) -> ShardedPlanCache {
        let capacity = capacity.max(1);
        let n = n_shards.clamp(1, capacity);
        let per_shard = capacity / n;
        ShardedPlanCache {
            shards: (0..n).map(|_| Mutex::new(Shard::default())).collect(),
            capacity_per_shard: per_shard,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            evictions: AtomicU64::new(0),
        }
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// Effective total capacity across shards (<= the requested budget;
    /// even division can round it down slightly).
    pub fn capacity(&self) -> usize {
        self.capacity_per_shard * self.shards.len()
    }

    fn shard(&self, key: &PlanKey) -> &Mutex<Shard> {
        &self.shards[(key.hash64() % self.shards.len() as u64) as usize]
    }

    /// Look up a plan, bumping its recency and the hit/miss counters.
    pub fn get(&self, key: &PlanKey) -> Option<Plan> {
        let mut shard = lock_unpoisoned(self.shard(key));
        shard.tick += 1;
        let tick = shard.tick;
        match shard.map.get_mut(key) {
            Some(e) => {
                e.last_used = tick;
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(e.plan)
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    /// Look up a plan WITHOUT touching recency or the hit/miss counters
    /// — the graph planner's poll while it waits on another planner's
    /// in-flight exploration, where counting a hit/miss per poll would
    /// corrupt the stats.
    pub fn peek(&self, key: &PlanKey) -> Option<Plan> {
        lock_unpoisoned(self.shard(key)).map.get(key).map(|e| e.plan)
    }

    /// Insert (or refresh) a plan, evicting the shard's least-recently
    /// -used entry when the shard is at capacity.
    pub fn insert(&self, key: PlanKey, plan: Plan) {
        let mut shard = lock_unpoisoned(self.shard(&key));
        shard.tick += 1;
        let tick = shard.tick;
        if !shard.map.contains_key(&key) && shard.map.len() >= self.capacity_per_shard {
            if let Some(victim) = shard
                .map
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(k, _)| *k)
            {
                shard.map.remove(&victim);
                self.evictions.fetch_add(1, Ordering::Relaxed);
            }
        }
        shard.map.insert(
            key,
            Entry {
                plan,
                last_used: tick,
            },
        );
    }

    pub fn len(&self) -> usize {
        self.shards
            .iter()
            .map(|s| lock_unpoisoned(s).map.len())
            .sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }

    // -- persistence -----------------------------------------------------

    /// Serialize every cached entry (order-insensitive snapshot).
    pub fn to_json(&self) -> Json {
        let mut entries = Vec::new();
        for shard in &self.shards {
            let shard = lock_unpoisoned(shard);
            for (key, e) in shard.map.iter() {
                entries.push(entry_json(key, &e.plan));
            }
        }
        obj(vec![("version", num(1.0)), ("plans", arr(entries))])
    }

    /// Rebuild a cache from a snapshot under new shard/capacity settings
    /// (entries beyond capacity evict LRU-arbitrarily, which is fine for
    /// a warm-start hint). Malformed entries are skipped, not fatal: a
    /// stale cache file must never prevent the coordinator from booting.
    pub fn from_json(json: &Json, n_shards: usize, capacity: usize) -> ShardedPlanCache {
        let cache = ShardedPlanCache::new(n_shards, capacity);
        if let Some(plans) = json.get("plans").and_then(Json::as_arr) {
            for p in plans {
                if let Some((key, plan)) = entry_from_json(p) {
                    cache.insert(key, plan);
                }
            }
        }
        // A warm start is not a "hit" and skews nothing: reset counters.
        cache.hits.store(0, Ordering::Relaxed);
        cache.misses.store(0, Ordering::Relaxed);
        cache.evictions.store(0, Ordering::Relaxed);
        cache
    }

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    pub fn load(path: &Path, n_shards: usize, capacity: usize) -> anyhow::Result<ShardedPlanCache> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading plan cache {}: {e}", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow::anyhow!("plan cache: {e}"))?;
        Ok(ShardedPlanCache::from_json(&json, n_shards, capacity))
    }
}

/// Graph-level plan cache: one entry per whole DAG
/// ([`crate::workloads::graph::GemmGraph::dag_hash`] keyed), holding the
/// per-node plans in node order. A hit skips the per-node key walk and
/// every single-flight interaction — a repeated forward pass plans in
/// one lookup. Bounded FIFO eviction (graphs are few and coarse; LRU
/// precision buys nothing here).
#[derive(Debug)]
pub struct GraphPlanCache {
    inner: Mutex<GraphCacheState>,
    capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
}

#[derive(Debug, Default)]
struct GraphCacheState {
    map: HashMap<u64, Vec<Plan>>,
    /// Insertion order for FIFO eviction.
    order: Vec<u64>,
}

impl GraphPlanCache {
    pub fn new(capacity: usize) -> GraphPlanCache {
        GraphPlanCache {
            inner: Mutex::new(GraphCacheState::default()),
            capacity: capacity.max(1),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Per-node plans for a previously planned DAG, in node order.
    pub fn get(&self, dag_hash: u64) -> Option<Vec<Plan>> {
        let inner = lock_unpoisoned(&self.inner);
        match inner.map.get(&dag_hash) {
            Some(plans) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Some(plans.clone())
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                None
            }
        }
    }

    pub fn insert(&self, dag_hash: u64, plans: Vec<Plan>) {
        let mut inner = lock_unpoisoned(&self.inner);
        if !inner.map.contains_key(&dag_hash) && inner.map.len() >= self.capacity {
            if !inner.order.is_empty() {
                let victim = inner.order.remove(0);
                inner.map.remove(&victim);
            }
        }
        if inner.map.insert(dag_hash, plans).is_none() {
            inner.order.push(dag_hash);
        }
    }

    pub fn len(&self) -> usize {
        lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

fn entry_json(key: &PlanKey, plan: &Plan) -> Json {
    let t = &plan.tiling;
    let pred = &plan.predicted;
    let sim = &plan.simulated;
    let res = &sim.resources;
    obj(vec![
        ("m", num(key.gemm.m as f64)),
        ("n", num(key.gemm.n as f64)),
        ("k", num(key.gemm.k as f64)),
        ("obj", num(key.objective_tag as f64)),
        (
            "tiling",
            arr([
                num(t.p_m as f64),
                num(t.p_n as f64),
                num(t.p_k as f64),
                num(t.b_m as f64),
                num(t.b_n as f64),
                num(t.b_k as f64),
            ]),
        ),
        ("pred_latency_s", num(pred.latency_s)),
        ("pred_power_w", num(pred.power_w)),
        ("pred_resources_pct", arr(pred.resources_pct.iter().map(|&v| num(v)))),
        ("sim_latency_s", num(sim.latency_s)),
        ("sim_power_w", num(sim.power_w)),
        ("sim_gflops", num(sim.gflops)),
        ("sim_energy_eff", num(sim.energy_eff)),
        ("sim_busy", num(sim.busy)),
        (
            "sim_resources",
            arr([
                num(res.bram as f64),
                num(res.uram as f64),
                num(res.lut as f64),
                num(res.ff as f64),
                num(res.dsp as f64),
            ]),
        ),
    ])
}

fn entry_from_json(json: &Json) -> Option<(PlanKey, Plan)> {
    let usize_field = |k: &str| json.get(k).and_then(Json::as_usize);
    let f64_field = |k: &str| json.get(k).and_then(Json::as_f64);
    let gemm = Gemm::new(usize_field("m")?, usize_field("n")?, usize_field("k")?);
    // Range-check BEFORE narrowing: `256 as u8` would wrap to a "valid"
    // tag and let a corrupted entry masquerade as a Throughput plan.
    let tag_raw = usize_field("obj")?;
    if tag_raw > u8::MAX as usize {
        return None;
    }
    let tag = tag_raw as u8;
    objective_from_tag(tag)?;
    let tl = json.get("tiling")?.as_arr()?;
    if tl.len() != 6 {
        return None;
    }
    let tv: Vec<usize> = tl.iter().filter_map(Json::as_usize).collect();
    if tv.len() != 6 || tv.iter().any(|&v| v == 0) {
        return None;
    }
    let tiling = Tiling::new((tv[0], tv[1], tv[2]), (tv[3], tv[4], tv[5]));
    let pr = json.get("pred_resources_pct")?.as_arr()?;
    let prv: Vec<f64> = pr.iter().filter_map(Json::as_f64).collect();
    if prv.len() != 5 {
        return None;
    }
    let mut resources_pct = [0.0; 5];
    resources_pct.copy_from_slice(&prv);
    let predicted = Prediction {
        latency_s: f64_field("pred_latency_s")?,
        power_w: f64_field("pred_power_w")?,
        resources_pct,
    };
    let sr = json.get("sim_resources")?.as_arr()?;
    let srv: Vec<usize> = sr.iter().filter_map(Json::as_usize).collect();
    if srv.len() != 5 {
        return None;
    }
    let simulated = Measurement {
        latency_s: f64_field("sim_latency_s")?,
        power_w: f64_field("sim_power_w")?,
        resources: Resources {
            bram: srv[0],
            uram: srv[1],
            lut: srv[2],
            ff: srv[3],
            dsp: srv[4],
        },
        gflops: f64_field("sim_gflops")?,
        energy_eff: f64_field("sim_energy_eff")?,
        busy: f64_field("sim_busy")?,
    };
    Some((
        PlanKey {
            gemm,
            objective_tag: tag,
        },
        Plan {
            tiling,
            predicted,
            simulated,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn plan(p_m: usize) -> Plan {
        Plan {
            tiling: Tiling::new((p_m, 2, 1), (1, 2, 4)),
            predicted: Prediction {
                latency_s: 1e-3 * p_m as f64,
                power_w: 20.0,
                resources_pct: [1.0, 2.0, 3.0, 4.0, 5.0],
            },
            simulated: Measurement {
                latency_s: 1.1e-3 * p_m as f64,
                power_w: 21.5,
                resources: Resources {
                    bram: 10 * p_m,
                    uram: 3,
                    lut: 12_345,
                    ff: 23_456,
                    dsp: 78,
                },
                gflops: 100.0 + p_m as f64,
                energy_eff: 5.0,
                busy: 0.9,
            },
        }
    }

    fn key(m: usize, obj: Objective) -> PlanKey {
        PlanKey::new(Gemm::new(m, 64, 64), obj)
    }

    #[test]
    fn get_insert_roundtrip_and_counters() {
        let cache = ShardedPlanCache::new(4, 64);
        let k = key(128, Objective::Throughput);
        assert_eq!(cache.get(&k), None);
        cache.insert(k, plan(4));
        assert_eq!(cache.get(&k).unwrap().tiling.p_m, 4);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.evictions, s.entries), (1, 1, 0, 1));
        // Objectives key separately.
        assert_eq!(cache.get(&key(128, Objective::EnergyEfficiency)), None);
    }

    #[test]
    fn peek_does_not_touch_counters_or_recency() {
        let cache = ShardedPlanCache::new(1, 2);
        let (ka, kb, kc) = (
            key(32, Objective::Throughput),
            key(64, Objective::Throughput),
            key(96, Objective::Throughput),
        );
        assert_eq!(cache.peek(&ka), None);
        cache.insert(ka, plan(1));
        cache.insert(kb, plan(2));
        // Peek A many times: counters stay untouched AND A gains no
        // recency — it is still the LRU victim when C arrives.
        for _ in 0..10 {
            assert!(cache.peek(&ka).is_some());
        }
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (0, 0), "peek moved the counters");
        cache.insert(kc, plan(3));
        assert!(cache.peek(&ka).is_none(), "peek bumped recency");
        assert!(cache.peek(&kb).is_some() && cache.peek(&kc).is_some());
    }

    #[test]
    fn graph_cache_roundtrip_and_fifo_eviction() {
        let cache = GraphPlanCache::new(2);
        assert!(cache.get(1).is_none());
        cache.insert(1, vec![plan(1), plan(2)]);
        cache.insert(2, vec![plan(3)]);
        let got = cache.get(1).expect("hit");
        assert_eq!(got.len(), 2);
        assert_eq!(got[0].tiling.p_m, 1);
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // Refresh of an existing key evicts nothing.
        cache.insert(1, vec![plan(9)]);
        assert_eq!(cache.len(), 2);
        // Third distinct key evicts the oldest (FIFO: key 1).
        cache.insert(3, vec![plan(4)]);
        assert_eq!(cache.len(), 2);
        assert!(cache.get(1).is_none(), "FIFO victim survived");
        assert!(cache.get(2).is_some() && cache.get(3).is_some());
    }

    #[test]
    fn lru_eviction_order() {
        // Single shard, capacity 2: classic LRU sequence.
        let cache = ShardedPlanCache::new(1, 2);
        let (ka, kb, kc) = (
            key(32, Objective::Throughput),
            key(64, Objective::Throughput),
            key(96, Objective::Throughput),
        );
        cache.insert(ka, plan(1));
        cache.insert(kb, plan(2));
        // Touch A so B becomes the LRU victim.
        assert!(cache.get(&ka).is_some());
        cache.insert(kc, plan(3));
        assert_eq!(cache.len(), 2);
        assert_eq!(cache.stats().evictions, 1);
        assert!(cache.get(&ka).is_some(), "recently-used entry evicted");
        assert!(cache.get(&kb).is_none(), "LRU entry survived");
        assert!(cache.get(&kc).is_some());
    }

    #[test]
    fn reinserting_existing_key_does_not_evict() {
        let cache = ShardedPlanCache::new(1, 2);
        let (ka, kb) = (key(32, Objective::Throughput), key(64, Objective::Throughput));
        cache.insert(ka, plan(1));
        cache.insert(kb, plan(2));
        cache.insert(ka, plan(5)); // refresh, at capacity
        assert_eq!(cache.stats().evictions, 0);
        assert_eq!(cache.get(&ka).unwrap().tiling.p_m, 5);
        assert!(cache.get(&kb).is_some());
    }

    #[test]
    fn concurrent_hit_miss_accounting() {
        let cache = Arc::new(ShardedPlanCache::new(8, 1024));
        let n_threads = 4usize;
        let per_thread = 200usize;
        // Pre-populate half the key space.
        for m in 0..per_thread {
            if m % 2 == 0 {
                cache.insert(key(32 * (m + 1), Objective::Throughput), plan(1));
            }
        }
        let mut handles = Vec::new();
        for _ in 0..n_threads {
            let cache = Arc::clone(&cache);
            handles.push(std::thread::spawn(move || {
                let mut hits = 0u64;
                for m in 0..per_thread {
                    if cache.get(&key(32 * (m + 1), Objective::Throughput)).is_some() {
                        hits += 1;
                    }
                }
                hits
            }));
        }
        let local_hits: u64 = handles.into_iter().map(|h| h.join().unwrap()).sum();
        let s = cache.stats();
        assert_eq!(local_hits, (per_thread as u64 / 2) * n_threads as u64);
        assert_eq!(s.hits, local_hits);
        assert_eq!(s.hits + s.misses, (n_threads * per_thread) as u64);
        assert_eq!(s.evictions, 0);
    }

    #[test]
    fn deterministic_sharding() {
        let k = key(224, Objective::EnergyEfficiency);
        let a = ShardedPlanCache::new(8, 64);
        let b = ShardedPlanCache::new(8, 64);
        a.insert(k, plan(2));
        b.insert(k, plan(2));
        // Same shard index both times: hash64 is process-independent.
        let idx_a = (k.hash64() % 8) as usize;
        let idx_b = (k.hash64() % 8) as usize;
        assert_eq!(idx_a, idx_b);
        assert!(a.get(&k).is_some() && b.get(&k).is_some());
    }

    #[test]
    fn json_roundtrip_preserves_plans() {
        let cache = ShardedPlanCache::new(4, 64);
        for m in [32usize, 64, 224] {
            cache.insert(key(m, Objective::Throughput), plan(m / 32));
            cache.insert(key(m, Objective::EnergyEfficiency), plan(m / 16));
        }
        let json = cache.to_json();
        let back = ShardedPlanCache::from_json(&json, 2, 64);
        assert_eq!(back.len(), cache.len());
        for m in [32usize, 64, 224] {
            let k = key(m, Objective::Throughput);
            assert_eq!(back.get(&k), cache.get(&k));
        }
        // Text roundtrip too.
        let reparsed = Json::parse(&json.to_string_compact()).unwrap();
        let again = ShardedPlanCache::from_json(&reparsed, 8, 64);
        assert_eq!(again.len(), cache.len());
    }

    #[test]
    fn capacity_budget_is_an_upper_bound() {
        // 8 shards with budget 4 must clamp, not inflate to 8 entries.
        let cache = ShardedPlanCache::new(8, 4);
        assert_eq!(cache.n_shards(), 4);
        assert!(cache.capacity() <= 4);
        for m in 1..=10usize {
            cache.insert(key(32 * m, Objective::Throughput), plan(1));
        }
        assert!(cache.len() <= 4, "cache grew past its budget: {}", cache.len());
        // Exact division stays exact.
        assert_eq!(ShardedPlanCache::new(8, 1024).capacity(), 1024);
    }

    #[test]
    fn out_of_range_objective_tag_is_rejected() {
        // A tag of 256 must not wrap to 0 and load as a Throughput plan.
        let good = ShardedPlanCache::new(1, 8);
        good.insert(key(32, Objective::Throughput), plan(1));
        let mut text = good.to_json().to_string_compact();
        text = text.replace("\"obj\":0", "\"obj\":256");
        let tampered = Json::parse(&text).unwrap();
        let back = ShardedPlanCache::from_json(&tampered, 1, 8);
        assert!(back.is_empty(), "wrapped objective tag was accepted");
    }

    #[test]
    fn malformed_snapshot_entries_are_skipped() {
        let json = Json::parse(
            r#"{"version": 1, "plans": [{"m": 32, "n": "bad"}, 17, {"m": 32}]}"#,
        )
        .unwrap();
        let cache = ShardedPlanCache::from_json(&json, 4, 64);
        assert!(cache.is_empty());
    }

    #[test]
    fn save_load_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("versal_gemm_plan_cache_test");
        let path = dir.join("plans.json");
        let cache = ShardedPlanCache::new(4, 64);
        cache.insert(key(512, Objective::Throughput), plan(8));
        cache.save(&path).unwrap();
        let back = ShardedPlanCache::load(&path, 4, 64).unwrap();
        assert_eq!(back.len(), 1);
        assert_eq!(
            back.get(&key(512, Objective::Throughput)),
            cache.get(&key(512, Objective::Throughput))
        );
        let _ = std::fs::remove_dir_all(dir);
    }
}
