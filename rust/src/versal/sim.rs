//! Composition of the component models into full design measurements —
//! the simulator's public API and the framework's ground truth.

use crate::config::{BoardConfig, Config, SimConfig};
use crate::tiling::Tiling;
use crate::util::rng::{fnv1a, Rng};
use crate::versal::pl::{self, BufferPlacement, Resources};
use crate::versal::power::{self, PowerBreakdown};
use crate::versal::{aie, ddr, noc};
use crate::workloads::Gemm;

/// One "on-board" measurement of a (workload, tiling) design.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Measurement {
    pub latency_s: f64,
    pub power_w: f64,
    pub resources: Resources,
    /// Throughput in GFLOP/s over the *unpadded* workload FLOPs.
    pub gflops: f64,
    /// Energy efficiency in GFLOP/s/W — the paper's decisive edge metric.
    pub energy_eff: f64,
    /// AIE duty cycle (diagnostics; drives the power activity factor).
    pub busy: f64,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimError {
    InvalidTiling,
    DoesNotFit,
    BuildFailed,
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SimError::InvalidTiling => "tiling does not evenly partition the workload",
            SimError::DoesNotFit => "design exceeds PL resources",
            SimError::BuildFailed => "design failed to build (timing/placement)",
        })
    }
}

impl std::error::Error for SimError {}

/// Latency decomposition (diagnostics and §Perf reporting).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct LatencyParts {
    pub compute_s: f64,
    pub feed_s: f64,
    pub ddr_s: f64,
    pub overhead_s: f64,
    pub total_s: f64,
}

/// The VCK190 simulator.
#[derive(Debug, Clone)]
pub struct VersalSim {
    pub board: BoardConfig,
    pub sim: SimConfig,
}

impl VersalSim {
    pub fn new(cfg: &Config) -> VersalSim {
        VersalSim {
            board: cfg.board.clone(),
            sim: cfg.sim.clone(),
        }
    }

    pub fn with(board: BoardConfig, sim: SimConfig) -> VersalSim {
        VersalSim { board, sim }
    }

    /// Exact resource allocation for a design.
    pub fn resources(&self, t: &Tiling, placement: BufferPlacement) -> Resources {
        pl::resources(t, &self.board, placement)
    }

    /// Latency decomposition without measurement noise.
    pub fn latency_parts(&self, g: &Gemm, t: &Tiling) -> Result<LatencyParts, SimError> {
        let micro = self.board.micro_tile;
        let (t_m, t_n, t_k) = t.l3_iters(g, micro).ok_or(SimError::InvalidTiling)?;
        let iters = (t_m * t_n * t_k) as f64;

        let compute_iter = aie::compute_time_per_l2_iter(t, &self.board, &self.sim);
        let feed_iter = noc::feed_time_per_l2_iter(t, &self.board, &self.sim);
        // Double buffering overlaps feed and compute inside an iteration;
        // the slower of the two paces the pipeline.
        let pipe_iter = compute_iter.max(feed_iter);
        let pipe_total = iters * pipe_iter;

        let ddr_total = ddr::ddr_time(g, t, &self.board, &self.sim).ok_or(SimError::InvalidTiling)?;

        // DDR streaming overlaps the pipeline; the binding resource wins.
        let core = pipe_total.max(ddr_total);
        // Pipeline fill/drain at workload start plus per-iteration sync
        // with the PS, plus one-time XRT kernel launch.
        let ramp = self.sim.ramp_fraction * (pipe_iter + ddr_total / iters.max(1.0));
        let overhead = self.sim.launch_overhead_s + ramp + iters * self.sim.iter_overhead_s;

        Ok(LatencyParts {
            compute_s: iters * compute_iter,
            feed_s: iters * feed_iter,
            ddr_s: ddr_total,
            overhead_s: overhead,
            total_s: core + overhead,
        })
    }

    /// Ground-truth measurement without noise (model expectation).
    pub fn evaluate_noiseless(
        &self,
        g: &Gemm,
        t: &Tiling,
        placement: BufferPlacement,
    ) -> Result<Measurement, SimError> {
        self.eval_inner(g, t, placement, false)
    }

    /// "On-board" measurement: adds deterministic per-design lognormal
    /// noise (the same design re-measured returns the same value, as a
    /// time-averaged 60 s BEAM power sample would) and gates on build
    /// success near resource capacity.
    pub fn evaluate(
        &self,
        g: &Gemm,
        t: &Tiling,
        placement: BufferPlacement,
    ) -> Result<Measurement, SimError> {
        self.eval_inner(g, t, placement, true)
    }

    fn eval_inner(
        &self,
        g: &Gemm,
        t: &Tiling,
        placement: BufferPlacement,
        noisy: bool,
    ) -> Result<Measurement, SimError> {
        let res = self.resources(t, placement);
        if !res.fits(&self.board) {
            return Err(SimError::DoesNotFit);
        }

        let mut rng = self.design_rng(g, t);
        if noisy {
            // Near-capacity designs sometimes fail placement/timing; the
            // paper "retains only successful builds".
            let util = res.max_utilization(&self.board);
            let thr = self.sim.build_fail_util_threshold;
            if util > thr {
                let p_fail = 0.6 * (util - thr) / (1.0 - thr).max(1e-9);
                if rng.bool(p_fail) {
                    return Err(SimError::BuildFailed);
                }
            }
        }

        let parts = self.latency_parts(g, t)?;
        let mut latency = parts.total_s;
        if noisy {
            latency *= rng.lognormal(self.sim.noise_sigma);
        }

        let busy = (parts.compute_s / latency).clamp(0.0, 1.0);
        let micro = self.board.micro_tile;
        let ddr_gbps = ddr::achieved_bandwidth(g, t, micro, latency) / 1e9;
        let padded = g.padded(micro);
        let total_micros =
            (padded.m / micro) as f64 * (padded.n / micro) as f64 * (padded.k / micro) as f64;
        let noc_gbps = noc::array_traffic_bytes(total_micros, &self.board) / latency / 1e9;

        let pb: PowerBreakdown =
            power::power(&res, t.n_aie(), busy, ddr_gbps, noc_gbps, &self.board, &self.sim);
        let mut power_w = pb.total();
        if noisy {
            power_w *= rng.lognormal(self.sim.noise_sigma * 0.7);
        }

        let gflops = g.flops() / latency / 1e9;
        Ok(Measurement {
            latency_s: latency,
            power_w,
            resources: res,
            gflops,
            energy_eff: gflops / power_w,
            busy,
        })
    }

    /// Recompute the component power breakdown behind a measurement —
    /// the serving executor's energy-accounting source: given the plan's
    /// resources/duty/latency it re-derives the DDR and NoC traffic
    /// rates and feeds them through [`power::power`], yielding the
    /// noiseless steady power the selected mapping draws on the VCK190.
    pub fn power_breakdown(&self, g: &Gemm, t: &Tiling, m: &Measurement) -> PowerBreakdown {
        let micro = self.board.micro_tile;
        let ddr_gbps = ddr::achieved_bandwidth(g, t, micro, m.latency_s) / 1e9;
        let padded = g.padded(micro);
        let total_micros =
            (padded.m / micro) as f64 * (padded.n / micro) as f64 * (padded.k / micro) as f64;
        let noc_gbps = noc::array_traffic_bytes(total_micros, &self.board) / m.latency_s / 1e9;
        power::power(
            &m.resources,
            t.n_aie(),
            m.busy,
            ddr_gbps,
            noc_gbps,
            &self.board,
            &self.sim,
        )
    }

    /// Deterministic per-design RNG: the same (workload, tiling, seed)
    /// always yields the same "measurement".
    fn design_rng(&self, g: &Gemm, t: &Tiling) -> Rng {
        let h = fnv1a(&t.to_bytes(g));
        Rng::new(h ^ self.sim.seed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tiling::{enumerate_candidates, TilingLimits};
    use crate::util::forall;

    fn sim() -> VersalSim {
        VersalSim::new(&Config::default())
    }

    fn valid(g: &Gemm, t: &Tiling) -> Measurement {
        sim()
            .evaluate_noiseless(g, t, BufferPlacement::UramFirst)
            .unwrap()
    }

    #[test]
    fn throughput_below_peak_and_positive() {
        let g = Gemm::new(2048, 2048, 2048);
        let t = Tiling::new((8, 8, 4), (2, 2, 2));
        let m = valid(&g, &t);
        assert!(m.gflops > 0.0);
        assert!(m.gflops < sim().board.peak_gflops());
        assert!(m.power_w > 10.0 && m.power_w < 60.0);
        assert!(m.energy_eff > 0.0);
    }

    #[test]
    fn big_compute_bound_gemm_nears_array_efficiency() {
        // A large square GEMM on 256 AIEs with good reuse should achieve
        // a solid fraction of the allocated AIEs' peak.
        let g = Gemm::new(4096, 4096, 4096);
        let t = Tiling::new((8, 8, 4), (4, 4, 4));
        let m = valid(&g, &t);
        let alloc_peak =
            256.0 / 400.0 * sim().board.peak_gflops();
        let ratio = m.gflops / alloc_peak;
        assert!(ratio > 0.55, "ratio {ratio}");
        assert!(ratio < 0.95);
    }

    #[test]
    fn more_aies_faster_for_big_workloads() {
        let g = Gemm::new(2048, 2048, 2048);
        let small = valid(&g, &Tiling::new((2, 2, 1), (4, 4, 8)));
        let big = valid(&g, &Tiling::new((8, 8, 4), (2, 2, 2)));
        assert!(big.latency_s < small.latency_s);
    }

    #[test]
    fn reuse_helps_memory_bound_workloads() {
        // Skinny GEMM: with minimal reuse the DDR path dominates; adding
        // PL reuse buffers improves throughput.
        let g = Gemm::new(64, 4096, 1024);
        let no_reuse = valid(&g, &Tiling::new((2, 8, 4), (1, 1, 1)));
        let reuse = valid(&g, &Tiling::new((2, 8, 4), (1, 4, 8)));
        assert!(reuse.gflops > no_reuse.gflops);
    }

    #[test]
    fn invalid_and_oversized_rejected() {
        let g = Gemm::new(96, 96, 96);
        let s = sim();
        assert_eq!(
            s.evaluate(&g, &Tiling::new((2, 1, 1), (1, 1, 1)), BufferPlacement::UramFirst),
            Err(SimError::InvalidTiling)
        );
        // A buffer tiling far beyond PL capacity.
        let g2 = Gemm::new(8192, 8192, 8192);
        let huge = Tiling::new((8, 8, 4), (32, 32, 2));
        assert_eq!(
            s.evaluate(&g2, &huge, BufferPlacement::UramFirst),
            Err(SimError::DoesNotFit)
        );
    }

    #[test]
    fn noise_is_deterministic_and_small() {
        let g = Gemm::new(1024, 1024, 1024);
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let s = sim();
        let a = s.evaluate(&g, &t, BufferPlacement::UramFirst).unwrap();
        let b = s.evaluate(&g, &t, BufferPlacement::UramFirst).unwrap();
        assert_eq!(a, b, "re-measuring must be deterministic");
        let clean = s.evaluate_noiseless(&g, &t, BufferPlacement::UramFirst).unwrap();
        let rel = (a.latency_s - clean.latency_s).abs() / clean.latency_s;
        assert!(rel < 0.15, "noise too large: {rel}");
        assert!(rel > 0.0, "noise absent");
    }

    #[test]
    fn power_breakdown_recovers_noiseless_power() {
        // The serving executor's energy source: the breakdown total must
        // equal the power the simulator composed into the measurement
        // (exactly, for a noiseless measurement).
        let s = sim();
        let g = Gemm::new(1024, 1024, 1024);
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let m = s.evaluate_noiseless(&g, &t, BufferPlacement::UramFirst).unwrap();
        let pb = s.power_breakdown(&g, &t, &m);
        assert!((pb.total() - m.power_w).abs() < 1e-9, "{} vs {}", pb.total(), m.power_w);
        assert!(pb.static_w > 0.0 && pb.aie_w > 0.0);
        // Noisy measurements recover the same components modulo the
        // lognormal power noise (latency noise shifts traffic rates).
        let noisy = s.evaluate(&g, &t, BufferPlacement::UramFirst).unwrap();
        let pb = s.power_breakdown(&g, &t, &noisy);
        let rel = (pb.total() - noisy.power_w).abs() / noisy.power_w;
        assert!(rel < 0.2, "rel {rel}");
    }

    #[test]
    fn latency_parts_sum_consistency() {
        let g = Gemm::new(1024, 1024, 1024);
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let p = sim().latency_parts(&g, &t).unwrap();
        let core = p.compute_s.max(p.feed_s).max(p.ddr_s);
        assert!((p.total_s - (core + p.overhead_s)).abs() < 1e-12);
    }

    #[test]
    fn energy_optimum_differs_from_throughput_optimum_somewhere() {
        // The paper's central observation (Fig. 1): for some workload the
        // most energy-efficient design is NOT the highest-throughput one.
        let s = sim();
        let limits = TilingLimits::from_board(&s.board);
        let g = Gemm::new(224, 3072, 768); // medium-FLOP, many tilings
        let cands = enumerate_candidates(&g, 32, &limits);
        let measured: Vec<(Tiling, Measurement)> = cands
            .iter()
            .filter_map(|t| {
                s.evaluate(&g, t, BufferPlacement::UramFirst)
                    .ok()
                    .map(|m| (*t, m))
            })
            .collect();
        assert!(measured.len() > 100);
        // NaN-safe best-design selection: non-finite measurements are
        // filtered before the total_cmp max (a bare total_cmp max_by
        // would let a NaN win; the old partial_cmp().unwrap() panicked).
        let best_thr = measured
            .iter()
            .filter(|c| c.1.gflops.is_finite())
            .max_by(|a, b| a.1.gflops.total_cmp(&b.1.gflops))
            .unwrap();
        let best_eff = measured
            .iter()
            .filter(|c| c.1.energy_eff.is_finite())
            .max_by(|a, b| a.1.energy_eff.total_cmp(&b.1.energy_eff))
            .unwrap();
        assert_ne!(best_thr.0, best_eff.0, "no energy/perf trade-off found");
        assert!(best_eff.1.resources.bram <= best_thr.1.resources.bram * 4);
        // Energy-best uses fewer or equal AIEs (paper Fig. 4c trend).
        assert!(best_eff.0.n_aie() <= best_thr.0.n_aie());
    }

    #[test]
    fn property_measurements_physical() {
        let s = sim();
        let limits = TilingLimits::from_board(&s.board);
        forall(
            0x5EED,
            25,
            |r| {
                Gemm::new(
                    32 * r.range_usize(1, 48),
                    32 * r.range_usize(1, 48),
                    32 * r.range_usize(1, 48),
                )
            },
            |g| {
                let cands = enumerate_candidates(g, 32, &limits);
                for t in cands.iter().step_by((cands.len() / 40).max(1)) {
                    if let Ok(m) = s.evaluate(g, t, BufferPlacement::UramFirst) {
                        assert!(m.latency_s > 0.0);
                        assert!(m.power_w > 10.0, "power {} below static", m.power_w);
                        assert!(m.power_w < 60.0, "power {} absurd", m.power_w);
                        assert!(m.gflops <= s.board.peak_gflops());
                        assert!((0.0..=1.0).contains(&m.busy));
                        assert!(m.resources.fits(&s.board));
                    }
                }
            },
        );
    }
}
