//! Bench: Fig. 3 power-model machinery — per-design power evaluation
//! rate and full offline dataset regeneration time.
use versal_gemm::config::Config;
use versal_gemm::dataset::Dataset;
use versal_gemm::report::{figures, Lab};
use versal_gemm::util::bench::{bench, once, report_throughput};
use versal_gemm::versal::{BufferPlacement, VersalSim};
use versal_gemm::workloads::{training_workloads, Gemm};
use versal_gemm::tiling::Tiling;

fn main() -> anyhow::Result<()> {
    let cfg = Config::default();
    let sim = VersalSim::new(&cfg);
    let g = Gemm::new(1024, 1024, 1024);
    let t = Tiling::new((8, 8, 4), (2, 2, 2));
    println!("== bench: Fig. 3 power profile machinery ==");
    let stats = bench(100, 10_000, || {
        std::hint::black_box(sim.evaluate(&g, &t, BufferPlacement::UramFirst).unwrap());
    });
    report_throughput("simulator evaluate()", &stats, 1.0, "designs");
    let ds = once("full offline dataset generation", || {
        Dataset::generate(&cfg, &training_workloads())
    });
    println!("  ({} designs)", ds.len());
    let lab = Lab::prepare(cfg, "data".into())?;
    println!("{}", figures::fig3_power_vs_aies(&lab));
    Ok(())
}
