//! Pluggable execution backends — how a planned GEMM job's numerics
//! actually run (DESIGN.md §3).
//!
//! Until this layer existed, execution was hard-wired to the PJRT
//! [`GemmEngine`]: without the AOT artifacts (the default in CI and
//! every offline checkout) a `GemmJob::with_data` died with "no
//! artifact engine" and the coordinator could not serve a single data
//! job end-to-end. [`ExecBackend`] breaks that coupling with three
//! implementations:
//!
//! * [`PjrtBackend`] — the original path: tiles streamed through the
//!   AOT-compiled Pallas artifacts on the PJRT CPU client;
//! * [`CpuBackend`] — always available: a blocked tiled GEMM over the
//!   same [`extract_tile`]/[`accumulate_tile`] primitives the PJRT
//!   executor composes, parallelized over row panels on the shared
//!   process-wide [`DsePool`] so execution honors the same worker
//!   budget as planning instead of spawning its own threads;
//! * [`SimBackend`] — executes via [`CpuBackend`] for real numerics but
//!   stamps the result with a [`VersalSim`] measurement, so the serving
//!   path reports the latency/power the *selected mapping* would
//!   achieve on the VCK190 — plan-quality evaluation as a service.
//!
//! [`BackendChoice::Auto`] (the default) selects PJRT when the
//! artifacts load and falls back to CPU otherwise, which is what
//! deletes the "plan-only mode" limitation the vendored `xla` stub used
//! to force.

use std::path::Path;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Result};

use crate::dse::DsePool;
use crate::runtime::{accumulate_tile, extract_tile, pick_variant, GemmEngine};
use crate::tiling::Tiling;
use crate::util::lock_unpoisoned;
use crate::versal::{BufferPlacement, Measurement, VersalSim};
use crate::workloads::Gemm;

/// One way of executing a GEMM's numerics. Implementations are owned by
/// the coordinator's executor thread (PJRT handles are not `Send`, so
/// the trait deliberately requires neither `Send` nor `Sync`).
pub trait ExecBackend {
    /// Stable identifier surfaced in the `serve` summary and stats.
    fn name(&self) -> &'static str;

    /// Whether this backend can execute the given workload.
    fn supports(&self, g: &Gemm) -> bool {
        g.m > 0 && g.n > 0 && g.k > 0
    }

    /// Execute `C[m,n] = A[m,k] @ B[k,n]` (row-major FP32).
    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>>;

    /// Artifact-variant key for executor batch grouping (PJRT reuses
    /// compiled executables across same-variant jobs; others have no
    /// variant notion).
    fn variant_hint(&self, _m: usize, _n: usize, _k: usize) -> Option<usize> {
        None
    }

    /// Board-level measurement stamp for an executed job: `Some` only
    /// for [`SimBackend`], whose results report the simulated VCK190
    /// latency/power of the job's selected mapping instead of host
    /// wall-clock.
    fn board_measurement(&self, _g: &Gemm, _t: &Tiling) -> Option<Measurement> {
        None
    }
}

/// Which backend `Coordinator::start` builds
/// (`CoordinatorOptions::backend`, `serve --backend`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BackendChoice {
    /// PJRT when the artifacts load, else [`CpuBackend`].
    #[default]
    Auto,
    Pjrt,
    Cpu,
    Sim,
}

impl BackendChoice {
    pub fn parse(text: &str) -> Result<BackendChoice> {
        match text {
            "auto" => Ok(BackendChoice::Auto),
            "pjrt" => Ok(BackendChoice::Pjrt),
            "cpu" => Ok(BackendChoice::Cpu),
            "sim" => Ok(BackendChoice::Sim),
            other => bail!("unknown backend `{other}` (pjrt|cpu|sim|auto)"),
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            BackendChoice::Auto => "auto",
            BackendChoice::Pjrt => "pjrt",
            BackendChoice::Cpu => "cpu",
            BackendChoice::Sim => "sim",
        }
    }
}

/// Build the backend a coordinator will execute on. `Auto` tries PJRT
/// when an artifacts directory is configured and falls back to the
/// always-available CPU backend (logged); explicit `Pjrt` propagates
/// the load error so a misconfigured deployment fails loudly.
pub fn make_backend(
    choice: BackendChoice,
    artifacts_dir: Option<&Path>,
    sim: VersalSim,
) -> Result<Box<dyn ExecBackend>> {
    match choice {
        BackendChoice::Cpu => Ok(Box::new(CpuBackend::new())),
        BackendChoice::Sim => Ok(Box::new(SimBackend::new(sim))),
        BackendChoice::Pjrt => {
            let dir = artifacts_dir
                .ok_or_else(|| anyhow!("backend `pjrt` requires an artifacts directory"))?;
            Ok(Box::new(PjrtBackend::load(dir)?))
        }
        BackendChoice::Auto => {
            if let Some(dir) = artifacts_dir {
                match PjrtBackend::load(dir) {
                    Ok(b) => return Ok(Box::new(b)),
                    Err(e) => {
                        eprintln!("exec backend: PJRT unavailable ({e}); falling back to cpu")
                    }
                }
            }
            Ok(Box::new(CpuBackend::new()))
        }
    }
}

/// The PJRT path: the AOT-compiled Pallas artifacts behind the
/// [`ExecBackend`] trait.
pub struct PjrtBackend {
    engine: GemmEngine,
}

impl PjrtBackend {
    pub fn load(dir: &Path) -> Result<PjrtBackend> {
        Ok(PjrtBackend {
            engine: GemmEngine::load(dir)?,
        })
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        self.engine.gemm(a, b, m, n, k)
    }

    fn variant_hint(&self, m: usize, n: usize, k: usize) -> Option<usize> {
        Some(pick_variant(&self.engine.manifest.variants, m, n, k))
    }
}

/// Default CPU block dimension: 64 keeps one A/B/C tile trio (~48 KB)
/// inside L1/L2 while giving row panels enough work per pool turn.
const CPU_TILE: usize = 64;

/// GEMMs at or below this total MAC count run inline — the pool
/// round-trip costs more than the whole product (one 64-cube). Gated
/// on *total* work, not per-panel work: a tall-skinny GEMM with many
/// small panels still amortizes one `run_scoped` fan-out across all of
/// them.
const CPU_INLINE_MACS: usize = 64 * 64 * 64;

/// Always-available host execution: blocked tiled GEMM over
/// [`extract_tile`]/[`accumulate_tile`], row panels fanned out as
/// cooperative tasks on the shared [`DsePool`] (execution and planning
/// draw from the same process-wide worker budget; a panel per turn
/// keeps concurrent explorations interleaving).
pub struct CpuBackend {
    /// `None` routes through the process-global pool.
    pool: Option<Arc<DsePool>>,
    tile: usize,
}

impl Default for CpuBackend {
    fn default() -> Self {
        CpuBackend::new()
    }
}

impl CpuBackend {
    pub fn new() -> CpuBackend {
        CpuBackend {
            pool: None,
            tile: CPU_TILE,
        }
    }

    /// Route panel tasks through a dedicated pool (tests, benches).
    pub fn with_pool(mut self, pool: Arc<DsePool>) -> CpuBackend {
        self.pool = Some(pool);
        self
    }

    fn pool(&self) -> &DsePool {
        match &self.pool {
            Some(p) => p,
            None => DsePool::global(),
        }
    }
}

/// `C_tile = A_tile @ B_tile` for square `t`-tiles (overwrites `c`).
/// Zero-padded lanes contribute nothing, so padded edge tiles are free.
fn tile_kernel(a: &[f32], b: &[f32], t: usize, c: &mut [f32]) {
    c.fill(0.0);
    for i in 0..t {
        for kk in 0..t {
            let av = a[i * t + kk];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kk * t..(kk + 1) * t];
            let crow = &mut c[i * t..(i + 1) * t];
            for (cv, bv) in crow.iter_mut().zip(brow) {
                *cv += av * bv;
            }
        }
    }
}

/// Per-thread A/B/C tile scratch, reused across panels, jobs, and the
/// process lifetime of whichever thread computes panels (pool workers
/// and the executor thread) — the same TLS pattern as the DSE worker
/// scratch, so the serving hot path allocates nothing per panel.
#[derive(Default)]
struct TileScratch {
    a: Vec<f32>,
    b: Vec<f32>,
    c: Vec<f32>,
}

thread_local! {
    static TILE_SCRATCH: std::cell::RefCell<TileScratch> =
        std::cell::RefCell::new(TileScratch::default());
}

/// Compute one row panel (`rows r0 .. r0+panel_rows` of C) of the
/// blocked product. `panel` is that slice of the output matrix.
#[allow(clippy::too_many_arguments)]
fn gemm_panel(
    a: &[f32],
    b: &[f32],
    m: usize,
    n: usize,
    k: usize,
    r0: usize,
    tile: usize,
    panel: &mut [f32],
) {
    let panel_rows = (m - r0).min(tile);
    debug_assert_eq!(panel.len(), panel_rows * n);
    TILE_SCRATCH.with(|cell| {
        let scratch = &mut *cell.borrow_mut();
        // resize is a no-op after the first panel at this tile size;
        // extract_tile and tile_kernel overwrite every lane they read.
        scratch.a.resize(tile * tile, 0.0);
        scratch.b.resize(tile * tile, 0.0);
        scratch.c.resize(tile * tile, 0.0);
        for kk in (0..k).step_by(tile) {
            extract_tile(a, m, k, r0, kk, tile, tile, &mut scratch.a);
            for j in (0..n).step_by(tile) {
                extract_tile(b, k, n, kk, j, tile, tile, &mut scratch.b);
                tile_kernel(&scratch.a, &scratch.b, tile, &mut scratch.c);
                accumulate_tile(panel, panel_rows, n, 0, j, tile, tile, &scratch.c);
            }
        }
    });
}

impl ExecBackend for CpuBackend {
    fn name(&self) -> &'static str {
        "cpu"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        if a.len() != m * k || b.len() != k * n {
            bail!("operand shapes do not match {m}x{n}x{k}");
        }
        let mut c = vec![0f32; m * n];
        let tile = self.tile;
        let n_panels = m.div_ceil(tile);
        let serial = |c: &mut [f32]| {
            for p in 0..n_panels {
                let r0 = p * tile;
                let end = ((p + 1) * tile * n).min(m * n);
                gemm_panel(a, b, m, n, k, r0, tile, &mut c[r0 * n..end]);
            }
        };
        // Decide serial vs fan-out before touching the pool, so tiny
        // GEMMs never lazily spin up the global worker threads.
        if n_panels <= 1 || m * n * k <= CPU_INLINE_MACS {
            serial(&mut c);
            return Ok(c);
        }
        let pool = self.pool();
        if pool.n_threads() == 1 {
            serial(&mut c);
            return Ok(c);
        }
        // One cooperative pool turn per row panel: panels are disjoint
        // slices of C, each claimed exactly once off the shared counter,
        // so the result is bit-identical for any pool width.
        let next = AtomicUsize::new(0);
        let panics = {
            let panels: Vec<Mutex<(usize, &mut [f32])>> = c
                .chunks_mut(tile * n)
                .enumerate()
                .map(Mutex::new)
                .collect();
            let n_tasks = pool.n_threads().min(n_panels);
            pool.run_scoped(n_tasks, |_| {
                let p = next.fetch_add(1, Ordering::SeqCst);
                if p >= n_panels {
                    return false;
                }
                let mut guard = lock_unpoisoned(&panels[p]);
                let (idx, panel) = &mut *guard;
                gemm_panel(a, b, m, n, k, *idx * tile, tile, panel);
                true
            })
        };
        if panics > 0 {
            bail!("cpu backend worker panicked executing {m}x{n}x{k}");
        }
        Ok(c)
    }
}

/// Plan-quality evaluation as a service: real numerics via
/// [`CpuBackend`], but the result is stamped with the [`VersalSim`]
/// measurement of the job's selected mapping, so `exec_time`, power,
/// and GFLOPS/W report what the plan would deliver on the VCK190.
pub struct SimBackend {
    cpu: CpuBackend,
    sim: VersalSim,
}

impl SimBackend {
    pub fn new(sim: VersalSim) -> SimBackend {
        SimBackend {
            cpu: CpuBackend::new(),
            sim,
        }
    }

    pub fn with_cpu(cpu: CpuBackend, sim: VersalSim) -> SimBackend {
        SimBackend { cpu, sim }
    }
}

impl ExecBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn gemm(&self, a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Result<Vec<f32>> {
        self.cpu.gemm(a, b, m, n, k)
    }

    fn board_measurement(&self, g: &Gemm, t: &Tiling) -> Option<Measurement> {
        self.sim.evaluate(g, t, BufferPlacement::UramFirst).ok()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::runtime::{matmul_ref, max_abs_diff};
    use crate::util::rng::Rng;

    fn randn(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n).map(|_| rng.normal() as f32).collect()
    }

    #[test]
    fn cpu_backend_matches_reference() {
        let cpu = CpuBackend::new();
        let mut rng = Rng::new(11);
        for (m, n, k) in [
            (1, 1, 1),
            (1, 33, 7),
            (70, 50, 90),
            (64, 64, 64),
            (65, 63, 66),
            (1, 256, 130),
            (97, 1, 5),
            (128, 128, 1),
            (200, 96, 131),
        ] {
            let a = randn(&mut rng, m * k);
            let b = randn(&mut rng, k * n);
            let got = cpu.gemm(&a, &b, m, n, k).unwrap();
            let want = matmul_ref(&a, &b, m, n, k);
            let err = max_abs_diff(&got, &want);
            assert!(err < 1e-3, "{m}x{n}x{k}: err {err}");
        }
    }

    #[test]
    fn cpu_backend_rejects_bad_shapes() {
        let cpu = CpuBackend::new();
        assert!(cpu.gemm(&[0.0; 10], &[0.0; 16], 4, 4, 4).is_err());
        assert!(cpu.gemm(&[0.0; 16], &[0.0; 10], 4, 4, 4).is_err());
    }

    #[test]
    fn cpu_backend_identical_across_pool_widths() {
        // Panel decomposition is fixed, so any worker interleaving
        // produces bit-identical output.
        let mut rng = Rng::new(5);
        let (m, n, k) = (300, 129, 170);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let base = CpuBackend::new()
            .with_pool(Arc::new(DsePool::new(1)))
            .gemm(&a, &b, m, n, k)
            .unwrap();
        for width in [2usize, 4, 8] {
            let got = CpuBackend::new()
                .with_pool(Arc::new(DsePool::new(width)))
                .gemm(&a, &b, m, n, k)
                .unwrap();
            assert_eq!(got, base, "width {width}");
        }
    }

    #[test]
    fn backend_choice_parses() {
        assert_eq!(BackendChoice::parse("auto").unwrap(), BackendChoice::Auto);
        assert_eq!(BackendChoice::parse("pjrt").unwrap(), BackendChoice::Pjrt);
        assert_eq!(BackendChoice::parse("cpu").unwrap(), BackendChoice::Cpu);
        assert_eq!(BackendChoice::parse("sim").unwrap(), BackendChoice::Sim);
        assert!(BackendChoice::parse("tpu").is_err());
        assert_eq!(BackendChoice::default().label(), "auto");
    }

    #[test]
    fn auto_without_artifacts_is_cpu_and_explicit_pjrt_fails_loudly() {
        let cfg = Config::default();
        let missing = Path::new("definitely/not/artifacts");
        let b = make_backend(BackendChoice::Auto, Some(missing), VersalSim::new(&cfg)).unwrap();
        assert_eq!(b.name(), "cpu");
        let b = make_backend(BackendChoice::Auto, None, VersalSim::new(&cfg)).unwrap();
        assert_eq!(b.name(), "cpu");
        assert!(make_backend(BackendChoice::Pjrt, Some(missing), VersalSim::new(&cfg)).is_err());
        assert!(make_backend(BackendChoice::Pjrt, None, VersalSim::new(&cfg)).is_err());
    }

    #[test]
    fn sim_backend_stamps_measurement_and_matches_cpu_numerics() {
        let cfg = Config::default();
        let sim = SimBackend::new(VersalSim::new(&cfg));
        assert_eq!(sim.name(), "sim");
        let mut rng = Rng::new(9);
        let (m, n, k) = (64, 96, 32);
        let a = randn(&mut rng, m * k);
        let b = randn(&mut rng, k * n);
        let got = sim.gemm(&a, &b, m, n, k).unwrap();
        assert!(max_abs_diff(&got, &matmul_ref(&a, &b, m, n, k)) < 1e-3);
        let g = Gemm::new(1024, 1024, 1024);
        let t = Tiling::new((4, 4, 2), (2, 2, 2));
        let mea = sim.board_measurement(&g, &t).expect("buildable design");
        assert!(mea.latency_s > 0.0 && mea.power_w > 0.0);
        // Non-sim backends never stamp.
        assert!(CpuBackend::new().board_measurement(&g, &t).is_none());
    }

    #[test]
    fn supports_rejects_degenerate_dims() {
        let cpu = CpuBackend::new();
        assert!(cpu.supports(&Gemm::new(64, 64, 64)));
        assert!(!cpu.supports(&Gemm::new(0, 64, 64)));
    }
}
